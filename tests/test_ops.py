"""§IV analysis operations on structured tracegen apps."""

import numpy as np
import pytest

from repro import tracegen as tg
from repro.core.constants import NAME, PROC


def test_loimos_load_imbalance_finds_hot_procs():
    t = tg.loimos(nprocs=64, iters=3, hot_procs=(21, 22, 23))
    li = t.load_imbalance(num_processes=3)
    row = {n: i for i, n in enumerate(li[NAME])}
    idx = row["ComputeInteractions()"]
    top = li["Top processes"][idx]
    assert set(int(p) for p in top) <= {21, 22, 23}
    assert li["time.exc.imbalance"][idx] > 1.5


def test_comm_matrix_symmetric_neighbors():
    t = tg.stencil3d(nprocs=27, iters=2)
    cm = t.comm_matrix()
    assert np.allclose(cm, cm.T)               # symmetric exchange
    assert cm.diagonal().sum() == 0
    # three message-size clusters (face/edge/corner analog)
    counts, edges = t.message_histogram(bins=10)
    assert (counts > 0).sum() >= 2


def test_comm_over_time_bursty():
    t = tg.gol(nprocs=4, iters=6)
    vals, edges = t.comm_over_time(num_bins=16)
    assert vals.sum() > 0
    assert len(vals) == 16


def test_idle_time_ranking():
    t = tg.loimos(nprocs=16, iters=3, hot_procs=(3,))
    idle = t.idle_time(k=16)
    procs = idle[PROC].tolist()
    # hot proc 3 idles the least → should be last in most-idle ranking
    assert int(procs[-1]) == 3


def test_kripke_critical_path_crosses_processes():
    t = tg.kripke_sweep(nprocs=8, iters=2)
    cp = t.critical_path_analysis()[0]
    assert len(set(cp[PROC].tolist())) >= 4    # wavefront spans ranks


def test_gol_lateness_positive_for_laggard():
    t = tg.gol(nprocs=4, iters=5, imbalance=0.5)
    lb = t.lateness_by_process()
    assert np.asarray(lb["max_lateness"]).max() > 0


def test_tortuga_pattern_detection_counts_iterations():
    t = tg.tortuga(nprocs=8, iters=6)
    pats = t.detect_pattern(start_event="time-loop")
    assert len(pats) == 6


def test_axonn_overlap_ordering():
    """v2 (overlapped) must show more overlap and less exposed comm than v0."""
    bd = {v: tg.axonn_training(nprocs=4, iters=4, version=v)
          .comm_comp_breakdown() for v in (0, 1, 2)}
    ov = {v: np.asarray(b["overlap"]).mean() for v, b in bd.items()}
    comm = {v: np.asarray(b["comm_only"]).mean() for v, b in bd.items()}
    assert ov[2] > ov[0]
    assert comm[1] < comm[0]
    assert comm[2] < comm[0]


def test_multirun_scaling_study():
    from repro.core.trace import Trace
    traces = [tg.tortuga(nprocs=n, iters=3) for n in (4, 8, 16)]
    df = Trace.multirun_analysis(traces, top_n=6)
    assert "computeRhs" in list(df.columns) or "computeRhs" in list(df[df.columns[0]])


def test_time_profile_backend_registry():
    """time_profile backends dispatch through the registered table: unknown
    names fail loudly listing the options, and user backends register the
    same way the built-ins do."""
    from repro.core import ops_summary

    t = tg.gol(nprocs=2, iters=2)
    with pytest.raises(ValueError, match="numpy.*pallas|pallas.*numpy"):
        t.time_profile(num_bins=8, backend="nope")

    @ops_summary.register_time_profile_backend("double")
    def _double(starts, ends, rate, name_codes, edges, nf):
        return 2 * ops_summary._exact_profile(starts, ends, rate,
                                              name_codes, edges, nf)

    try:
        a = t.time_profile(num_bins=8)
        b = t.time_profile(num_bins=8, backend="double")
        cols = [c for c in a.columns if c not in ("bin_start", "bin_end")]
        for c in cols:
            np.testing.assert_allclose(np.asarray(b[c]),
                                       2 * np.asarray(a[c]))
    finally:
        del ops_summary.TIME_PROFILE_BACKENDS["double"]


def test_time_profile_pallas_backend_parity_fast():
    """Interpret-mode parity of the registered Pallas kernel backend on a
    small trace — the fast-tier guard that keeps the kernel exercised
    (the full sweep lives in tests/test_kernels.py, slow tier)."""
    t = tg.gol(nprocs=2, iters=2, seed=3)
    a = t.time_profile(num_bins=8)
    b = t.time_profile(num_bins=8, backend="pallas")
    cols = [c for c in a.columns if c not in ("bin_start", "bin_end")]
    assert cols == [c for c in b.columns if c not in ("bin_start", "bin_end")]
    for c in cols:
        np.testing.assert_allclose(np.asarray(b[c]), np.asarray(a[c]),
                                   rtol=1e-5, atol=1e-3)
