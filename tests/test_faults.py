"""Closed-loop fault-injection suite.

Every test injects a fault whose ground truth is known exactly (which
bytes, which chunk group, which connection) via ``repro.testing.faults``
and then asserts the recovery contract end to end:

* pack integrity: strict opens fail loudly naming the file; salvage
  keeps precisely the undamaged chunk groups; torn footers rebuild from
  the chunk-trailer scan with zero row loss;
* ``tools/pack.py --verify`` / ``--repair`` as a subprocess round trip,
  including a SIGKILL-mid-write crash-consistency check;
* transport: the client retries idempotent requests through injected
  connection resets (including mid-response) and surfaces server-side
  deadline expiry as 504;
* the handle pool's circuit breaker trips after repeated injected open
  failures and recovers after its cooldown.
"""

import asyncio
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro import tracegen
from repro.core import plancache, registry
from repro.core.constants import TS
from repro.core.trace import Trace
from repro.readers.pack import (io_stats, read_pack, repair_pack,
                                verify_pack, write_pack)
from repro.serving.client import RemoteError, ServiceClient
from repro.serving.tracequery import (ServiceError, TraceServer,
                                      TraceService)
from repro.testing.faults import (FaultProxy, bit_flip, flaky_opens,
                                  garbage_append, torn_footer, truncate_at)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PACK_TOOL = os.path.join(REPO, "tools", "pack.py")


def run(coro):
    return asyncio.run(coro)


@pytest.fixture(scope="module")
def golden_pack(tmp_path_factory):
    """A pack with many small chunk groups, so single-group damage has a
    precisely known blast radius."""
    d = tmp_path_factory.mktemp("faults")
    t = tracegen.gol(nprocs=3, iters=10, seed=5)
    p = str(d / "golden.pack")
    write_pack(t, p, chunk_rows=40)
    return p


@pytest.fixture()
def fresh_cache():
    plancache.clear()
    yield
    plancache.clear()


# ---------------------------------------------------------------------------
# file-level injectors: determinism and reports
# ---------------------------------------------------------------------------

def test_injectors_are_deterministic(golden_pack, tmp_path):
    a, b = str(tmp_path / "a.pack"), str(tmp_path / "b.pack")
    ra = bit_flip(golden_pack, a, frac=0.4, count=3, seed=9)
    rb = bit_flip(golden_pack, b, frac=0.4, count=3, seed=9)
    assert ra == rb
    assert open(a, "rb").read() == open(b, "rb").read()
    ra = garbage_append(golden_pack, a, nbytes=64, seed=9)
    rb = garbage_append(golden_pack, b, nbytes=64, seed=9)
    assert open(a, "rb").read() == open(b, "rb").read()
    r = truncate_at(golden_pack, a, frac=0.25)
    assert r["cut_at"] == os.path.getsize(a)
    assert r["lost"] == r["size"] - r["cut_at"]


# ---------------------------------------------------------------------------
# pack salvage: exact blast radius
# ---------------------------------------------------------------------------

def test_single_group_flip_quarantines_only_that_group(golden_pack,
                                                       tmp_path):
    full = read_pack(golden_pack)
    rep = verify_pack(golden_pack)
    assert rep["ok"] and rep["chunks_total"] >= 5

    # flip one byte at ~40% of the file: the body of an interior group
    bad = str(tmp_path / "flip.pack")
    bit_flip(golden_pack, bad, frac=0.4, count=1, seed=3)

    vrep = verify_pack(bad)
    assert not vrep["ok"]
    bad_groups = vrep["chunks_bad"]
    assert len(bad_groups) >= 1

    # strict is the zero-scan mmap fast path: structure is intact, so it
    # returns the stored bytes without CRC-checking them (integrity is
    # what verify_pack and the verifying open modes are for)
    assert len(read_pack(bad, on_error="strict")) == len(full)

    # salvage: exactly the rows outside the quarantined groups survive
    before = io_stats()
    t = read_pack(bad, on_error="salvage")
    after = io_stats()
    lost = sum(g["rows"][1] - g["rows"][0] for g in bad_groups)
    assert len(t) == len(full) - lost
    assert (after["chunks_quarantined"] - before["chunks_quarantined"]
            == len(bad_groups))

    # the survivors are byte-identical to the same rows of the original
    keep = np.ones(len(full), bool)
    for g in bad_groups:
        keep[g["rows"][0]:g["rows"][1]] = False
    np.testing.assert_array_equal(np.asarray(t.events[TS]),
                                  np.asarray(full.events[TS])[keep])

    # and the ingest report counts the quarantine
    from repro.core.errors import IngestReport
    rpt = IngestReport()
    t2 = read_pack(bad, on_error="salvage", report=rpt)
    assert rpt.total_skipped() == len(bad_groups)
    assert len(t2) == len(t)


def test_torn_footer_rebuilds_all_rows(golden_pack, tmp_path):
    full = read_pack(golden_pack)
    torn = str(tmp_path / "torn.pack")
    torn_footer(golden_pack, torn)
    with pytest.raises(ValueError, match="torn.pack"):
        read_pack(torn, on_error="strict")
    before = io_stats()
    t = read_pack(torn, on_error="salvage")
    after = io_stats()
    assert after["footers_rebuilt"] - before["footers_rebuilt"] == 1
    assert len(t) == len(full)
    np.testing.assert_array_equal(np.asarray(t.events[TS]),
                                  np.asarray(full.events[TS]))


def test_truncation_keeps_intact_prefix(golden_pack, tmp_path):
    from repro.readers.pack import read_footer
    full = read_pack(golden_pack)
    # cut in the middle of an interior chunk group's data, so the groups
    # before it survive and everything from it on is lost
    chunks = read_footer(golden_pack)["chunks"]
    victim = chunks[len(chunks) // 2]
    cut = str(tmp_path / "cut.pack")
    truncate_at(golden_pack, cut,
                offset=victim["offset"] + victim["nbytes"] // 2)
    t = read_pack(cut, on_error="salvage")
    n = len(t)
    assert n == victim["lo"]  # exactly the groups before the cut
    assert 0 < n < len(full)
    np.testing.assert_array_equal(np.asarray(t.events[TS]),
                                  np.asarray(full.events[TS])[:n])


def test_garbage_tail_salvages_every_row(golden_pack, tmp_path):
    full = read_pack(golden_pack)
    gar = str(tmp_path / "gar.pack")
    garbage_append(golden_pack, gar, nbytes=512, seed=1)
    with pytest.raises(ValueError, match="gar.pack"):
        read_pack(gar, on_error="strict")
    t = read_pack(gar, on_error="salvage")
    assert len(t) == len(full)


# ---------------------------------------------------------------------------
# tools/pack.py --verify / --repair round trip
# ---------------------------------------------------------------------------

def _tool(*argv):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run([sys.executable, PACK_TOOL, *argv],
                          capture_output=True, text=True, env=env)


def test_cli_verify_and_repair(golden_pack, tmp_path):
    r = _tool("--verify", golden_pack)
    assert r.returncode == 0 and "OK" in r.stdout

    bad = str(tmp_path / "cli.pack")
    torn_footer(golden_pack, bad)
    r = _tool("--verify", bad)
    assert r.returncode == 1
    assert "repair" in r.stdout.lower()

    fixed = str(tmp_path / "fixed.pack")
    r = _tool("--repair", bad, "-o", fixed)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "footer rebuilt" in r.stdout
    r = _tool("--verify", fixed)
    assert r.returncode == 0

    full = read_pack(golden_pack)
    rec = read_pack(fixed)
    assert len(rec) == len(full)
    np.testing.assert_array_equal(np.asarray(rec.events[TS]),
                                  np.asarray(full.events[TS]))


def test_crash_consistency_sigkill_mid_write(tmp_path):
    """SIGKILL a writer partway through a pack write, then assert the
    survivor contract: strict open fails loudly, --repair recovers every
    complete chunk group, and the repaired pack verifies clean."""
    dst = str(tmp_path / "crash.pack")
    code = (
        "import sys; sys.path.insert(0, %r)\n"
        "from repro import tracegen\n"
        "from repro.readers.pack import write_pack\n"
        "t = tracegen.gol(nprocs=3, iters=40, seed=2)\n"
        "print('ready', len(t.events), flush=True)\n"
        "write_pack(t, %r, chunk_rows=64)\n"
        "print('done', flush=True)\n" % (os.path.join(REPO, "src"), dst)
    )
    proc = subprocess.Popen([sys.executable, "-c", code],
                            stdout=subprocess.PIPE, text=True)
    assert proc.stdout.readline().startswith("ready")
    # kill while the chunked write is in flight (poll for partial bytes)
    deadline = time.time() + 30
    while time.time() < deadline:
        if os.path.exists(dst) and os.path.getsize(dst) > 4096:
            break
        if proc.poll() is not None:
            break
        time.sleep(0.001)
    proc.send_signal(signal.SIGKILL)
    proc.wait()
    if not os.path.exists(dst) or os.path.getsize(dst) == 0:
        pytest.skip("writer finished or never started before SIGKILL")

    fixed = str(tmp_path / "recovered.pack")
    r = _tool("--repair", dst, "-o", fixed)
    assert r.returncode == 0, r.stdout + r.stderr
    assert _tool("--verify", fixed).returncode == 0
    rec = read_pack(fixed)
    # whatever was recovered is a prefix of the source trace, bit-exact
    t = tracegen.gol(nprocs=3, iters=40, seed=2)
    src_ts = np.asarray(t.events[TS], np.int64)
    ts = np.asarray(rec.events[TS])
    assert len(ts) <= len(src_ts)
    np.testing.assert_array_equal(ts, src_ts[:len(ts)])


# ---------------------------------------------------------------------------
# transport faults: retry through resets, deadline 504
# ---------------------------------------------------------------------------

def test_client_retries_through_connection_resets(golden_pack, fresh_cache):
    local = Trace.open(golden_pack).query().flat_profile()
    from repro.serving.protocol import result_digest

    async def main():
        server = await TraceServer(TraceService(), port=0).start()

        def client_work():
            with FaultProxy("127.0.0.1", server.port,
                            reset_every=2) as proxy:
                with ServiceClient("127.0.0.1", proxy.port,
                                   retries=3, backoff=0.01) as c:
                    profs = [c.open(golden_pack).query().flat_profile()
                             for _ in range(6)]
                    return profs, c.retry_count, dict(proxy.stats)

        out = await asyncio.to_thread(client_work)
        await server.shutdown(grace=5)
        return out

    profs, retries, stats = run(main())
    # every request eventually succeeded despite every 2nd conn dying
    assert len(profs) == 6
    for p in profs:
        assert result_digest(p) == result_digest(local)
    assert retries >= 1
    assert stats["resets"] >= 1


def test_client_survives_mid_response_reset(golden_pack, fresh_cache):
    """A reset *after* part of the response was forwarded: the dangerous
    case — the request executed server-side, and the retry must still
    converge because plan execution is digest-idempotent."""
    local = Trace.open(golden_pack).query().flat_profile()
    from repro.serving.protocol import result_digest

    async def main():
        server = await TraceServer(TraceService(), port=0).start()

        def client_work():
            with FaultProxy("127.0.0.1", server.port, reset_every=2,
                            reset_after_bytes=40) as proxy:
                with ServiceClient("127.0.0.1", proxy.port,
                                   retries=4, backoff=0.01) as c:
                    profs = [c.open(golden_pack).query().flat_profile()
                             for _ in range(4)]
                    return profs, dict(proxy.stats)

        out = await asyncio.to_thread(client_work)
        await server.shutdown(grace=5)
        return out

    profs, stats = run(main())
    assert len(profs) == 4
    for p in profs:
        assert result_digest(p) == result_digest(local)
    assert stats["resets"] >= 1


def test_deadline_expiry_is_504(golden_pack, fresh_cache):
    @registry.register_op("_fault_sleep")
    def _fault_sleep(trace, duration=1.0):
        time.sleep(float(duration))
        return float(len(trace.events))

    try:
        async def main():
            server = await TraceServer(TraceService(), port=0).start()

            def client_work():
                with ServiceClient("127.0.0.1", server.port) as c:
                    q = c.open(golden_pack).query()
                    t0 = time.monotonic()
                    with pytest.raises(RemoteError) as exc:
                        q.run("_fault_sleep", cache=False, deadline_ms=80)
                    elapsed = time.monotonic() - t0
                    # generous deadline on the same op succeeds
                    ok = q.run("_fault_sleep", duration=0.01, cache=False,
                               deadline_ms=10_000)
                    return exc.value, elapsed, ok

            out = await asyncio.to_thread(client_work)
            await server.shutdown(grace=5)
            return out

        err, elapsed, ok = run(main())
        assert err.status == 504 and err.code == "deadline_exceeded"
        assert elapsed < 0.9  # answered long before the 1s op finished
        assert ok > 0
    finally:
        registry._OP_REGISTRY.pop("_fault_sleep", None)


def test_streaming_deadline_cancels_at_chunk_boundary(golden_pack,
                                                      fresh_cache):
    """An expired deadline on a streaming scan frees the lane thread via
    cooperative cancellation — the next request runs immediately."""
    async def main():
        svc = TraceService()
        body = {"open": {"paths": [golden_pack], "streaming": True,
                         "chunk_rows": 16},
                "op": "flat_profile", "steps": [], "tenant": "t",
                "args": [], "kwargs": {}, "cache": False,
                "deadline_ms": 0.0001}
        with pytest.raises(ServiceError) as exc:
            await svc.query(body)
        assert exc.value.status == 504
        # the lane is free: an undeadlined request completes normally
        body2 = dict(body)
        body2.pop("deadline_ms")
        out = await svc.query(body2)
        return exc.value, out, svc.counters.get("deadline_exceeded", 0)

    err, out, n504 = run(main())
    assert err.code == "deadline_exceeded"
    assert out["ok"] and n504 >= 1


# ---------------------------------------------------------------------------
# circuit breaker on injected open failures
# ---------------------------------------------------------------------------

def test_breaker_trips_and_recovers(golden_pack, fresh_cache):
    async def main():
        svc = TraceService(breaker_threshold=3, breaker_cooldown=0.2)
        body = lambda: {"open": {"paths": [golden_pack],
                                 "streaming": False},
                        "op": "flat_profile", "steps": [], "tenant": "t",
                        "args": [], "kwargs": {}, "cache": False}
        codes = []
        with flaky_opens(3) as counter:
            for _ in range(5):
                try:
                    await svc.query(body())
                    codes.append("ok")
                except ServiceError as e:
                    codes.append((e.status, e.code))
        # wait out the cooldown; the probe open now succeeds (injector
        # exhausted) and the breaker resets
        await asyncio.sleep(0.25)
        out = await svc.query(body())
        return codes, counter, svc.handles.stats(), out

    codes, counter, stats, out = run(main())
    # 1st+2nd: plain open_failed; 3rd trips the breaker to 422;
    # 4th+5th: fast-fail without touching the injector
    assert codes[0] == (404, "open_failed")
    assert codes[1] == (404, "open_failed")
    assert codes[2] == (422, "source_corrupt")
    assert codes[3] == (422, "source_corrupt")
    assert codes[4] == (422, "source_corrupt")
    # only 3 opens reached the injector: the 2 fast-fails never did
    assert counter["failed"] == 3 and counter["calls"] == 3
    assert stats["breaker_trips"] >= 1
    assert stats["breaker_fastfails"] >= 2
    assert out["ok"]


def test_breaker_fastfail_carries_salvage_hint(tmp_path, fresh_cache):
    bad = str(tmp_path / "bad.pack")
    with open(bad, "wb") as f:
        f.write(b"#pipitpack 2\n" + b"\x00" * 64)

    async def main():
        svc = TraceService(breaker_threshold=2, breaker_cooldown=60.0)
        body = {"open": {"paths": [bad], "streaming": False},
                "op": "flat_profile", "steps": [], "tenant": "t",
                "args": [], "kwargs": {}, "cache": False}
        last = None
        for _ in range(3):
            try:
                await svc.query(body)
            except ServiceError as e:
                last = e
        return last

    err = run(main())
    assert err.status == 422 and err.code == "source_corrupt"
    assert "tools/pack.py" in str(err) and "salvage" in str(err)


def test_verified_clean_cache_skips_resweep(golden_pack, tmp_path):
    """A pack that passed its CRC sweep is not re-swept until the file
    changes on disk; in-place damage invalidates the cached verdict."""
    import shutil

    from repro.readers import pack as packmod

    p = str(tmp_path / "clean.pack")
    shutil.copyfile(golden_pack, p)
    packmod._VERIFIED_CLEAN.clear()

    packmod.reset_io_stats()
    t1 = read_pack(p, on_error="salvage")
    assert io_stats()["verify_cache_hits"] == 0
    t2 = read_pack(p, on_error="salvage")
    assert io_stats()["verify_cache_hits"] >= 1
    assert len(t1.events) == len(t2.events)

    # in-place rewrite: stat identity changes, so the sweep runs again
    # and the damaged group is quarantined, not served from the cache
    time.sleep(0.01)  # ensure mtime_ns moves even on coarse filesystems
    from repro.readers.pack import read_footer
    victim = read_footer(p)["chunks"][0]
    bit_flip(p, p, offsets=[victim["offset"] + 5])
    packmod.reset_io_stats()
    with pytest.warns(RuntimeWarning, match="quarantined"):
        t3 = read_pack(p, on_error="salvage")
    assert io_stats()["verify_cache_hits"] == 0
    assert io_stats()["chunks_quarantined"] == 1
    assert len(t3.events) == len(t1.events) - (victim["hi"] - victim["lo"])
