"""Per-op backend registry property suite (accelerator-resident ops PR).

Every (op, backend) pair in the registry is exercised over the inputs that
historically break block-padded kernels: zero-duration calls, calls
straddling the profile window's bin edges, empty selections, record
counts that are not a multiple of the kernel block size, and name counts
that are not a multiple of the block size.  Non-numpy backends must agree
with the exact numpy reference to f32 rounding, and must be
digest-identical across every execution path — eager, streaming over a
pack, and parallel run-units over sharded jsonl (the merge_from seam).
"""

import numpy as np
import pytest

from repro import tracegen as tg
from repro.core import registry
from repro.core.constants import EXC, INC, NAME
from repro.core.executor import execute_parallel
from repro.core.filters import Filter
from repro.core.streaming import StreamingTrace
from repro.core.trace import Trace
from repro.readers.jsonl import write_jsonl
from repro.readers.pack import write_pack
from repro.serving.protocol import result_digest
from repro.tracegen.builder import TraceBuilder

KERNEL_OPS = ("flat_profile", "time_profile", "load_imbalance",
              "comm_matrix", "message_histogram", "stragglers")

OP_KWARGS = {
    "flat_profile": {"metrics": (EXC, INC)},
    "time_profile": {"num_bins": 8},
    "load_imbalance": {},
    "comm_matrix": {},
    "message_histogram": {"bins": 8},
    "stragglers": {"threshold": 0.05},
}

PAIRS = [(op, b) for op in KERNEL_OPS for b in registry.list_backends(op)]
ACCEL = [(op, b) for op, b in PAIRS if b != "numpy"]


def assert_equivalent(op, a, b, context=""):
    """Backend result vs numpy reference: f32 rounding on sums, exact
    counts/edges, exact everything non-float."""
    if op == "comm_matrix":
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-3, err_msg=context)
        return
    if op == "message_histogram":
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]),
                                      err_msg=f"{context}: counts")
        np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]),
                                   err_msg=f"{context}: edges")
        return
    assert list(a.columns) == list(b.columns), context
    assert len(a) == len(b), context
    for c in a.columns:
        va, vb = np.asarray(a[c]), np.asarray(b[c])
        if va.dtype.kind == "f":
            np.testing.assert_allclose(va, vb, rtol=1e-5, atol=1e-3,
                                       err_msg=f"{context}: column {c}")
        elif va.dtype == object:
            for x, y in zip(va, vb):
                assert np.array_equal(np.asarray(x), np.asarray(y)), \
                    f"{context}: column {c}"
        else:
            np.testing.assert_array_equal(va, vb,
                                          err_msg=f"{context}: column {c}")


# ---------------------------------------------------------------------------
# registry surface
# ---------------------------------------------------------------------------

def test_every_kernel_op_has_both_backends():
    for op in KERNEL_OPS:
        names = registry.list_backends(op)
        assert "numpy" in names and "pallas" in names, op
        assert names == sorted(names)
        assert list(registry.get_op(op).backends) == names


def test_unknown_backend_fails_loudly_listing_options():
    for op in KERNEL_OPS:
        with pytest.raises(ValueError,
                           match="numpy.*pallas|pallas.*numpy"):
            registry.get_backend(op, "nope")


def test_register_backend_roundtrip():
    @registry.register_backend("comm_matrix", "zeros_test")
    def _zeros(trace, **kw):
        n = 1
        return np.zeros((n, n))

    try:
        assert "zeros_test" in registry.list_backends("comm_matrix")
        assert registry.get_backend("comm_matrix", "zeros_test") is _zeros
        t = tg.stencil3d(nprocs=8, iters=1)
        assert t.comm_matrix(backend="zeros_test").sum() == 0
    finally:
        del registry.op_backends("comm_matrix")["zeros_test"]
    assert "zeros_test" not in registry.list_backends("comm_matrix")


# ---------------------------------------------------------------------------
# edge-input properties, every (op, backend) pair
# ---------------------------------------------------------------------------

def _edge_trace():
    """Deterministic trace with every pathological shape at once: zero
    duration calls, 7 names (not a block multiple), sends, and a call
    count that is not a multiple of any kernel block size."""
    tb = TraceBuilder()
    for p in range(3):
        t = float(p) * 0.1
        for i in range(161):                       # 3*161 = 483 calls
            # proc-dependent durations so per-proc totals are never exactly
            # tied (ties make top-process ranking rounding-sensitive)
            dur = 0.0 if i % 7 == 0 else (0.5 + ((i + 3 * p) % 5) * 0.25
                                          + p * 0.01)
            t = tb.call(t, dur, f"f{i % 7}", p)
        t = tb.send(t, 1.0, p, (p + 1) % 3, 64.0 * (p + 1))
        tb.recv(t, 1.0, p, (p - 1) % 3, 64.0 * ((p - 1) % 3 + 1))
    return tb.trace()


@pytest.fixture(scope="module")
def edge_trace():
    return _edge_trace()


@pytest.mark.parametrize("op,backend", ACCEL)
def test_zero_duration_and_padded_tail(edge_trace, op, backend):
    """483 call records (not a multiple of 256), 69 of them zero-duration,
    7 function names: the padded tail blocks and sentinel rows must not
    leak into the result."""
    kw = OP_KWARGS[op]
    ref = edge_trace.query().run(op, cache=False, backend="numpy", **kw)
    res = edge_trace.query().run(op, cache=False, backend=backend, **kw)
    assert_equivalent(op, ref, res, context=f"{op}/{backend}")


@pytest.mark.parametrize("backend",
                         registry.list_backends("time_profile"))
def test_time_profile_straddling_bins_conserves_mass(backend):
    """A call spanning the whole window plus calls straddling interior bin
    edges: every backend must spread each call's metric over its exact
    span, so per-function bin sums equal the call durations."""
    tb = TraceBuilder()
    tb.call(0.0, 9.0, "whole", 0)                  # spans all bins
    t = tb.call(1.4, 2.2, "straddle", 1)           # crosses 3.0 edge
    tb.call(t + 0.1, 5.0, "straddle", 1)           # crosses 6.0 edge
    tb.call(8.999, 0.001, "tail", 2)               # ends exactly at t1
    tr = tb.trace()
    prof = tr.time_profile(num_bins=3, backend=backend)
    sums = {c: float(np.asarray(prof[c]).sum()) for c in prof.columns
            if c not in ("bin_start", "bin_end")}
    assert sums["whole"] == pytest.approx(9.0, rel=1e-5)
    assert sums["straddle"] == pytest.approx(7.2, rel=1e-5)
    assert sums["tail"] == pytest.approx(0.001, rel=1e-3)
    # no call straddles t0/t1 themselves: total mass is conserved
    assert sum(sums.values()) == pytest.approx(16.201, rel=1e-5)


@pytest.mark.parametrize("op,backend", PAIRS)
def test_empty_selection(edge_trace, op, backend):
    """A filter that matches nothing must produce an empty (not crashed,
    not NaN) result on every backend."""
    kw = OP_KWARGS[op]
    res = (edge_trace.query()
           .filter(Filter(NAME, "==", "no_such_function"))
           .run(op, cache=False, backend=backend, **kw))
    if op == "comm_matrix":
        assert np.asarray(res).sum() == 0
    elif op == "message_histogram":
        assert np.asarray(res[0]).sum() == 0
    else:
        assert len(res) == 0


@pytest.mark.parametrize("backend",
                         registry.list_backends("time_profile"))
def test_time_profile_single_instant_trace(backend):
    """Degenerate trace whose events share one timestamp: no NaNs, no
    crash (regression for the zero-bin-width guard in the pallas
    backend)."""
    tb = TraceBuilder()
    tb.enter(5.0, "f", 0)
    tb.leave(5.0, "f", 0)
    tr = tb.trace()
    prof = tr.time_profile(num_bins=4, backend=backend)
    for c in prof.columns:
        assert np.isfinite(np.asarray(prof[c], float)).all(), c


# ---------------------------------------------------------------------------
# path identity: eager / streaming / parallel run-units
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def path_files(tmp_path_factory):
    d = tmp_path_factory.mktemp("backends")
    tr, _gt = tg.pathology_trace("straggler", nprocs=4, iters=24,
                                 magnitude=2.0, seed=11)
    pack = str(d / "t.pack")
    jsonl = str(d / "t.jsonl")
    write_pack(tr, pack)
    write_jsonl(tr, jsonl)
    return pack, jsonl


@pytest.mark.parametrize("op,backend", ACCEL)
def test_digest_identical_across_paths(path_files, op, backend):
    """The accelerator contract: identical record multiset + canonical
    order + one kernel invocation ⇒ bit-identical results on every path."""
    pack, jsonl = path_files
    kw = OP_KWARGS[op]
    eager = Trace.open(pack).query().run(op, cache=False, backend=backend,
                                         **kw)
    stream = (Trace.open(pack, streaming=True, chunk_rows=97)
              .query().run(op, cache=False, backend=backend, **kw))
    spec = registry.get_op(op)
    agg = spec.streaming(backend=backend, **kw)
    par = execute_parallel(
        StreamingTrace(jsonl, chunk_rows=61, processes=2), (), spec,
        (), dict(kw, backend=backend), agg, n_units=4, use_pool=False)
    d0 = result_digest(eager)
    assert result_digest(stream) == d0, f"{op}/{backend}: streaming"
    assert result_digest(par) == d0, f"{op}/{backend}: parallel"


def test_streaming_time_profile_pallas_no_longer_raises(path_files):
    """Regression: streaming time_profile used to hard-raise for any
    non-numpy backend instead of consulting the backend table."""
    pack, _ = path_files
    st = Trace.open(pack, streaming=True, chunk_rows=97)
    eager = Trace.open(pack).time_profile(num_bins=16, backend="pallas")
    stream = st.time_profile(num_bins=16, backend="pallas")
    assert result_digest(eager) == result_digest(stream)
