"""Cross-reader conformance suite.

One golden trace (tracegen, messages included) is serialized in every
registered writable format — jsonl, csv, chrome, otf2j (single-file and
directory archive) — and every route back into memory must produce the
same canonical event table:

* the format's registered reader,
* ``Trace.open(path, format="auto")`` (content sniffing),
* the format's chunked reader (several chunk sizes), which is the
  out-of-core streaming path.

Canonicalization sorts by (process, thread, timestamp) and normalizes the
optional columns (thread / message triplet) so formats that always emit
them compare equal to formats that emit them on demand.
"""

import os

import numpy as np
import pytest

from repro import tracegen
from repro.core.constants import (ET, MSG_SIZE, NAME, PARTNER, PROC, TAG,
                                  THREAD, TS)
from repro.core.frame import EventFrame, concat
from repro.core.registry import get_reader, list_readers, sniff_format
from repro.core.trace import Trace
from repro.readers.chrome import write_chrome
from repro.readers.csvreader import write_csv
from repro.readers.jsonl import write_jsonl
from repro.readers.otf2j import write_otf2_json
from repro.readers.pack import write_pack

WRITERS = {
    "jsonl": ("golden.jsonl", write_jsonl),
    "csv": ("golden.csv", write_csv),
    "chrome": ("golden.json", write_chrome),
    "otf2j": ("golden.otf2.json", write_otf2_json),
    "pack": ("golden.pack", write_pack),
}

ALL_FMTS = ["jsonl", "csv", "chrome", "otf2j", "otf2j-dir", "pack"]


@pytest.fixture(scope="module")
def golden():
    # gol: messages on every iteration, several processes, distinct enough
    # timestamps that integer-ns truncation cannot create ordering ties
    return tracegen.gol(nprocs=3, iters=4, seed=7)


@pytest.fixture(scope="module")
def written(golden, tmp_path_factory):
    d = tmp_path_factory.mktemp("conformance")
    paths = {}
    for fmt, (fname, writer) in WRITERS.items():
        p = str(d / fname)
        writer(golden, p)
        paths[fmt] = p
    arch = str(d / "golden_archive")
    os.makedirs(arch, exist_ok=True)
    write_otf2_json(golden, arch, split_locations=True)
    paths["otf2j-dir"] = arch
    return paths


def canonical(trace_or_frame) -> EventFrame:
    """The uniform event table every format must round-trip to."""
    ev = getattr(trace_or_frame, "events", trace_or_frame)
    n = len(ev)
    # the data model has three event types; generators use richer instant
    # subtypes (MpiSend/MpiRecv) that every on-disk format renders as a
    # plain instant — normalize before comparing
    et = [s if s in ("Enter", "Leave") else "Instant"
          for s in map(str, ev[ET])]
    out = EventFrame({
        TS: np.asarray(ev[TS], np.int64),
        ET: np.asarray(et, dtype=object),
        NAME: np.asarray(list(map(str, ev[NAME])), dtype=object),
        PROC: np.asarray(ev[PROC], np.int64),
        THREAD: (np.asarray(ev[THREAD], np.int64) if THREAD in ev
                 else np.zeros(n, np.int64)),
        MSG_SIZE: (np.nan_to_num(np.asarray(ev[MSG_SIZE], np.float64),
                                 nan=-1.0)
                   if MSG_SIZE in ev else np.full(n, -1.0)),
        PARTNER: (np.asarray(ev[PARTNER], np.int64) if PARTNER in ev
                  else np.full(n, -1, np.int64)),
        TAG: (np.asarray(ev[TAG], np.int64) if TAG in ev
              else np.zeros(n, np.int64)),
    })
    return out.sort_by([PROC, THREAD, TS])


def assert_canonical_equal(a: EventFrame, b: EventFrame, context: str):
    assert len(a) == len(b), f"{context}: {len(a)} vs {len(b)} events"
    for c in a.columns:
        va, vb = a[c], b[c]
        if va.dtype.kind in "UO":
            assert list(va) == list(vb), f"{context}: column {c}"
        else:
            np.testing.assert_array_equal(va, vb,
                                          err_msg=f"{context}: column {c}")


@pytest.fixture(scope="module")
def golden_canonical(golden):
    return canonical(golden)


def _fmt_name(fmt: str) -> str:
    return "otf2j" if fmt.startswith("otf2j") else fmt


@pytest.mark.parametrize("fmt", ALL_FMTS)
def test_reader_roundtrip(fmt, written, golden_canonical):
    spec = get_reader(_fmt_name(fmt))
    got = canonical(spec.read(written[fmt]))
    assert_canonical_equal(golden_canonical, got, f"{fmt} whole-file")


@pytest.mark.parametrize("fmt", ALL_FMTS)
def test_auto_sniff_roundtrip(fmt, written, golden_canonical):
    assert sniff_format(written[fmt]) == _fmt_name(fmt)
    got = canonical(Trace.open(written[fmt], format="auto"))
    assert_canonical_equal(golden_canonical, got, f"{fmt} auto")


@pytest.mark.parametrize("fmt", ALL_FMTS)
@pytest.mark.parametrize("chunk_rows", [13, 101])
def test_chunked_roundtrip(fmt, chunk_rows, written, golden_canonical):
    spec = get_reader(_fmt_name(fmt))
    assert spec.iter_chunks is not None, f"{fmt} has no chunked reader"
    chunks = list(spec.iter_chunks(written[fmt], chunk_rows, None))
    assert all(len(c) > 0 for c in chunks)
    got = canonical(concat(chunks))
    assert_canonical_equal(golden_canonical, got,
                           f"{fmt} chunked({chunk_rows})")


@pytest.mark.parametrize("fmt", ALL_FMTS)
def test_streaming_handle_matches_memory(fmt, written):
    """Trace.open(streaming=True) over every format: the streamed flat
    profile equals the in-memory one (string-level, values exact)."""
    mem = Trace.open(written[fmt]).flat_profile()
    st = Trace.open(written[fmt], streaming=True,
                    chunk_rows=61).flat_profile()
    assert list(map(str, mem[NAME])) == list(map(str, st[NAME]))
    np.testing.assert_array_equal(np.asarray(mem["time.exc"]),
                                  np.asarray(st["time.exc"]))
    np.testing.assert_array_equal(np.asarray(mem["count"]),
                                  np.asarray(st["count"]))


@pytest.mark.parametrize("fmt", ALL_FMTS)
def test_parallel_unit_roundtrip(fmt, written, golden_canonical):
    """Formats with a registered unit planner: the frames of every planned
    work unit, concatenated in unit order, must partition the golden events
    exactly (ByteSpan line ownership, pack RowSpans, per-proc ProcSpans)."""
    from repro.core.constants import DERIVED_COLUMNS
    from repro.core.executor import _unit_frames
    name = _fmt_name(fmt)
    spec = get_reader(name)
    if spec.plan_units is None:
        pytest.skip(f"{fmt} has no unit planner")
    units = spec.plan_units(written[fmt], 3)
    if not units or len(units) <= 1:
        pytest.skip(f"{fmt} input too small to split")
    frames = [f.drop(*DERIVED_COLUMNS) for u in units
              for f in _unit_frames(u, name, 37, None, {})]
    got = canonical(concat(frames))
    assert_canonical_equal(golden_canonical, got, f"{fmt} units")


def test_every_registered_reader_covered():
    """The suite must grow with the registry: every registered reader with
    a sniffer is exercised here (hlo is text-blob input, no writer)."""
    import repro.readers  # noqa: F401
    covered = {_fmt_name(f) for f in WRITERS}
    for name in list_readers():
        if name in ("hlo",):
            continue
        assert name in covered, (
            f"reader {name!r} registered but not in the conformance suite; "
            f"add a writer + WRITERS entry")


# ---------------------------------------------------------------------------
# diagnostics conformance: detector output is byte-identical whichever
# format the pathology-bearing trace was serialized in, and whichever
# execution path (whole-file eager / chunked streaming) ran it
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pathology_written(tmp_path_factory):
    """A straggler-injected golden trace in every writable format."""
    from repro.tracegen import pathology_trace
    tr, gt = pathology_trace("straggler", nprocs=3, iters=12,
                             magnitude=2.0, seed=7)
    d = tmp_path_factory.mktemp("patho_conformance")
    paths = {}
    for fmt, (fname, writer) in WRITERS.items():
        p = str(d / fname)
        writer(tr, p)
        paths[fmt] = p
    arch = str(d / "patho_archive")
    os.makedirs(arch, exist_ok=True)
    write_otf2_json(tr, arch, split_locations=True)
    paths["otf2j-dir"] = arch
    return tr, gt, paths


@pytest.mark.parametrize("fmt", ALL_FMTS)
def test_detector_identical_across_formats(fmt, pathology_written):
    """diagnose() digests agree between the in-memory golden and every
    on-disk format, eager and chunked/streaming alike."""
    from repro.serving.protocol import result_digest
    tr, gt, paths = pathology_written
    want = result_digest(tr.query().run("diagnose", cache=False))
    eager = Trace.open(paths[fmt], format="auto")
    assert result_digest(
        eager.query().run("diagnose", cache=False)) == want, (
        f"{fmt} eager diagnose diverges")
    for chunk_rows in (47, 301):
        st = Trace.open(paths[fmt], format="auto", streaming=True,
                        chunk_rows=chunk_rows)
        assert result_digest(
            st.query().run("diagnose", cache=False)) == want, (
            f"{fmt} streaming({chunk_rows}) diagnose diverges")


def test_detector_recovers_pathology_from_any_format(pathology_written):
    """The injected culprit survives serialization: top-1 recovery holds
    when the detector reads the trace back from disk."""
    tr, gt, paths = pathology_written
    for fmt in ALL_FMTS:
        f = Trace.open(paths[fmt], format="auto").query().run(
            "stragglers", cache=False)
        assert len(f) >= 1, fmt
        assert int(f["process"][0]) == gt.process, fmt


# ---------------------------------------------------------------------------
# corruption matrix: every reader x injected damage x error policy
# ---------------------------------------------------------------------------
#
# Closed loop with ``repro.testing.faults``: each registered reader is fed
# the golden trace damaged in a precisely known way, under both error
# policies.  The contract is uniform:
#
# * ``on_error="strict"`` either fails loudly (a TraceReadError naming
#   the file) or parses cleanly — it never half-returns;
# * the lenient policy (``salvage`` for pack, ``skip`` for text formats)
#   NEVER raises on body damage: it returns the survivors plus an ingest
#   report accounting for every dropped record/byte;
# * when both policies succeed, they agree bit-for-bit;
# * lenient eager and lenient streaming agree bit-for-bit (deterministic
#   skip: same survivors regardless of execution strategy);
# * zero-byte inputs are an "empty file" TraceReadError under EVERY
#   policy — an empty trace is indistinguishable from total data loss,
#   so no policy invents an empty success.

from repro.core.errors import TraceReadError
from repro.testing.faults import bit_flip, garbage_append, truncate_at

MATRIX_FMTS = ["jsonl", "csv", "chrome", "otf2j", "pack"]
LENIENT = {"pack": "salvage"}  # every other reader spells it "skip"

CORRUPTIONS = {
    "trunc25": lambda s, d: truncate_at(s, d, frac=0.25),
    "trunc75": lambda s, d: truncate_at(s, d, frac=0.75),
    "trunc99": lambda s, d: truncate_at(s, d, frac=0.99),
    "bitflip": lambda s, d: bit_flip(s, d, frac=0.5, count=4, seed=13),
    "garbage": lambda s, d: garbage_append(s, d, nbytes=97, seed=13),
}


@pytest.fixture(scope="module")
def matrix_sources(golden, written, tmp_path_factory):
    """Matrix inputs: the conformance goldens, except pack is re-written
    with small chunk groups so partial damage has partial survivors."""
    d = tmp_path_factory.mktemp("matrix_src")
    paths = dict(written)
    paths["pack"] = str(d / "golden.pack")
    write_pack(golden, paths["pack"], chunk_rows=20)
    return paths


@pytest.mark.parametrize("hurt", sorted(CORRUPTIONS))
@pytest.mark.parametrize("fmt", MATRIX_FMTS)
def test_corruption_matrix(fmt, hurt, matrix_sources, golden_canonical,
                           tmp_path):
    lenient = LENIENT.get(fmt, "skip")
    dst = str(tmp_path / os.path.basename(matrix_sources[fmt]))
    CORRUPTIONS[hurt](matrix_sources[fmt], dst)

    # strict: loud failure naming the file, or a clean parse
    strict_t = None
    try:
        strict_t = Trace.open(dst, format=fmt, on_error="strict")
    except (TraceReadError, ValueError) as e:
        assert os.path.basename(dst) in str(e), (
            f"{fmt}/{hurt}: strict error does not name the file: {e}")

    # lenient: returns the survivors, or — only on TOTAL loss — fails
    # loudly naming the file; it never half-returns silently-wrong data
    try:
        t = Trace.open(dst, format=fmt, on_error=lenient)
    except TraceReadError as e:
        assert os.path.basename(dst) in str(e), (
            f"{fmt}/{hurt}: lenient error does not name the file: {e}")
        return
    assert len(t.events) <= len(golden_canonical)
    rpt = t.ingest_report().as_dict()
    assert rpt["paths"], f"{fmt}/{hurt}: ingest report is empty"

    if len(t.events) == 0:
        # total loss surfaced as an accounted-for empty trace (e.g. a
        # single-file JSON body destroyed): the report must say where the
        # bytes went, and streaming must agree it is empty
        assert not t.ingest_report().clean, (
            f"{fmt}/{hurt}: empty result with nothing accounted")
        st = Trace.open(dst, format=fmt, streaming=True, chunk_rows=61,
                        on_error=lenient).materialize()
        assert len(st.events) == 0, f"{fmt}/{hurt}: streaming not empty"
        return

    # policy coherence: when strict parsed cleanly AND lenient dropped
    # nothing, the two must agree.  (Pack strict is zero-scan by design:
    # it can "succeed" over a bit-flipped body that the CRC-verifying
    # lenient mode quarantines — the report records the divergence.)
    if strict_t is not None and t.ingest_report().clean:
        assert_canonical_equal(canonical(strict_t), canonical(t),
                               f"{fmt}/{hurt} strict-vs-lenient")

    # execution-strategy coherence: streaming skip == eager skip
    st = Trace.open(dst, format=fmt, streaming=True, chunk_rows=61,
                    on_error=lenient).materialize()
    assert_canonical_equal(canonical(st), canonical(t),
                           f"{fmt}/{hurt} eager-vs-streaming")


@pytest.mark.parametrize("fmt", MATRIX_FMTS + ["hlo"])
def test_empty_file_is_loud_under_every_policy(fmt, tmp_path):
    ext = {"jsonl": ".jsonl", "csv": ".csv", "chrome": ".json",
           "otf2j": ".otf2.json", "pack": ".pack", "hlo": ".hlo"}[fmt]
    p = str(tmp_path / ("empty" + ext))
    open(p, "w").close()
    lenient = LENIENT.get(fmt, "skip")
    for policy in ("strict", lenient):
        with pytest.raises(TraceReadError) as exc:
            Trace.open(p, format=fmt, on_error=policy)
        msg = str(exc.value)
        assert "empty file" in msg and os.path.basename(p) in msg, (
            f"{fmt}/{policy}: {msg}")


def test_empty_file_auto_sniff_names_sniffers(tmp_path):
    p = str(tmp_path / "mystery.dat")
    open(p, "w").close()
    with pytest.raises(TraceReadError) as exc:
        Trace.open(p)
    msg = str(exc.value)
    assert "empty file" in msg and "Sniffers tried" in msg
    for fmt in MATRIX_FMTS:
        assert fmt in msg


def test_archive_stream_damage_drops_only_that_location(written, tmp_path,
                                                        golden_canonical):
    """OTF2-style directory archives: damage to one location stream file
    is quarantined at location granularity; definitions damage is always
    fatal (nothing is decodable without the anchor)."""
    import shutil
    src = written["otf2j-dir"]
    arch = str(tmp_path / "arch")
    shutil.copytree(src, arch)
    loc_dir = os.path.join(arch, "locations")
    streams = sorted(os.listdir(loc_dir))
    assert len(streams) >= 2
    victim = os.path.join(loc_dir, streams[0])
    bit_flip(victim, victim, offsets=[10], seed=0)

    with pytest.raises(TraceReadError, match=os.path.basename(victim)):
        Trace.open(arch, format="otf2j", on_error="strict")

    t = Trace.open(arch, format="otf2j", on_error="skip")
    assert 0 < len(t.events) < len(golden_canonical)
    rpt = t.ingest_report()
    assert rpt.total_skipped() >= 1

    # streaming sees the identical survivors
    st = Trace.open(arch, format="otf2j", streaming=True, chunk_rows=61,
                    on_error="skip").materialize()
    assert_canonical_equal(canonical(st), canonical(t),
                           "archive eager-vs-streaming")

    # definitions.json is the unsalvageable anchor: sever it mid-JSON
    from repro.testing.faults import truncate_at
    defs = os.path.join(arch, "definitions.json")
    truncate_at(defs, defs, frac=0.5)
    for policy in ("strict", "skip"):
        with pytest.raises(TraceReadError, match="definitions"):
            Trace.open(arch, format="otf2j", on_error=policy)


def test_hlo_corruption_policies(tmp_path):
    """The HLO text reader honors the same contract: strict raises on an
    undecodable dump, skip returns an empty trace plus a report."""
    p = str(tmp_path / "broken.hlo")
    with open(p, "w") as f:
        f.write("HloModule busted\n\n%f (x: f32[2]) -> f32[2] {\n  ROOT")
    with pytest.raises((TraceReadError, ValueError), match="broken.hlo"):
        Trace.open(p, format="hlo", on_error="strict")
    t = Trace.open(p, format="hlo", on_error="skip")
    assert len(t.events) == 0
    assert t.ingest_report().total_skipped() >= 1
