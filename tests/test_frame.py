"""EventFrame unit + hypothesis property tests (the pandas-analogue core)."""

import numpy as np
import pytest

from repro.testing.hyp import given, settings, st

from repro.core.frame import Categorical, EventFrame, concat


def test_basic_columns():
    f = EventFrame({"a": np.arange(5), "s": np.array(list("xyxzy"))})
    assert len(f) == 5
    assert isinstance(f.column("s"), Categorical)
    assert list(f["s"]) == list("xyxzy")
    assert f.column("s").lookup("z") >= 0
    assert f.column("s").lookup("nope") == -1


def test_mask_take_sort():
    f = EventFrame({"a": np.array([3, 1, 2]), "s": np.array(list("cab"))})
    srt = f.sort_by("a")
    assert list(srt["a"]) == [1, 2, 3]
    assert list(srt["s"]) == ["a", "b", "c"]
    m = f.mask(np.array([True, False, True]))
    assert list(m["a"]) == [3, 2]


def test_concat_categorical_merge():
    f1 = EventFrame({"s": np.array(["a", "b"])})
    f2 = EventFrame({"s": np.array(["c", "a"])})
    c = concat([f1, f2])
    assert list(c["s"]) == ["a", "b", "c", "a"]


@st.composite
def frame_and_keys(draw):
    n = draw(st.integers(1, 200))
    keys = draw(st.lists(st.integers(0, 5), min_size=n, max_size=n))
    vals = draw(st.lists(st.floats(-1e6, 1e6, allow_nan=False),
                         min_size=n, max_size=n))
    return np.asarray(keys), np.asarray(vals)


@given(frame_and_keys())
@settings(max_examples=50, deadline=None)
def test_groupby_agg_matches_numpy(data):
    keys, vals = data
    f = EventFrame({"k": keys, "v": vals})
    out = f.groupby_agg("k", {"v": "sum"}, count_name="n")
    got = dict(zip(out["k"].tolist(), out["v"]))
    cnt = dict(zip(out["k"].tolist(), out["n"]))
    for k in np.unique(keys):
        sel = vals[keys == k]
        assert got[k] == pytest.approx(sel.sum(), rel=1e-9, abs=1e-9)
        assert cnt[k] == len(sel)


@given(frame_and_keys())
@settings(max_examples=30, deadline=None)
def test_groupby_minmax_mean(data):
    keys, vals = data
    f = EventFrame({"k": keys, "v": vals})
    out = f.groupby_agg("k", {"v": "max"})
    got = dict(zip(out["k"].tolist(), out["v"]))
    for k in np.unique(keys):
        assert got[k] == vals[keys == k].max()


@given(st.lists(st.integers(0, 1000), min_size=1, max_size=300))
@settings(max_examples=30, deadline=None)
def test_sort_is_stable_permutation(xs):
    arr = np.asarray(xs)
    f = EventFrame({"a": arr, "i": np.arange(len(arr))})
    srt = f.sort_by("a")
    assert sorted(xs) == list(srt["a"])
    # stability: equal keys keep original order
    a, i = np.asarray(srt["a"]), np.asarray(srt["i"])
    for v in np.unique(a):
        idx = i[a == v]
        assert (np.diff(idx) > 0).all()
