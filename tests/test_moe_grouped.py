"""Grouped MoE dispatch (§Perf iteration 2) semantics tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_ffn

# full-matrix jax suites: minutes, not seconds — slow tier only
pytestmark = pytest.mark.slow


def _mats(T=64, d=8, E=4, f=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    return (jax.random.normal(ks[0], (T, d), jnp.float32),
            jax.random.normal(ks[1], (d, E)) * 0.3,
            jax.random.normal(ks[2], (E, d, f)) * 0.1,
            jax.random.normal(ks[3], (E, d, f)) * 0.1,
            jax.random.normal(ks[4], (E, f, d)) * 0.1)


@pytest.mark.parametrize("groups", [2, 4, 8])
def test_grouped_dropless_equals_flat(groups):
    x, wr, wg, wu, wd = _mats()
    flat = moe_ffn(x, wr, wg, wu, wd, topk=2, dropless=True)
    grp = moe_ffn(x, wr, wg, wu, wd, topk=2, dropless=True, groups=groups)
    np.testing.assert_allclose(np.asarray(grp), np.asarray(flat), atol=1e-5)


def test_grouped_gradients_match_flat():
    x, wr, wg, wu, wd = _mats()

    def loss(x, g):
        return (moe_ffn(x, wr, wg, wu, wd, topk=2, dropless=True,
                        groups=g) ** 2).sum()

    g1 = jax.grad(loss)(x, 1)
    g4 = jax.grad(loss)(x, 4)
    np.testing.assert_allclose(np.asarray(g4), np.asarray(g1), atol=1e-5)


def test_capacity_is_per_group():
    """With per-group capacity, a hot expert in one group can't evict
    tokens of another group."""
    x, wr, wg, wu, wd = _mats(T=128)
    out = moe_ffn(x, wr, wg, wu, wd, topk=1, capacity_factor=1.0, groups=4)
    assert np.isfinite(np.asarray(out)).all()


def test_indivisible_groups_fall_back():
    x, wr, wg, wu, wd = _mats(T=63)          # 63 % 4 != 0 → groups ignored
    out = moe_ffn(x, wr, wg, wu, wd, topk=2, dropless=True, groups=4)
    flat = moe_ffn(x, wr, wg, wu, wd, topk=2, dropless=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(flat), atol=1e-5)
