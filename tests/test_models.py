"""Per-architecture smoke tests (reduced same-family configs) + serve-path
consistency: prefill+decode must reproduce full-forward logits."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models import SHAPES, build_model, input_specs

# full-matrix jax suites: minutes, not seconds — slow tier only
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B, S):
    kw = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
          "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        kw["frames"] = jax.random.normal(KEY, (B, cfg.enc_frames, cfg.d_model),
                                         jnp.float32)
    if cfg.family == "vlm":
        kw["img_embeds"] = jax.random.normal(KEY, (B, cfg.img_tokens,
                                                   cfg.d_model), jnp.float32)
    return kw


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY, jnp.float32)
    batch = _batch(cfg, 2, 32)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) < np.log(cfg.vocab) * 1.5
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0
    logits, prefix = model.forward(params, batch["tokens"],
                                   **{k: v for k, v in batch.items()
                                      if k in ("img_embeds", "frames")})
    V = cfg.padded_vocab
    assert logits.shape == (2, 32 + prefix, V)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY, jnp.float32)
    B, S, P, CACHE = 2, 24, 20, 48
    batch = _batch(cfg, B, S)
    kw = {k: v for k, v in batch.items() if k in ("img_embeds", "frames")}
    full, prefix = model.forward(params, batch["tokens"], **kw)
    cache, lg, pos = model.prefill(params, batch["tokens"][:, :P], CACHE, **kw)
    errs = [float(np.abs(lg - full[:, prefix + P - 1]).max())]
    for j in range(S - P):
        lg, cache = model.decode_step(params, cache,
                                      batch["tokens"][:, P + j:P + j + 1],
                                      pos, CACHE)
        pos = pos + 1
        errs.append(float(np.abs(lg - full[:, prefix + P + j]).max()))
    assert max(errs) < 5e-4, f"{arch}: {errs}"


def test_exact_configs_match_assignment():
    """The full configs carry the exact published dimensions."""
    c = get_config("qwen3-moe-235b-a22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (94, 4096, 64, 4)
    assert (c.n_experts, c.topk, c.moe_d_ff, c.vocab) == (128, 8, 1536, 151936)
    c = get_config("gemma3-27b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (62, 5376, 21504, 262144)
    assert c.window == 1024 and c.global_every == 6
    c = get_config("qwen1.5-110b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == \
        (80, 8192, 64, 8, 49152)
    c = get_config("hymba-1.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (32, 1600, 25, 5)
    assert c.ssm_state == 16 and c.meta_tokens == 128
    c = get_config("mamba2-130m")
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab) == (24, 768, 128, 50280)
    c = get_config("whisper-medium")
    assert (c.n_layers, c.enc_layers, c.d_model, c.d_ff, c.vocab) == \
        (24, 24, 1024, 4096, 51865)


def test_param_counts_plausible():
    """param_count() should land near the published sizes (±25%)."""
    expect = {"qwen1.5-110b": 111e9, "gemma3-27b": 27e9,
              "codeqwen1.5-7b": 7.25e9, "qwen1.5-0.5b": 0.62e9,
              "qwen2-moe-a2.7b": 14.3e9, "qwen3-moe-235b-a22b": 235e9,
              "mamba2-130m": 0.13e9, "hymba-1.5b": 1.5e9,
              "phi-3-vision-4.2b": 3.8e9, "whisper-medium": 0.76e9}
    for name, want in expect.items():
        got = get_config(name).param_count()
        assert 0.7 * want < got < 1.35 * want, (name, got, want)


def test_input_specs_cover_all_cells():
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            assert all(hasattr(s, "shape") for s in specs.values())
            if shape.kind == "train":
                assert specs["tokens"].shape == (shape.global_batch,
                                                 shape.seq_len)
