"""TraceDiff subsystem: TraceSet/SetQuery shared plans + comparison ops.

Ground truth comes from the tracegen perturbation knob: generating the same
app with and without a ``perturb`` multiplier yields a before/after pair
whose only injected difference is known.
"""

import numpy as np
import pytest

from repro import tracegen as tg
from repro.core import Filter, TraceSet, list_ops
from repro.core import structure
from repro.core.constants import EXC, NAME, PROC, TS
from repro.core.diff import align_flat_profiles, regression_report
from repro.readers import write_jsonl


# ---------------------------------------------------------------------------
# injected regressions are recovered
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("app,func", [
    ("tortuga", "computeRhs"),
    ("gol", "compute_cells()"),
    ("stencil3d", "kernel_update()"),
])
def test_regression_report_recovers_injection(app, func):
    kw = dict(nprocs=4, iters=3) if app != "stencil3d" else dict(nprocs=8, iters=3)
    before, after = tg.regression_pair(app, func=func, factor=1.6, **kw)
    rep = TraceSet([before, after]).regression_report()
    assert str(rep[NAME][0]) == func            # top-1 ranked by delta
    top = {c: rep[c][0] for c in rep.columns}
    assert top["status"] == "regressed"
    assert top["delta"] > 0
    assert top["delta_rel"] == pytest.approx(0.6, rel=1e-9)  # exact knob


def test_regression_pair_identical_elsewhere():
    """The pair differs *only* in the perturbed function's own durations."""
    before, after = tg.regression_pair("tortuga", func="computeRhs",
                                       factor=2.0, nprocs=4, iters=2)
    rep = regression_report([before, after])
    byname = {str(n): (d, s) for n, d, s in
              zip(rep[NAME], rep["delta"], rep["status"])}
    # compute functions other than the injected one keep their durations
    # (clock shifts only perturb float64 rounding, sub-ns); waits downstream
    # of the shifted clocks are the only real movers
    for fn in ("gradC2C", "setGhostCvsInterfaces", "endGhostCvsInterfaces"):
        assert abs(byname[fn][0]) < 1e-6        # < one millionth of a ns
        assert byname[fn][1] == "stable"


def test_improvement_factor_below_one():
    before, after = tg.regression_pair("gol", func="compute_cells()",
                                       factor=0.5, nprocs=4, iters=3)
    rep = regression_report([before, after])
    byname = {str(n): s for n, s in zip(rep[NAME], rep["status"])}
    assert byname["compute_cells()"] == "improved"


# ---------------------------------------------------------------------------
# delta profiles: antisymmetry + name alignment
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["absolute", "normalized"])
def test_diff_flat_profile_antisymmetric(mode):
    a = tg.tortuga(nprocs=4, iters=2, seed=0)
    b = tg.tortuga(nprocs=4, iters=2, seed=1)
    ab = TraceSet([a, b]).diff_flat_profile(mode=mode)
    ba = TraceSet([b, a]).diff_flat_profile(mode=mode)
    # rows align (same |delta| ranking, same name tie-breaks)...
    assert list(ab[NAME]) == list(ba[NAME])
    da = np.asarray(ab[[c for c in ab.columns if c.startswith("delta|")][0]])
    db = np.asarray(ba[[c for c in ba.columns if c.startswith("delta|")][0]])
    # ...and diff(a,b) == -diff(b,a)
    np.testing.assert_allclose(da, -db, rtol=0, atol=0)


def test_name_alignment_functions_in_one_run_only():
    a = tg.tortuga(nprocs=4, iters=2)
    b = tg.tortuga(nprocs=4, iters=2)
    # drop gradC2C from the "after" run entirely: it vanished
    b_small = b.filter(Filter(NAME, "not-in", ["gradC2C"]))
    b_small.label = "after"
    a.label = "before"
    rep = regression_report([a, b_small])
    byname = {str(n): s for n, s in zip(rep[NAME], rep["status"])}
    assert byname["gradC2C"] == "vanished"
    # and the reverse direction flags it as new
    rep2 = regression_report([b_small, a])
    byname2 = {str(n): (s, r) for n, s, r in
               zip(rep2[NAME], rep2["status"], rep2["delta_rel"])}
    assert byname2["gradC2C"][0] == "new"
    assert np.isinf(byname2["gradC2C"][1])
    # the aligned profile zero-fills the missing run, keeps the name
    labels, names, mat, present = align_flat_profiles([a, b_small])
    j = names.index("gradC2C")
    assert present[0, j] and not present[1, j]
    assert mat[1, j] == 0.0 and mat[0, j] > 0


def test_diff_load_imbalance_pair():
    # skew (not uniform slowdown) changes max/mean: gol puts extra work on
    # process 0, so raising that knob raises compute_cells' imbalance
    balanced = tg.gol(nprocs=8, iters=3, imbalance=0.05)
    skewed = tg.gol(nprocs=8, iters=3, imbalance=0.8)
    d = TraceSet([balanced, skewed]).diff_load_imbalance()
    byname = {str(n): v for n, v in zip(d[NAME], d["delta"])}
    assert byname["compute_cells()"] > 0.05
    # the skewed compute and the waits it induces top the ranking
    assert "compute_cells()" in set(map(str, d[NAME][:2]))
    # deltas sorted descending
    dd = np.asarray(d["delta"], np.float64)
    assert np.all(np.diff(dd) <= 1e-12)


def test_diff_time_profile_localizes_change():
    before, after = tg.regression_pair("tortuga", func="computeRhs",
                                       factor=1.7, nprocs=4, iters=3)
    d = TraceSet([before, after]).diff_time_profile(num_bins=16)
    assert list(d["bin"]) == list(range(16))
    # the perturbed function carries the largest total |delta| → first column
    funcs = [c for c in d.columns if c not in ("bin", "bin_frac")]
    assert funcs[0] == "computeRhs"
    assert np.asarray(d["computeRhs"]).sum() > 0


# ---------------------------------------------------------------------------
# scaling series
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("app,sizes", [
    ("gol", (2, 4, 8)),
    ("stencil3d", (8, 16, 32)),
])
def test_scaling_analysis_monotone(app, sizes):
    gen = getattr(tg, app)
    runs = [gen(nprocs=n, iters=2) for n in sizes]
    s = TraceSet(runs).scaling_analysis()
    nprocs = np.asarray(s["num_processes"], np.int64)
    assert list(nprocs) == sorted(sizes)        # ordered by process count
    # per-process work is constant in these apps, so total summed exclusive
    # time grows monotonically with the process count
    tot = np.asarray(s[f"{EXC}.total"], np.float64)
    assert np.all(np.diff(tot) > 0)
    # baseline row is its own reference
    assert s["speedup"][0] == pytest.approx(1.0)
    assert s["efficiency"][0] == pytest.approx(1.0)


def test_scaling_analysis_weak_vs_strong():
    runs = [tg.tortuga(nprocs=n, iters=2) for n in (4, 8)]
    strong = TraceSet(runs).scaling_analysis(mode="strong")
    weak = TraceSet(runs).scaling_analysis(mode="weak")
    # same speedups, different efficiency normalization
    np.testing.assert_allclose(np.asarray(strong["speedup"]),
                               np.asarray(weak["speedup"]))
    assert strong["efficiency"][1] == pytest.approx(
        weak["efficiency"][1] / 2.0)
    with pytest.raises(ValueError):
        TraceSet(runs).scaling_analysis(mode="nope")


# ---------------------------------------------------------------------------
# TraceSet / SetQuery mechanics
# ---------------------------------------------------------------------------

def test_one_plan_over_three_traces_structure_once(monkeypatch):
    """One shared plan over >= 3 traces; event matching runs exactly once
    per member even across two terminal comparison ops."""
    traces = [tg.tortuga(nprocs=4, iters=2, seed=s) for s in range(3)]
    calls = {"n": 0}
    orig = structure.match_events

    def counting(ev):
        calls["n"] += 1
        return orig(ev)

    monkeypatch.setattr(structure, "match_events", counting)
    ts = TraceSet(traces)
    q = (ts.query()
           .filter(Filter(NAME, "not-in", ["MPI_Isend"]))
           .restrict_processes(range(3)))
    d = q.diff_flat_profile()            # terminal #1: materializes members
    rep = q.regression_report()          # terminal #2: reuses them
    assert calls["n"] == 3               # once per member, not per op
    assert len([c for c in d.columns if c.startswith("delta|")]) == 2
    assert len(rep) > 0
    # restriction applied to every member
    for t in q.collect():
        assert set(np.asarray(t.events[PROC]).tolist()) <= {0, 1, 2}


def test_set_query_matches_manual_per_trace_chain():
    a = tg.gol(nprocs=4, iters=3, seed=0)
    b = tg.gol(nprocs=4, iters=3, seed=1)
    ts_all = np.asarray(a.events[TS], np.float64)
    lo, hi = np.percentile(ts_all, 20), np.percentile(ts_all, 80)
    via_set = (TraceSet([a, b]).query().slice_time(lo, hi)
               .diff_flat_profile())
    manual = regression_report(
        [a.slice_time(lo, hi), b.slice_time(lo, hi)])
    # same aligned name set either way
    assert set(map(str, via_set[NAME])) == set(map(str, manual[NAME]))


def test_single_trace_op_maps_over_set():
    ts = TraceSet([tg.gol(nprocs=2, iters=2, seed=s) for s in range(3)])
    profs = ts.query().flat_profile()
    assert isinstance(profs, list) and len(profs) == 3
    ids = ts.idle_time()
    assert len(ids) == 3


def test_set_ops_rejected_on_single_trace_query():
    t = tg.gol(nprocs=2, iters=1)
    with pytest.raises(ValueError, match="TraceSet"):
        t.query().run("regression_report")
    with pytest.raises(ValueError, match="at least 2"):
        TraceSet([t]).regression_report()
    with pytest.raises(ValueError):
        TraceSet([])
    with pytest.raises(AttributeError):
        TraceSet([t]).no_such_op()


def test_traceset_open_sniffs_and_labels(tmp_path):
    traces = [tg.gol(nprocs=2, iters=2, seed=s) for s in range(3)]
    paths = []
    for i, t in enumerate(traces):
        p = str(tmp_path / f"run{i}.jsonl")
        write_jsonl(t, p)
        paths.append(p)
    ts = TraceSet.open(paths, labels=["r0", "r1", "r2"])
    assert ts.labels == ["r0", "r1", "r2"]
    assert [len(t) for t in ts] == [len(t) for t in traces]
    d = ts.diff_flat_profile()
    assert any(c == f"{EXC}|r1" for c in d.columns)


def test_parallel_preparation_matches_serial(tmp_path):
    before, after = tg.regression_pair("gol", func="compute_cells()",
                                       factor=1.5, nprocs=4, iters=3)
    ts = TraceSet([before, after])
    serial = ts.query().run("regression_report")
    par = ts.query().run("regression_report", processes=2)
    assert list(serial[NAME]) == list(par[NAME])
    np.testing.assert_allclose(np.asarray(serial["delta"]),
                               np.asarray(par["delta"]))


def test_multirun_analysis_still_matches_diff_alignment():
    """Trace.multirun_analysis is now a thin wrapper over the TraceDiff
    alignment — same rows/columns contract as the seed implementation."""
    from repro.core.trace import Trace
    traces = [tg.tortuga(nprocs=n, iters=2) for n in (4, 8)]
    df = Trace.multirun_analysis(traces, top_n=6)
    assert df.columns[0] == "Run"
    assert "computeRhs" in df.columns
    labels, names, mat, _ = align_flat_profiles(traces, top_n=6)
    np.testing.assert_allclose(np.asarray(df["computeRhs"]),
                               mat[:, names.index("computeRhs")])


def test_set_ops_registered():
    have = set(list_ops())
    assert {"diff_flat_profile", "diff_time_profile", "scaling_analysis",
            "diff_load_imbalance", "regression_report"} <= have


# ---------------------------------------------------------------------------
# review hardening: run indices, totals, caching, batched-open input shapes
# ---------------------------------------------------------------------------

def test_out_of_range_run_index_is_loud():
    a, b = tg.gol(nprocs=2, iters=1, seed=0), tg.gol(nprocs=2, iters=1, seed=1)
    with pytest.raises(IndexError):
        regression_report([a, b], baseline=-3)
    with pytest.raises(IndexError):
        regression_report([a, b], target=2)
    with pytest.raises(IndexError):
        TraceSet([a, b]).diff_flat_profile(baseline=5)


def test_scaling_total_not_truncated_by_top_n():
    runs = [tg.tortuga(nprocs=n, iters=2) for n in (4, 8)]
    s1 = TraceSet(runs).scaling_analysis(top_n=1)
    s8 = TraceSet(runs).scaling_analysis(top_n=None)
    # the .total column sums ALL functions regardless of column truncation
    np.testing.assert_allclose(np.asarray(s1[f"{EXC}.total"]),
                               np.asarray(s8[f"{EXC}.total"]))


def test_chained_set_ops_profile_each_member_once(monkeypatch):
    from repro.core import ops_summary
    calls = {"n": 0}
    orig = ops_summary.flat_profile

    def counting(trace, *a, **kw):
        calls["n"] += 1
        return orig(trace, *a, **kw)

    monkeypatch.setattr(ops_summary, "flat_profile", counting)
    traces = [tg.gol(nprocs=2, iters=2, seed=s) for s in range(2)]
    q = TraceSet(traces).query()
    q.regression_report()
    q.diff_flat_profile()     # second op over the same prepared members
    assert calls["n"] == 2    # one aggregation pass per member, not per op


def test_open_many_single_path_string(tmp_path):
    from repro.readers import open_many
    t = tg.gol(nprocs=2, iters=1)
    p = str(tmp_path / "one.jsonl")
    write_jsonl(t, p)
    out = open_many(p)        # bare string, not iterated char-by-char
    assert len(out) == 1 and len(out[0]) == len(t)


def test_jsonl_sniff_survives_truncated_head(tmp_path):
    # first event line longer than the 8KB sniff window
    t = tg.gol(nprocs=2, iters=1)
    p = str(tmp_path / "fat.jsonl")
    write_jsonl(t, p)
    with open(p) as f:
        lines = f.read().splitlines()
    import json as _json
    fat = _json.loads(lines[0])
    fat["blob"] = "x" * 10000
    with open(p, "w") as f:
        f.write(_json.dumps(fat) + "\n")
        f.write("\n".join(lines[1:]) + "\n")
    from repro.core.trace import Trace
    assert len(Trace.open(p)) == len(t)   # sniffed as jsonl despite truncation


def test_labels_do_not_mutate_caller_traces():
    a = tg.gol(nprocs=2, iters=1, seed=0)
    b = tg.gol(nprocs=2, iters=1, seed=1)
    a.label = "prod-run"
    ts = TraceSet([a, b], labels=["base", "exp"])
    assert ts.labels == ["base", "exp"]
    assert a.label == "prod-run"          # caller's object untouched
    # clones share the events frame, so structure caches once for both
    ts[0]._ensure_structure()
    assert a._structured or "time.exc" in a.events  # columns landed in place


def test_processes_honored_on_cached_members(monkeypatch):
    from repro.core.diff import SetQuery
    calls = {"n": 0}
    orig = SetQuery._pool_prepare

    def counting(traces, steps, ns, nm, processes):
        calls["n"] += 1
        return orig(traces, steps, ns, nm, processes)

    monkeypatch.setattr(SetQuery, "_pool_prepare", staticmethod(counting))
    traces = [tg.gol(nprocs=2, iters=1, seed=s) for s in range(2)]
    q = TraceSet(traces).query().restrict_processes([0, 1])
    q.collect()                             # caches members, no prereqs yet
    q.run("diff_flat_profile", processes=2)  # pool must still be used
    assert calls["n"] == 1
