"""Math-level model tests: chunked algorithms vs references (hypothesis
shape sweeps) and MoE dispatch semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.testing.hyp import given, settings, st

from repro.models.attention import (chunked_attention, local_attention,
                                    reference_attention)
from repro.models.moe import moe_ffn
from repro.models.ssm import (causal_conv1d, conv1d_step, ssd_chunked,
                              ssd_reference, ssd_step)

# full-matrix jax suites: minutes, not seconds — slow tier only
pytestmark = pytest.mark.slow


@given(st.integers(1, 2), st.integers(8, 200), st.sampled_from([1, 2, 4]),
       st.sampled_from([16, 32]), st.sampled_from([16, 33, 64]))
@settings(max_examples=20, deadline=None)
def test_chunked_attention_property(B, S, KVH, D, chunk):
    H = KVH * 2
    ks = jax.random.split(jax.random.PRNGKey(S), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KVH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KVH, D), jnp.float32)
    got = chunked_attention(q, k, v, chunk=chunk)
    want = reference_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


@given(st.integers(8, 150), st.sampled_from([4, 16, 40]),
       st.sampled_from([8, 16, 32]))
@settings(max_examples=20, deadline=None)
def test_local_attention_property(S, window, chunk):
    B, H, KVH, D = 1, 2, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(S * 7 + window), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KVH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KVH, D), jnp.float32)
    got = local_attention(q, k, v, window=window, chunk=chunk)
    want = reference_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


@given(st.integers(4, 130), st.sampled_from([8, 32, 64]))
@settings(max_examples=15, deadline=None)
def test_ssd_chunked_property(S, chunk):
    B, H, P, N = 2, 3, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(S), 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    yr, hr = ssd_reference(x, dt, A, Bm, Cm)
    yc, hc = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr), atol=2e-4)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hr), atol=2e-4)


def test_ssd_step_matches_sequence():
    """Recurrent decode steps must reproduce the parallel form exactly."""
    B, S, H, P, N = 1, 20, 2, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    yr, _ = ssd_reference(x, dt, A, Bm, Cm)
    h = jnp.zeros((B, H, P, N))
    outs = []
    for t in range(S):
        h, y = ssd_step(h, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(yr), atol=2e-4)


def test_conv1d_step_matches_full():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 12, 6))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 6)) * 0.3
    b = jnp.zeros(6)
    full = causal_conv1d(x, w, b)
    st_ = jnp.zeros((2, 3, 6))
    for t in range(12):
        st_, yt = conv1d_step(st_, x[:, t], w, b)
        np.testing.assert_allclose(np.asarray(yt), np.asarray(full[:, t]),
                                   atol=1e-5)


def test_moe_dropless_matches_dense_oracle():
    T, d, E, f, k = 48, 16, 8, 32, 2
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wr = jax.random.normal(ks[1], (d, E)) * 0.1
    wg = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wu = jax.random.normal(ks[3], (E, d, f)) * 0.1
    wd = jax.random.normal(ks[4], (E, f, d)) * 0.1
    y = moe_ffn(x, wr, wg, wu, wd, topk=k, dropless=True)
    logits = x @ wr
    g, i = jax.lax.top_k(logits, k)
    g = jax.nn.softmax(g, axis=-1)
    want = np.zeros((T, d), np.float32)
    for t in range(T):
        for j in range(k):
            e = int(i[t, j])
            h = np.asarray(jax.nn.silu(x[t] @ wg[e]) * (x[t] @ wu[e]))
            want[t] += float(g[t, j]) * (h @ np.asarray(wd[e]))
    np.testing.assert_allclose(np.asarray(y), want, atol=1e-4)


def test_moe_capacity_drops_monotone():
    """Tighter capacity ⇒ outputs shrink toward zero (dropped tokens)."""
    T, d, E, f, k = 256, 8, 4, 16, 2
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wr = jax.random.normal(ks[1], (d, E)) * 0.5
    wg = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wu = jax.random.normal(ks[3], (E, d, f)) * 0.1
    wd = jax.random.normal(ks[4], (E, f, d)) * 0.1
    full = moe_ffn(x, wr, wg, wu, wd, topk=k, dropless=True)
    tight = moe_ffn(x, wr, wg, wu, wd, topk=k, capacity_factor=0.25)
    n_full = float(jnp.sum(jnp.any(full != 0, -1)))
    n_tight = float(jnp.sum(jnp.any(tight != 0, -1)))
    assert n_tight < n_full
