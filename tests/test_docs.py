"""Docs drift check: docs/api.md must match the live registries.

This wires ``tools/gen_api_docs.py --check`` into the tier-1 verify flow —
registering/changing an op or reader without regenerating the API page
fails here with the regeneration command in the message.
"""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_api_docs_in_sync_with_registry():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "gen_api_docs.py"),
         "--check"],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert proc.returncode == 0, (
        "docs/api.md is out of date with the op/reader registry.\n"
        "Regenerate with: PYTHONPATH=src python tools/gen_api_docs.py\n"
        f"stderr: {proc.stderr}")


def test_readme_and_guides_exist():
    for rel in ("README.md", "docs/api.md", "docs/comparing-traces.md"):
        path = os.path.join(REPO, rel)
        assert os.path.exists(path), f"{rel} missing"
        with open(path) as f:
            assert len(f.read()) > 500, f"{rel} suspiciously empty"
