"""Enter/leave matching + structure derivation: unit + property tests.

Property: for any randomly generated *balanced* call forest per process, the
vectorized matcher recovers exactly the generator's nesting.
"""

import numpy as np
import pytest

from repro.testing.hyp import given, settings, st

from repro.core.constants import ET, NAME, PROC, TS
from repro.core.frame import EventFrame
from repro.core.structure import compute_inc_exc, compute_parents, match_events


@st.composite
def call_forest(draw):
    """Generate a random call forest; returns events + true matching."""
    nprocs = draw(st.integers(1, 3))
    ts_list, et_list, name_list, proc_list = [], [], [], []
    true_pairs = []

    def gen(proc, t, depth, budget):
        while budget[0] > 0 and draw(st.booleans()):
            budget[0] -= 1
            name = draw(st.sampled_from(["f", "g", "h"]))
            enter_idx = len(ts_list)
            ts_list.append(t)
            et_list.append("Enter")
            name_list.append(name)
            proc_list.append(proc)
            t += 1
            if depth < 4:
                t = gen(proc, t, depth + 1, budget)
            leave_idx = len(ts_list)
            ts_list.append(t)
            et_list.append("Leave")
            name_list.append(name)
            proc_list.append(proc)
            true_pairs.append((enter_idx, leave_idx))
            t += 1
        return t

    for p in range(nprocs):
        gen(p, 0, 0, [draw(st.integers(0, 12))])
    ev = EventFrame({
        TS: np.asarray(ts_list, np.float64),
        ET: np.asarray(et_list if et_list else ["Enter"])[: len(ts_list)],
        NAME: np.asarray(name_list if name_list else ["f"])[: len(ts_list)],
        PROC: np.asarray(proc_list, np.int64),
    }) if ts_list else None
    return ev, true_pairs


@given(call_forest())
@settings(max_examples=60, deadline=None)
def test_matching_recovers_generated_forest(data):
    ev, true_pairs = data
    if ev is None:
        return
    matching, depth, _ = match_events(ev)
    for e, l in true_pairs:
        assert matching[e] == l and matching[l] == e
    # involution + enter-before-leave
    ts = np.asarray(ev[TS])
    for i, m in enumerate(matching):
        if m >= 0:
            assert matching[m] == i
            lo, hi = min(i, m), max(i, m)
            assert ts[lo] <= ts[hi]


@given(call_forest())
@settings(max_examples=40, deadline=None)
def test_inc_exc_invariants(data):
    ev, _ = data
    if ev is None:
        return
    matching, depth, order = match_events(ev)
    parent = compute_parents(ev, matching, depth, order)
    inc, exc = compute_inc_exc(ev, matching, parent)
    ok = ~np.isnan(inc)
    # exclusive ≤ inclusive; both non-negative
    assert (inc[ok] >= -1e-9).all()
    assert (exc[ok] <= inc[ok] + 1e-9).all()
    # parent of any matched enter is an enter on the same process
    procs = np.asarray(ev[PROC])
    for i in np.nonzero(ok)[0]:
        if parent[i] >= 0:
            assert procs[parent[i]] == procs[i]


def test_unbalanced_trace_repair():
    """A truncated trace (missing leaves) must not crash or mis-match."""
    ev = EventFrame({
        TS: np.asarray([0, 1, 2, 3], np.float64),
        ET: np.asarray(["Enter", "Enter", "Leave", "Enter"]),
        NAME: np.asarray(["a", "b", "b", "c"]),
        PROC: np.zeros(4, np.int64),
    })
    matching, depth, _ = match_events(ev)
    assert matching[1] == 2 and matching[2] == 1
    assert matching[0] == -1 and matching[3] == -1
