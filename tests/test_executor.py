"""Parallel plan executor + plan-result cache tests (tentpole of PR 4).

The contract: for every registered op whose streaming aggregator declares a
cross-worker merge, multi-core execution over partitioned work units is
byte-identical to serial streaming and to in-memory eager execution —
including enter/leave pairs split across unit seams — and degradations back
to the serial path always warn with the concrete reason.  The plan cache
returns identical objects on repeat calls and never serves stale results.
"""

import json
import os
import warnings

import numpy as np
import pytest

from repro import tracegen
from repro.core import executor as ex
from repro.core import plancache, registry
from repro.core.constants import EXC, INC, NAME, PROC
from repro.core.diff import TraceSet
from repro.core.filters import Filter, time_window_filter
from repro.core.streaming import (StreamAgg, StreamingTrace,
                                  StreamingUnsupported)
from repro.core.trace import Trace
from repro.readers.jsonl import iter_lines_range, write_jsonl


def assert_frames_equal(a, b, tol=False, context=""):
    assert a.columns == b.columns, f"{context}: {a.columns} vs {b.columns}"
    for c in a.columns:
        va, vb = a[c], b[c]
        if np.asarray(va).dtype.kind in "UO":
            assert list(map(str, va)) == list(map(str, vb)), \
                f"{context}: column {c}"
        elif tol:
            np.testing.assert_allclose(np.asarray(va, float),
                                       np.asarray(vb, float),
                                       rtol=1e-9, atol=1e-6,
                                       err_msg=f"{context}: column {c}")
        else:
            np.testing.assert_array_equal(np.asarray(va), np.asarray(vb),
                                          err_msg=f"{context}: column {c}")


def run_units(path_or_paths, op, *args, n_units=4, chunk_rows=61, steps=(),
              **kwargs):
    """Partitioned execution with in-process workers: exercises unit
    planning, the deferring stitcher, and the merge — without pool cost."""
    h = StreamingTrace(path_or_paths, chunk_rows=chunk_rows, processes=2)
    spec = registry.get_op(op)
    agg = spec.streaming(*args, **kwargs)
    return ex.execute_parallel(h, tuple(steps), spec, args, kwargs, agg,
                               n_units=n_units, use_pool=False)


@pytest.fixture(autouse=True)
def _fresh_cache():
    plancache.clear()
    yield
    plancache.clear()


@pytest.fixture(scope="module")
def trace_file(tmp_path_factory):
    d = tmp_path_factory.mktemp("par")
    t = tracegen.tortuga(nprocs=4, iters=4, seed=3)
    path = str(d / "tortuga.jsonl")
    write_jsonl(t, path)
    return path


@pytest.fixture(scope="module")
def mem(trace_file):
    return Trace.open(trace_file)


# ---------------------------------------------------------------------------
# parity: every parallel-safe op, byte-identical across unit seams
# ---------------------------------------------------------------------------

# op -> (args, kwargs, comparison) — the completeness test below fails when
# an op gains a parallel merge without gaining coverage here
FRAME_EQ, FRAME_TOL, ARRAY_EQ, HIST_EQ = "frame", "frame_tol", "array", "hist"
PARALLEL_OPS = {
    "flat_profile": ((), {"metrics": [EXC, INC]}, FRAME_EQ),
    "load_imbalance": ((), {}, FRAME_EQ),
    "idle_time": ((), {}, FRAME_EQ),
    "comm_matrix": ((), {}, ARRAY_EQ),
    "comm_by_process": ((), {}, FRAME_EQ),
    "message_histogram": ((), {"bins": 7}, HIST_EQ),
    "comm_over_time": ((), {"num_bins": 16}, HIST_EQ),
    "time_profile": ((), {"num_bins": 24}, FRAME_TOL),
    # diagnostics suite: Findings / efficiency frames, exact accumulation
    "late_sender": ((), {"threshold": 0.0}, FRAME_EQ),
    "stragglers": ((), {"threshold": 0.0}, FRAME_EQ),
    "serialization": ((), {"threshold": 0.0}, FRAME_EQ),
    "imbalance_root_cause": ((), {"threshold": 0.0}, FRAME_EQ),
    "pop_efficiency": ((), {"threshold": 0.0}, FRAME_EQ),
    "efficiency_metrics": ((), {"num_windows": 12}, FRAME_EQ),
    "diagnose": ((), {}, FRAME_EQ),
}


def test_every_parallel_safe_op_is_covered():
    safe = {name for name in registry.list_ops()
            if registry.get_op(name).parallel_safe}
    assert safe == set(PARALLEL_OPS), \
        "new parallel-safe op registered without parity coverage"


@pytest.mark.parametrize("op", sorted(PARALLEL_OPS))
def test_parallel_identical_to_eager(trace_file, mem, op):
    args, kwargs, cmp = PARALLEL_OPS[op]
    a = getattr(mem, op)(*args, **kwargs)
    b = run_units(trace_file, op, *args, **kwargs)
    if cmp == FRAME_EQ:
        assert_frames_equal(a, b, context=op)
    elif cmp == FRAME_TOL:
        assert_frames_equal(a, b, tol=True, context=op)
    elif cmp == ARRAY_EQ:
        np.testing.assert_array_equal(a, b, err_msg=op)
    else:
        np.testing.assert_array_equal(a[0], b[0], err_msg=op)
        np.testing.assert_allclose(a[1], b[1], err_msg=op)


@pytest.mark.parametrize("n_units", [2, 7, 19])
def test_seam_stitching_at_any_unit_count(trace_file, mem, n_units):
    """main()/wrapper pairs span every unit seam; inc/exc must still match
    the in-memory structure pass exactly."""
    a = mem.flat_profile(metrics=[EXC, INC], per_process=True)
    b = run_units(trace_file, "flat_profile", n_units=n_units, chunk_rows=37,
                  metrics=[EXC, INC], per_process=True)
    assert_frames_equal(a, b, context=f"n_units={n_units}")


def test_parallel_with_plan_steps(trace_file, mem):
    f = (Filter(NAME, "not-in", ["MPI_Wait", "MPI_Isend"])
         & time_window_filter(0, 10**15, trim="within"))
    a = mem.query().filter(f).restrict_processes([0, 1, 3]).flat_profile()
    h = StreamingTrace(trace_file, chunk_rows=53, processes=2)
    q = h.query().filter(f).restrict_processes([0, 1, 3])
    spec = registry.get_op("flat_profile")
    b = ex.execute_parallel(h, q._steps, spec, (), {}, spec.streaming(),
                            n_units=5, use_pool=False)
    assert_frames_equal(a, b)


def test_parallel_identical_to_serial_streaming(trace_file):
    st = Trace.open(trace_file, streaming=True, chunk_rows=61, cache=False)
    serial = st.flat_profile(metrics=[EXC, INC])
    par = run_units(trace_file, "flat_profile", metrics=[EXC, INC])
    assert_frames_equal(serial, par, context="serial vs parallel streaming")


def test_sharded_paths_parallel(tmp_path):
    paths = tracegen.big_trace(str(tmp_path / "big"), nprocs=3,
                               events_per_proc=2500, calls_per_iter=100)
    mem = Trace.open(paths)
    assert_frames_equal(mem.flat_profile(),
                        run_units(paths, "flat_profile", chunk_rows=400))
    np.testing.assert_array_equal(mem.comm_matrix(),
                                  run_units(paths, "comm_matrix",
                                            chunk_rows=400))


def test_chrome_procspan_units(tmp_path):
    """Chrome traces partition per-pid (ProcSpan units with a shared pid
    table); non-dense pids must densify identically to the eager read."""
    p = str(tmp_path / "weird.json")
    events = []
    for pid in (5000, 300, 71):
        events += [{"ph": "B", "name": "work", "pid": pid, "tid": 0,
                    "ts": 1.0},
                   {"ph": "B", "name": "inner", "pid": pid, "tid": 0,
                    "ts": 10.0},
                   {"ph": "E", "name": "inner", "pid": pid, "tid": 0,
                    "ts": 20.0},
                   {"ph": "E", "name": "work", "pid": pid, "tid": 0,
                    "ts": 50.0}]
    with open(p, "w") as f:
        json.dump({"traceEvents": events}, f)
    mem = Trace.open(p)
    units = registry.get_reader("chrome").plan_units(p, 3)
    assert len(units) == 3
    assert all(isinstance(u, registry.ProcSpan) for u in units)
    assert_frames_equal(mem.flat_profile(per_process=True),
                        run_units(p, "flat_profile", n_units=3,
                                  chunk_rows=4, per_process=True))


def test_csv_units_guard_extra_columns(tmp_path):
    """Canonical-only CSVs byte-split; extra (value-inferred) columns make
    the file a single unit so per-span type decisions can never diverge
    from serial streaming."""
    canon = str(tmp_path / "canon.csv")
    with open(canon, "w") as f:
        f.write("Timestamp (ns),Event Type,Name,Process\n")
        for i in range(50):
            f.write(f"{i * 10},Enter,f,0\n{i * 10 + 5},Leave,f,0\n")
    units = registry.get_reader("csv").plan_units(canon, 3)
    assert units and all(isinstance(u, registry.ByteSpan) for u in units)
    mem = Trace.open(canon)
    assert_frames_equal(mem.flat_profile(),
                        run_units(canon, "flat_profile", n_units=3,
                                  chunk_rows=7))
    extra = str(tmp_path / "extra.csv")
    with open(extra, "w") as f:
        f.write("Timestamp (ns),Event Type,Name,Process,phase\n")
        f.write("0,Enter,f,0,1\n5,Leave,f,0,warmup\n")
    assert registry.get_reader("csv").plan_units(extra, 3) is None


def test_unit_plan_replans_when_file_grows(tmp_path):
    """Byte spans computed against an old file extent must not silently
    truncate a file that grew between terminal ops on one handle."""
    p = str(tmp_path / "grow.jsonl")
    t = tracegen.gol(nprocs=2, iters=2, seed=11)
    write_jsonl(t, p)
    h = StreamingTrace(p, chunk_rows=32, processes=2)
    spec = registry.get_op("flat_profile")
    r1 = ex.execute_parallel(h, (), spec, (), {}, spec.streaming(),
                             n_units=3, use_pool=False)
    with open(p, "a") as f:
        for i in range(50):
            f.write('{"ts": %d, "et": "Enter", "name": "grown", "proc": 0}\n'
                    '{"ts": %d, "et": "Leave", "name": "grown", "proc": 0}\n'
                    % (10**9 + i * 100, 10**9 + i * 100 + 50))
    r2 = ex.execute_parallel(h, (), spec, (), {}, spec.streaming(),
                             n_units=3, use_pool=False)
    assert "grown" in set(map(str, r2[NAME]))
    assert int(np.asarray(r2["count"]).sum()) \
        == int(np.asarray(r1["count"]).sum()) + 50


def test_csv_numeric_looking_names_in_one_span(tmp_path):
    """A byte span whose Name values all look numeric must still type the
    column categorically (pinned by name), not crash or diverge."""
    p = str(tmp_path / "numnames.csv")
    with open(p, "w") as f:
        f.write("Timestamp (ns),Event Type,Name,Process\n")
        for i in range(30):
            f.write(f"{i * 10},Enter,alpha,0\n{i * 10 + 5},Leave,alpha,0\n")
        for i in range(30, 60):
            f.write(f"{i * 10},Enter,123,0\n{i * 10 + 5},Leave,123,0\n")
    prof = run_units(p, "flat_profile", n_units=4, chunk_rows=8)
    assert set(map(str, prof[NAME])) == {"alpha", "123"}
    counts = dict(zip(map(str, prof[NAME]), np.asarray(prof["count"])))
    assert counts == {"alpha": 30, "123": 30}


def test_procspan_units_pruned_by_plan_restriction(tmp_path):
    """ProcSpan units disjoint from restrict_processes are never
    dispatched — workers must not decode a stream just to drop it all."""
    p = str(tmp_path / "pids.json")
    events = []
    for pid in range(4):
        events += [{"ph": "B", "name": "w", "pid": pid, "tid": 0, "ts": 1.0},
                   {"ph": "E", "name": "w", "pid": pid, "tid": 0, "ts": 9.0}]
    with open(p, "w") as f:
        json.dump({"traceEvents": events}, f)
    h = StreamingTrace(p, chunk_rows=4, processes=2)
    steps = h.query().restrict_processes([0, 1])._steps
    from repro.core.streaming import _steps_hints
    units = ex._prune_units(ex.plan_units(h, steps, 4), _steps_hints(steps))
    assert units and all(set(u.procs) & {0, 1} for u in units)
    assert len(units) < len(ex.plan_units(h, steps, 4))


def test_unit_plan_replans_on_dir_rewrite(tmp_path):
    """otf2j archives are directories: rewriting a contained file in place
    (dir mtime unchanged) must still re-plan units."""
    from repro.readers.otf2j import write_otf2_json
    d = str(tmp_path / "arch")
    write_otf2_json(tracegen.gol(nprocs=2, iters=2, seed=3), d,
                    split_locations=True)
    h = StreamingTrace(d, chunk_rows=50, processes=2)
    spec = registry.get_op("flat_profile")
    ex.execute_parallel(h, (), spec, (), {}, spec.streaming(), n_units=2,
                        use_pool=False)
    keys_before = set(h._units_cache)
    write_otf2_json(tracegen.gol(nprocs=4, iters=2, seed=3), d,
                    split_locations=True)
    prof = ex.execute_parallel(h, (), spec, (), {}, spec.streaming(),
                               n_units=2, use_pool=False)
    assert set(h._units_cache) != keys_before  # stat of inner files changed
    mem = Trace.open(d)
    assert_frames_equal(mem.flat_profile(), prof)


def test_open_rejects_cache_flag_without_streaming(trace_file):
    with pytest.raises(ValueError, match="cache"):
        Trace.open(trace_file, cache=False)


def test_unit_plans_cached_on_handle(trace_file):
    h = StreamingTrace(trace_file, chunk_rows=64, processes=2)
    spec = registry.get_op("flat_profile")
    ex.execute_parallel(h, (), spec, (), {}, spec.streaming(), n_units=3,
                        use_pool=False)
    assert h._units_cache
    (key, units), = h._units_cache.items()
    ex.execute_parallel(h, (), spec, (), {}, spec.streaming(), n_units=3,
                        use_pool=False)
    assert h._units_cache[key] is units  # re-planned from cache, not anew


def test_otf2j_rank_units(tmp_path):
    from repro.readers.otf2j import write_otf2_json
    t = tracegen.gol(nprocs=4, iters=3, seed=7)
    d = str(tmp_path / "arch")
    write_otf2_json(t, d, split_locations=True)
    mem = Trace.open(d)
    units = registry.get_reader("otf2j").plan_units(d, 2)
    assert units and all(isinstance(u, registry.ProcSpan) for u in units)
    assert_frames_equal(mem.flat_profile(per_process=True),
                        run_units(d, "flat_profile", n_units=2,
                                  chunk_rows=50, per_process=True))


def test_spawn_pool_end_to_end(trace_file, mem):
    """The public API with a real spawn pool (pytest's __main__ is an
    importable script, so the pool genuinely starts)."""
    st = Trace.open(trace_file, streaming=True, chunk_rows=101,
                    executor="parallel", processes=2, cache=False)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)  # no degradation
        prof = st.flat_profile(metrics=[EXC, INC])
    assert_frames_equal(mem.flat_profile(metrics=[EXC, INC]), prof)
    # the handle keeps its pool: a second op must not restart workers
    pool = st._pool
    assert pool is not None
    assert_frames_equal(mem.load_imbalance(), st.load_imbalance())
    assert st._pool is pool


def test_traceset_members_share_one_pool(tmp_path):
    before, after = tracegen.regression_pair(
        "tortuga", func="computeRhs", factor=1.7, nprocs=4, iters=3)
    pb, pa = str(tmp_path / "b.jsonl"), str(tmp_path / "a.jsonl")
    write_jsonl(before, pb)
    write_jsonl(after, pa)
    ts_mem = TraceSet.open([pb, pa])
    ts_par = TraceSet.open([pb, pa], streaming=True, chunk_rows=128,
                           processes=2)
    assert ts_par[0]._pool is not None
    assert len({id(m._pool) for m in ts_par}) == 1
    assert_frames_equal(ts_mem.regression_report(),
                        ts_par.regression_report())
    a, b = ts_mem.scaling_analysis(), ts_par.scaling_analysis()
    np.testing.assert_allclose(np.asarray(a["time.exc.total"], float),
                               np.asarray(b["time.exc.total"], float))


# ---------------------------------------------------------------------------
# degradation paths report why
# ---------------------------------------------------------------------------

def _degradation_warning(handle, op="flat_profile"):
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        getattr(handle, op)(cache=False)
    msgs = [str(x.message) for x in w
            if issubclass(x.category, RuntimeWarning)]
    assert msgs, "expected a degradation warning"
    return "\n".join(msgs)


def test_degradation_reason_processes_1(trace_file):
    st = Trace.open(trace_file, streaming=True, chunk_rows=64,
                    executor="parallel", processes=1)
    assert "processes=1" in _degradation_warning(st)


def test_degradation_reason_spawn_unsafe(trace_file, monkeypatch):
    monkeypatch.setattr(ex, "spawn_unsafe_reason",
                        lambda: "__main__ has no importable file (test)")
    st = Trace.open(trace_file, streaming=True, chunk_rows=64,
                    executor="parallel", processes=2)
    assert "__main__" in _degradation_warning(st)


def test_degradation_reason_non_mergeable_op(trace_file):
    @registry.register_op("last_ts_op")
    def last_ts_op(trace):
        return float(np.asarray(trace.events["Timestamp (ns)"]).max())

    @registry.register_streaming("last_ts_op")
    class _LastTsAgg(StreamAgg):
        # combinable but (deliberately) not parallel-mergeable
        def __init__(self):
            self.v = -np.inf

        def update(self, chunk):
            self.v = max(self.v, float(
                np.asarray(chunk.events["Timestamp (ns)"]).max()))

        def result(self, ctx):
            return self.v

    assert not registry.get_op("last_ts_op").parallel_safe
    st = Trace.open(trace_file, streaming=True, chunk_rows=64,
                    executor="parallel", processes=2)
    msg = _degradation_warning(st, "last_ts_op")
    assert "last_ts_op" in msg and "no cross-worker merge" in msg


def test_degradation_reason_unsplittable_input(tmp_path):
    """A single chrome file with one pid has no second work unit."""
    p = str(tmp_path / "one.json")
    with open(p, "w") as f:
        json.dump({"traceEvents": [
            {"ph": "B", "name": "f", "pid": 0, "tid": 0, "ts": 1.0},
            {"ph": "E", "name": "f", "pid": 0, "tid": 0, "ts": 9.0}]}, f)
    st = Trace.open(p, streaming=True, chunk_rows=64,
                    executor="parallel", processes=2)
    assert "cannot be partitioned" in _degradation_warning(st)


def test_cross_unit_out_of_order_raises(tmp_path):
    """A (proc, thread) stream that runs backwards between file halves must
    fail loudly under partitioned execution, like serial streaming does."""
    p = str(tmp_path / "backwards.jsonl")
    with open(p, "w") as f:
        for ts in (1000, 2000, 3000, 4000):
            f.write('{"ts": %d, "et": "Enter", "name": "a", "proc": 0}\n'
                    % ts)
        for ts in (10, 20, 30, 40):
            f.write('{"ts": %d, "et": "Leave", "name": "a", "proc": 0}\n'
                    % ts)
    with pytest.raises(StreamingUnsupported, match="time order"):
        run_units(p, "flat_profile", n_units=2, chunk_rows=2)


# ---------------------------------------------------------------------------
# byte-span line ownership
# ---------------------------------------------------------------------------

def test_byte_spans_partition_lines_exactly(tmp_path):
    p = str(tmp_path / "lines.txt")
    lines = [("line-%03d" % i).encode() + b"\n" for i in range(37)]
    with open(p, "wb") as f:
        f.writelines(lines)
    size = os.path.getsize(p)
    for n in (1, 2, 3, 5, 11, size):
        edges = [size * i // n for i in range(n + 1)]
        got = []
        for lo, hi in zip(edges[:-1], edges[1:]):
            with open(p, "rb") as f:
                got.extend(iter_lines_range(f, lo, hi))
        assert got == lines, f"n={n}"


# ---------------------------------------------------------------------------
# plan-result cache
# ---------------------------------------------------------------------------

def test_cache_hit_returns_identical_object(trace_file):
    st = Trace.open(trace_file, streaming=True, chunk_rows=256)
    r1 = st.flat_profile()
    r2 = st.flat_profile()
    assert r2 is r1
    assert plancache.stats()["hits"] >= 1


def test_cache_false_bypasses(trace_file):
    st = Trace.open(trace_file, streaming=True, chunk_rows=256)
    r1 = st.flat_profile()
    assert st.flat_profile(cache=False) is not r1
    st2 = Trace.open(trace_file, streaming=True, chunk_rows=256, cache=False)
    assert st2.flat_profile() is not r1


def test_cache_digest_differs_across_args_and_steps(trace_file):
    st = Trace.open(trace_file, streaming=True, chunk_rows=256)
    r1 = st.flat_profile()
    r2 = st.flat_profile(metrics=[INC])
    assert r2 is not r1
    r3 = st.query().restrict_processes([0, 1]).flat_profile()
    assert r3 is not r1
    # identical plan through a fresh handle over the same file still hits
    st2 = Trace.open(trace_file, streaming=True, chunk_rows=256)
    assert st2.flat_profile() is r1


def test_cache_invalidated_by_file_mutation(tmp_path):
    t = tracegen.gol(nprocs=2, iters=2, seed=9)
    p = str(tmp_path / "g.jsonl")
    write_jsonl(t, p)
    st = Trace.open(p, streaming=True, chunk_rows=64)
    r1 = st.flat_profile()
    with open(p, "a") as f:
        f.write('{"ts": 99999999999, "et": "Enter", "name": "zz", '
                '"proc": 0}\n')
    r2 = st.flat_profile()
    assert r2 is not r1  # size/mtime changed -> new digest


def test_cache_eager_opt_in_and_mutation(trace_file, mem):
    r1 = mem.query().flat_profile(cache=True)
    assert mem.query().flat_profile(cache=True) is r1
    # default for in-memory traces is uncached (content hash is O(N))
    assert mem.query().flat_profile() is not r1
    # mutating the events changes the content hash -> miss
    t = Trace.open(trace_file)
    a = t.query().flat_profile(cache=True)
    ev = t.events
    ts = np.asarray(ev["Timestamp (ns)"], np.int64).copy()
    ts[0] += 1
    ev["Timestamp (ns)"] = ts
    b = t.query().flat_profile(cache=True)
    assert b is not a


def test_cache_clear(trace_file):
    st = Trace.open(trace_file, streaming=True, chunk_rows=256)
    r1 = st.flat_profile()
    plancache.clear()
    assert st.flat_profile() is not r1


def test_cache_skips_undigestable_arguments(mem):
    # a callable argument has no exact digest -> bypass, never a wrong hit
    r1 = mem.query().comm_comp_breakdown(
        cache=True, comm_matcher=lambda n: n.startswith("MPI"))
    r2 = mem.query().comm_comp_breakdown(
        cache=True, comm_matcher=lambda n: False)
    assert r1 is not r2
    assert plancache.stats()["entries"] == 0
