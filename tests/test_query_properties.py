"""Property-based tests for lazy-plan invariants (paper §IV-E).

Two invariants the query engine's whole design rests on:

* **fusion soundness** — a chain of selections executed as one fused plan
  yields exactly the trace the eager step-by-step application yields;
* **remap soundness** — when a selection provably preserves enter/leave
  pairs and parent chains, remapping the derived structure through the
  old→new row map is bit-identical to recomputing it from scratch.

Random balanced call forests (with messages) and random selection chains
drive both; runs under real hypothesis when installed, the vendored
minihyp fallback otherwise.
"""

import numpy as np

from repro.testing.hyp import given, settings, st

from repro.core.constants import (ET, EXC, INC, MATCH, NAME, PARENT, PROC,
                                  TS)
from repro.core.filters import Filter, time_window_filter
from repro.core.frame import EventFrame
from repro.core.query import apply_selection
from repro.core.trace import Trace


@st.composite
def message_forest(draw):
    """Random balanced per-process call forest, one trace."""
    nprocs = draw(st.integers(1, 3))
    ts_list, et_list, name_list, proc_list = [], [], [], []

    def gen(proc, t, depth, budget):
        while budget[0] > 0 and draw(st.booleans()):
            budget[0] -= 1
            name = draw(st.sampled_from(["f", "g", "h", "MPI_Wait"]))
            ts_list.append(t)
            et_list.append("Enter")
            name_list.append(name)
            proc_list.append(proc)
            t += draw(st.integers(1, 3))
            if depth < 3:
                t = gen(proc, t, depth + 1, budget)
            ts_list.append(t)
            et_list.append("Leave")
            name_list.append(name)
            proc_list.append(proc)
            t += draw(st.integers(1, 3))
        return t

    for p in range(nprocs):
        gen(p, draw(st.integers(0, 4)), 0, [draw(st.integers(1, 10))])
    if not ts_list:  # force at least one call
        ts_list, et_list = [0, 1], ["Enter", "Leave"]
        name_list, proc_list = ["f", "f"], [0, 0]
    ev = EventFrame({
        TS: np.asarray(ts_list, np.float64),
        ET: np.asarray(et_list),
        NAME: np.asarray(name_list),
        PROC: np.asarray(proc_list, np.int64),
    }).sort_by([PROC, TS])
    return Trace(ev)


@st.composite
def selection_chain(draw):
    """1-3 random plan steps (kind, payload)."""
    steps = []
    for _ in range(draw(st.integers(1, 3))):
        kind = draw(st.sampled_from(["name", "procs", "window"]))
        if kind == "name":
            names = draw(st.lists(st.sampled_from(["f", "g", "h"]),
                                  min_size=1, max_size=3))
            steps.append(("name", sorted(set(names))))
        elif kind == "procs":
            steps.append(("procs", sorted({draw(st.integers(0, 2)),
                                           draw(st.integers(0, 2))})))
        else:
            a = draw(st.integers(0, 20))
            steps.append(("window", (a, a + draw(st.integers(1, 30)))))
    return steps


def _apply_eager(trace, steps):
    cur = trace
    for kind, payload in steps:
        if kind == "name":
            cur = cur.filter(Filter(NAME, "not-in", payload))
        elif kind == "procs":
            cur = cur.filter_processes(payload)
        else:
            cur = cur.filter(time_window_filter(*payload, trim="within"))
    return cur


def _apply_lazy(trace, steps):
    q = trace.query()
    for kind, payload in steps:
        if kind == "name":
            q = q.filter(Filter(NAME, "not-in", payload))
        elif kind == "procs":
            q = q.restrict_processes(payload)
        else:
            q = q.filter(time_window_filter(*payload, trim="within"))
    return q.collect()


def _frames_identical(a: EventFrame, b: EventFrame) -> None:
    assert sorted(a.columns) == sorted(b.columns)
    for c in a.columns:
        va, vb = np.asarray(a[c]), np.asarray(b[c])
        if va.dtype.kind in "UO":
            assert list(map(str, va)) == list(map(str, vb)), c
        else:
            np.testing.assert_array_equal(va, vb, err_msg=c)


@given(message_forest(), selection_chain())
@settings(max_examples=60, deadline=None)
def test_fused_plan_equals_sequential_eager(trace, steps):
    """One fused mask == the same chain applied one eager step at a time."""
    eager = _apply_eager(trace, steps)
    lazy = _apply_lazy(trace, steps)
    assert len(eager) == len(lazy)
    _frames_identical(eager.events, lazy.events)


@given(message_forest(), selection_chain())
@settings(max_examples=60, deadline=None)
def test_fused_plan_profile_equals_eager_profile(trace, steps):
    """Terminal op on the fused plan == op on the eagerly selected trace."""
    eager = _apply_eager(trace, steps).flat_profile(metrics=[INC, EXC])
    q = _apply_lazy(trace, steps)
    lazy = q.flat_profile(metrics=[INC, EXC])
    _frames_identical(eager, lazy)


@given(message_forest(), st.lists(st.integers(0, 2), min_size=1, max_size=3))
@settings(max_examples=60, deadline=None)
def test_structure_remap_equals_recompute(trace, procs):
    """Pair-preserving selections (process subsets): remapped structure ==
    full from-scratch recompute, bit for bit."""
    trace._ensure_structure()
    keep = np.isin(np.asarray(trace.events[PROC], np.int64),
                   np.unique(procs))
    remapped = apply_selection(trace, keep)
    if not remapped._structured:
        return  # selection broke pairs; fallback path is the recompute
    # from-scratch reference on the same rows
    fresh = Trace(trace.events.drop(MATCH, PARENT, INC, EXC,
                                    "_matching_timestamp", "_depth",
                                    "_cct_node").mask(keep))
    fresh._ensure_structure()
    for col in (MATCH, PARENT, INC, EXC):
        np.testing.assert_array_equal(
            np.asarray(remapped.events.column(col)),
            np.asarray(fresh.events.column(col)), err_msg=col)


@given(message_forest())
@settings(max_examples=40, deadline=None)
def test_whole_subtree_drop_remap(trace):
    """Dropping whole call subtrees (a name filter that removes leaf calls
    entirely) keeps pairs; remap must equal recompute."""
    trace._ensure_structure()
    ev = trace.events
    match = np.asarray(ev.column(MATCH), np.int64)
    parent = np.asarray(ev.column(PARENT), np.int64)
    # drop every matched leaf call of name "h" (enter+leave pairs whose
    # enter has no children) — whole-subtree by construction
    names = ev[NAME]
    is_enter = ev.cat(ET).mask_eq("Enter")
    has_child = np.zeros(len(ev), bool)
    pe = parent[(parent >= 0)]
    has_child[pe] = True
    drop = np.zeros(len(ev), bool)
    sel = np.nonzero(is_enter & (match >= 0) & ~has_child
                     & (names == "h"))[0]
    drop[sel] = True
    drop[match[sel]] = True
    keep = ~drop
    remapped = apply_selection(trace, keep)
    assert remapped._structured
    fresh = Trace(trace.events.drop(MATCH, PARENT, INC, EXC,
                                    "_matching_timestamp", "_depth",
                                    "_cct_node").mask(keep))
    fresh._ensure_structure()
    for col in (MATCH, PARENT, INC, EXC):
        np.testing.assert_array_equal(
            np.asarray(remapped.events.column(col)),
            np.asarray(fresh.events.column(col)), err_msg=col)
