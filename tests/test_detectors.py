"""Closed-loop tests for the automated diagnostics suite.

Every detector in :mod:`repro.core.detectors` is validated against
:mod:`repro.tracegen.pathologies` ground truth, in four loops:

* **top-1 recovery** — inject each pathology into the clean baseline app;
  the matching detector's highest-severity finding must name the injected
  culprit (rank / function / time window).
* **monotone severity** — the culprit's severity strictly increases with
  injected magnitude.
* **false-positive gate** — the clean baseline yields zero findings from
  every detector at default thresholds.
* **path identity** — eager, streaming (two chunk sizes), parallel
  (2 workers), and pack execution produce digest-identical Findings.
"""

import os

import numpy as np
import pytest

from repro.core import Trace, list_detectors
from repro.core import detectors as D
from repro.core import registry
from repro.core.detectors import FINDINGS_COLUMNS
from repro.readers.jsonl import write_jsonl
from repro.readers.pack import write_pack
from repro.serving.protocol import result_digest
from repro.tracegen import PATHOLOGIES, baseline, inject, pathology_trace

# magnitudes chosen so severity clears each detector's default threshold
# at the low end and grows strictly from there
MAGNITUDES = {
    "late_sender": (2.0, 4.0, 8.0),
    "straggler": (1.5, 2.0, 3.0),
    "serialization": (3.0, 5.0, 9.0),
    "imbalance": (2.0, 4.0, 8.0),
    "efficiency_drop": (0.3, 0.6, 1.0),
}


@pytest.fixture(scope="module")
def clean():
    return baseline(nprocs=4, iters=16, seed=0)


def top_finding(findings):
    assert len(findings) >= 1
    return {c: findings[c][0] for c in FINDINGS_COLUMNS}


def assert_matches_ground_truth(findings, gt):
    top = top_finding(findings)
    assert str(top["detector"]) == gt.detector
    if gt.process != -1:
        assert int(top["process"]) == gt.process, (
            f"top-1 blames rank {top['process']}, injected rank "
            f"{gt.process}")
    if gt.function:
        assert str(top["function"]) == gt.function
    # reported window overlaps the injected one
    assert float(top["t_start"]) < gt.t_end
    assert float(top["t_end"]) > gt.t_start


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_all_five_detectors_registered():
    assert set(list_detectors()) >= {"late_sender", "stragglers",
                                     "serialization",
                                     "imbalance_root_cause",
                                     "pop_efficiency"}
    for name in list_detectors():
        spec = D.get_detector(name)
        assert spec is not None and spec.name == name
        assert spec.description, f"{name} has no description"
        # every detector is a registered op with a streaming form (so it
        # runs out of core and through the parallel executor)
        op = registry.get_op(name)
        assert op is not None and op.scope == "trace"
        assert op.streaming is not None, f"{name} not combinable"
        assert op.parallel_safe, f"{name} not parallel-safe"


def test_register_detector_and_diagnose_pickup(clean):
    @D.register_detector("always_fires", category="test", threshold=0.0)
    def always_fires(trace):
        """Fires once on any trace."""
        return D.Findings([{
            "detector": "always_fires", "location": "everywhere",
            "process": -1, "function": "", "severity": 0.5,
            "t_start": 0.0, "t_end": 1.0, "explanation": "test",
        }])

    try:
        assert "always_fires" in list_detectors()
        f = clean.query().run("always_fires", cache=False)
        assert len(f) == 1
        combined = clean.query().run("diagnose", cache=False)
        assert "always_fires" in set(map(str, combined["detector"]))
    finally:
        registry._OP_REGISTRY.pop("always_fires", None)
        D._DETECTOR_REGISTRY.pop("always_fires", None)


# ---------------------------------------------------------------------------
# false-positive gate
# ---------------------------------------------------------------------------

def test_clean_trace_yields_no_findings(clean):
    combined = clean.diagnose()
    assert len(combined) == 0, (
        "clean baseline produced findings: "
        + "; ".join(f"{d}:{loc}" for d, loc in
                    zip(combined["detector"], combined["location"])))
    for name in list_detectors():
        f = clean.query().run(name, cache=False)
        assert len(f) == 0, f"{name} fired on the clean baseline"


def test_empty_findings_keep_schema(clean):
    f = clean.diagnose()
    assert tuple(f.columns) == FINDINGS_COLUMNS
    assert np.asarray(f["severity"]).dtype == np.float64
    assert np.asarray(f["process"]).dtype == np.int64


# ---------------------------------------------------------------------------
# closed loop: top-1 recovery + monotone severity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pathology", sorted(PATHOLOGIES))
@pytest.mark.parametrize("seed", [0, 3])
def test_top1_recovery(pathology, seed):
    detector = PATHOLOGIES[pathology]
    tr, gt = pathology_trace(pathology, magnitude=MAGNITUDES[pathology][1],
                             seed=seed)
    findings = tr.query().run(detector, cache=False)
    assert_matches_ground_truth(findings, gt)


@pytest.mark.parametrize("pathology", sorted(PATHOLOGIES))
def test_severity_monotone_in_magnitude(pathology):
    detector = PATHOLOGIES[pathology]
    sevs = []
    for m in MAGNITUDES[pathology]:
        tr, gt = pathology_trace(pathology, magnitude=m, seed=1)
        findings = tr.query().run(detector, cache=False)
        sevs.append(float(top_finding(findings)["severity"]))
    assert all(a < b for a, b in zip(sevs, sevs[1:])), (
        f"{pathology}: severities {sevs} not strictly increasing with "
        f"magnitude {MAGNITUDES[pathology]}")


def test_diagnose_ranks_across_detectors():
    tr, gt = pathology_trace("straggler", magnitude=3.0, seed=2)
    combined = tr.diagnose()
    sev = np.asarray(combined["severity"], np.float64)
    assert (np.diff(sev) <= 0).all(), "diagnose output not severity-ranked"
    assert gt.detector in set(map(str, combined["detector"]))


# ---------------------------------------------------------------------------
# diagnose surface
# ---------------------------------------------------------------------------

def test_diagnose_subset_and_unknown(clean):
    tr, _ = pathology_trace("straggler", magnitude=2.0, seed=0)
    sub = tr.diagnose(detectors=["stragglers"])
    assert set(map(str, sub["detector"])) <= {"stragglers"}
    direct = tr.query().run("stragglers", cache=False)
    assert result_digest(sub) == result_digest(direct)
    with pytest.raises(ValueError, match="unknown detector"):
        tr.diagnose(detectors=["nonsense"])


def test_trace_method_equals_query_terminal():
    tr, _ = pathology_trace("imbalance", magnitude=4.0, seed=0)
    assert result_digest(tr.diagnose()) == result_digest(
        tr.query().run("diagnose", cache=False))


def test_query_plan_composes_with_detectors():
    tr, gt = pathology_trace("straggler", magnitude=2.0, seed=0)
    f = tr.query().restrict_processes(
        [gt.process]).run("stragglers", cache=False)
    # a single-rank selection can have no cross-rank excess — the plan
    # must still execute and return a well-formed Findings frame
    assert tuple(f.columns) == FINDINGS_COLUMNS


# ---------------------------------------------------------------------------
# path identity: eager == streaming == parallel == pack
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def injected_on_disk(tmp_path_factory):
    d = tmp_path_factory.mktemp("detector_paths")
    out = {}
    for pathology in sorted(PATHOLOGIES):
        tr, gt = pathology_trace(pathology,
                                 magnitude=MAGNITUDES[pathology][1], seed=0)
        jl = str(d / f"{pathology}.jsonl")
        pk = str(d / f"{pathology}.pack")
        write_jsonl(tr, jl)
        write_pack(tr, pk)
        out[pathology] = (jl, pk, gt)
    return out


@pytest.mark.parametrize("pathology", sorted(PATHOLOGIES))
def test_streaming_and_pack_identical_to_eager(pathology, injected_on_disk):
    jl, pk, gt = injected_on_disk[pathology]
    detector = PATHOLOGIES[pathology]
    for op in (detector, "diagnose"):
        want = result_digest(Trace.open(jl).query().run(op, cache=False))
        got = {
            "stream(64)": Trace.open(jl, streaming=True, chunk_rows=64),
            "stream(257)": Trace.open(jl, streaming=True, chunk_rows=257),
            "pack-eager": Trace.open(pk),
            "pack-stream": Trace.open(pk, streaming=True, chunk_rows=128),
        }
        for label, handle in got.items():
            assert result_digest(
                handle.query().run(op, cache=False)) == want, (
                f"{pathology}/{op}: {label} diverges from eager")


@pytest.mark.parametrize("pathology", ["straggler", "serialization"])
def test_parallel_identical_to_eager(pathology, injected_on_disk):
    jl, pk, gt = injected_on_disk[pathology]
    want = result_digest(Trace.open(jl).query().run("diagnose", cache=False))
    st = Trace.open(jl, streaming=True, chunk_rows=64, processes=2)
    assert result_digest(st.query().run("diagnose", cache=False)) == want
