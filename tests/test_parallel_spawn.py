"""Script-based tests for the multiprocessing spawn path.

Spawned pool workers re-import ``__main__``; when Python runs from stdin
or ``-c`` there is no importable ``__main__`` file and the pool used to
crash with a confusing re-import error.  ``spawn_pool_ok`` now detects
that and the drivers fall back to serial reading — these tests exercise
both the real pooled path (from an on-disk script, the supported layout)
and the stdin fallback, in subprocesses so the parent suite's ``__main__``
doesn't leak in.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np

from repro import tracegen
from repro.readers.jsonl import write_jsonl
from repro.readers.parallel import split_jsonl_by_process

_ENV_SETUP = """
import os, sys
sys.path.insert(0, {src!r})
from repro.readers.parallel import read_parallel, spawn_pool_ok
t = read_parallel({shards!r}, processes=2)
assert len(t) == {n}, f"expected {n} events, got {{len(t)}}"
print("OK", len(t), spawn_pool_ok())
"""


def _make_shards(tmp_path):
    t = tracegen.gol(nprocs=3, iters=3, seed=5)
    whole = str(tmp_path / "g.jsonl")
    write_jsonl(t, whole)
    shards = split_jsonl_by_process(whole, str(tmp_path / "shards"))
    return shards, len(t)


def _src_dir():
    return os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "src"))


def test_pooled_read_from_script_file(tmp_path):
    """The supported layout: a real script file on disk; the pool spawns."""
    shards, n = _make_shards(tmp_path)
    code = _ENV_SETUP.format(src=_src_dir(), shards=shards, n=n)
    script = tmp_path / "driver.py"
    script.write_text(textwrap.dedent(code))
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert out.stdout.startswith("OK")
    assert "True" in out.stdout  # pool genuinely allowed from a script


def test_pooled_read_from_stdin_falls_back(tmp_path):
    """Python run from stdin has no importable __main__: the driver must
    degrade to serial reading instead of crashing in the spawn re-import."""
    shards, n = _make_shards(tmp_path)
    code = _ENV_SETUP.format(src=_src_dir(), shards=shards, n=n)
    out = subprocess.run([sys.executable, "-"], input=textwrap.dedent(code),
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert out.stdout.startswith("OK")
    assert "False" in out.stdout  # guard reported the unsafe __main__


def test_pooled_read_from_dash_c_falls_back(tmp_path):
    shards, n = _make_shards(tmp_path)
    code = _ENV_SETUP.format(src=_src_dir(), shards=shards, n=n)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert out.stdout.startswith("OK")


def test_results_identical_pool_vs_serial(tmp_path):
    """Same events either way (order is canonicalized by the driver)."""
    from repro.readers.parallel import read_parallel
    shards, n = _make_shards(tmp_path)
    serial = read_parallel(shards, processes=1)
    # in-process pytest run: __main__ is pytest's entry — spawn_pool_ok
    # decides; either path must produce identical frames
    pooled = read_parallel(shards, processes=2)
    assert len(serial) == len(pooled) == n
    for c in serial.events.columns:
        va, vb = serial.events[c], pooled.events[c]
        if np.asarray(va).dtype.kind in "UO":
            assert list(map(str, va)) == list(map(str, vb))
        else:
            np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
