"""Trace-query service tests: wire protocol fidelity, per-op conformance
against direct library calls, single-flight coalescing, admission control
(per-tenant concurrency + plan-cache quotas, lane starvation), graceful
shutdown, and the HTTP client round trip."""

import asyncio
import json
import time

import numpy as np
import pytest

from repro.core import plancache, registry
from repro.core.diff import TraceSet
from repro.core.filters import Filter
from repro.core.frame import Categorical, EventFrame
from repro.core.scheduler import Scheduler, set_scheduler
from repro.core.trace import Trace
from repro.serving import protocol
from repro.serving.client import RemoteError, ServiceClient
from repro.serving.protocol import ProtocolError, result_digest
from repro.serving.tracequery import (ServiceError, TraceServer,
                                      TraceService)
from repro.tracegen.big import big_trace


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pack_paths(tmp_path_factory):
    out = tmp_path_factory.mktemp("serve_trc")
    big_trace(str(out), nprocs=4, events_per_proc=600, calls_per_iter=40,
              seed=11, format="pack")
    return sorted(str(p) for p in out.glob("*.pack"))


@pytest.fixture()
def fresh_cache():
    plancache.clear()
    plancache.configure(enabled=True, tenant_quota=0)
    yield
    plancache.clear()
    plancache.configure(enabled=True, tenant_quota=0)


@pytest.fixture()
def sleep_op():
    @registry.register_op("_serve_sleep")
    def _serve_sleep(trace, duration=0.2, tag=0):
        time.sleep(float(duration))
        return float(len(trace.events)) + float(tag)

    yield "_serve_sleep"
    registry._OP_REGISTRY.pop("_serve_sleep", None)


def run(coro):
    return asyncio.run(coro)


def payload(paths, op, steps=None, streaming=False, tenant="t", args=(),
            kwargs=None, **extra):
    body = {"open": {"paths": list(paths), "streaming": streaming},
            "op": op, "steps": steps or [], "tenant": tenant,
            "args": [protocol.encode_value(a) for a in args],
            "kwargs": {k: protocol.encode_value(v)
                       for k, v in (kwargs or {}).items()}}
    body.update(extra)
    return body


def set_payload(paths, op, **extra):
    body = payload(paths, op, **extra)
    body["open"]["mode"] = "set"
    return body


async def one(service, body, set_scope=False):
    return await service.query(body, set_scope=set_scope)


# ---------------------------------------------------------------------------
# protocol unit tests
# ---------------------------------------------------------------------------

def test_value_roundtrip_bit_exact():
    ev = EventFrame({"Name": ["a", "b", "a"],
                     "x": np.asarray([1.5, np.nan, 3.0]),
                     "n": np.asarray([1, 2, 3], np.int64)})
    values = [ev, np.arange(12, dtype=np.float32).reshape(3, 4),
              (np.arange(3), np.arange(4.0)), [ev, ev],
              {"k": 1, "v": np.arange(2)},
              np.asarray(["x", "y"], object), np.float64(3.25), None,
              True, "s", 7, 2.5]
    for val in values:
        wire = json.loads(json.dumps(protocol.encode_value(val)))
        assert result_digest(protocol.decode_value(wire)) == \
            result_digest(val)


def test_digest_representation_independent():
    cat = Categorical.from_values(np.asarray(["a", "b", "a"], object))
    assert result_digest(cat) == result_digest(cat.to_strings())
    assert result_digest((1, 2)) == result_digest([1, 2])


def test_filter_roundtrip():
    f = (Filter("Name", "in", ["a", "b"]) & Filter("Process", "<", 4)) | \
        ~Filter("Event Type", "==", "Enter")
    wire = json.loads(json.dumps(protocol.encode_filter(f)))
    assert repr(protocol.decode_filter(wire)) == repr(f)


def test_custom_filter_subclass_rejected():
    class Weird(Filter):
        pass

    with pytest.raises(ProtocolError):
        protocol.encode_filter(Weird("Name", "==", "a"))


def test_callable_kwarg_rejected():
    with pytest.raises(ProtocolError):
        protocol.encode_value(lambda x: x)


def test_apply_steps_equals_direct_chain(pack_paths):
    trace = Trace.open(pack_paths[0])
    direct = (trace.query().slice_time(0.0, 40.0, trim="within")
              .filter(Filter("Process", "==", 0)).flat_profile())
    q = trace.query()
    wire = [{"k": "slice_time", "start": 0.0, "end": 40.0,
             "trim": "within"},
            {"k": "filter", "filter": protocol.encode_filter(
                Filter("Process", "==", 0))}]
    replayed = protocol.apply_steps(q, wire).flat_profile()
    assert result_digest(replayed) == result_digest(direct)


# ---------------------------------------------------------------------------
# per-op conformance: service result == direct library call, for every op
# ---------------------------------------------------------------------------

def test_every_trace_op_roundtrips(pack_paths, fresh_cache):
    trace = Trace.open(pack_paths)
    failures = []

    async def main():
        service = TraceService(max_handles=4)
        out = {}
        for op in registry.list_ops():
            if registry.get_op(op).scope != "trace":
                continue
            out[op] = await one(service, payload(pack_paths, op))
        return out

    responses = run(main())
    for op, resp in responses.items():
        wire = json.loads(json.dumps(resp["result"]))
        got = protocol.decode_value(wire)
        want = trace.query().run(op)
        if result_digest(got) != result_digest(want):
            failures.append(op)
        assert resp["digest"] == result_digest(want), op
    assert not failures


def test_every_set_op_roundtrips(pack_paths, fresh_cache):
    tset = TraceSet.open(pack_paths[:2])
    set_ops = [op for op in registry.list_ops()
               if registry.get_op(op).scope == "set"]
    assert set_ops

    async def main():
        service = TraceService(max_handles=4)
        out = {}
        for op in set_ops:
            out[op] = await one(
                service, set_payload(pack_paths[:2], op), set_scope=True)
        return out

    for op, resp in run(main()).items():
        got = protocol.decode_value(json.loads(json.dumps(resp["result"])))
        want = tset.query().run(op)
        assert result_digest(got) == result_digest(want), op


def test_trace_op_mapped_over_set(pack_paths, fresh_cache):
    async def main():
        service = TraceService()
        return await one(service,
                         set_payload(pack_paths[:2], "flat_profile"),
                         set_scope=True)

    got = protocol.decode_value(run(main())["result"])
    want = TraceSet.open(pack_paths[:2]).query().run("flat_profile")
    assert result_digest(got) == result_digest(want)


def test_streaming_digest_matches_eager(pack_paths, fresh_cache):
    async def main():
        service = TraceService()
        return await one(service, payload(pack_paths, "flat_profile",
                                          streaming=True))

    resp = run(main())
    want = Trace.open(pack_paths).query().flat_profile()
    assert resp["digest"] == result_digest(want)


# ---------------------------------------------------------------------------
# single-flight coalescing
# ---------------------------------------------------------------------------

def test_identical_inflight_plans_coalesce(pack_paths, fresh_cache,
                                           sleep_op):
    async def main():
        service = TraceService()
        body = payload(pack_paths[:1], sleep_op, cache=False,
                       kwargs={"duration": 0.05})
        results = await asyncio.gather(
            *[one(service, dict(body)) for _ in range(6)])
        return service, results

    service, results = run(main())
    assert service.counters["executed"] == 1
    assert service.counters["coalesced"] == 5
    digests = {r["digest"] for r in results}
    assert len(digests) == 1
    assert sum(1 for r in results if r.get("coalesced")) == 5


def test_distinct_plans_do_not_coalesce(pack_paths, fresh_cache, sleep_op):
    async def main():
        service = TraceService(per_tenant=8)
        bodies = [payload(pack_paths[:1], sleep_op, cache=False,
                          kwargs={"duration": 0.01, "tag": i})
                  for i in range(3)]
        results = await asyncio.gather(*[one(service, b) for b in bodies])
        return service, results

    service, results = run(main())
    assert service.counters["executed"] == 3
    assert service.counters["coalesced"] == 0
    assert len({r["digest"] for r in results}) == 3


def test_repeat_request_hits_shared_cache(pack_paths, fresh_cache):
    async def main():
        service = TraceService()
        body = payload(pack_paths, "flat_profile", streaming=True,
                       tenant="alice")
        first = await one(service, body)
        second = await one(service, dict(body))
        return service, first, second

    service, first, second = run(main())
    assert not first.get("cached")
    assert second.get("cached")
    assert first["digest"] == second["digest"]
    assert service.counters["cache_hits"] == 1
    assert plancache.stats()["tenants"]["alice"]["hits"] >= 1


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_per_tenant_concurrency_rejects_floods(pack_paths, fresh_cache,
                                               sleep_op):
    async def main():
        service = TraceService(per_tenant=1, max_active=64)
        bodies = [payload(pack_paths[:1], sleep_op, cache=False,
                          tenant="greedy",
                          kwargs={"duration": 0.05, "tag": i})
                  for i in range(10)]
        results = await asyncio.gather(
            *[one(service, b) for b in bodies], return_exceptions=True)
        return service, results

    service, results = run(main())
    rejected = [r for r in results if isinstance(r, ServiceError)]
    ok = [r for r in results if isinstance(r, dict)]
    assert rejected and all(r.code == "tenant_saturated" for r in rejected)
    assert ok  # the in-limit requests still completed
    assert service.counters["rejected"] == len(rejected)
    assert service.tenant_counters["greedy"]["rejected"] == len(rejected)


def test_other_tenant_unaffected_by_flood(pack_paths, fresh_cache,
                                          sleep_op):
    async def main():
        service = TraceService(per_tenant=1, max_active=64)
        flood = [one(service, payload(
            pack_paths[:1], sleep_op, cache=False, tenant="greedy",
            kwargs={"duration": 0.05, "tag": i})) for i in range(8)]
        polite = one(service, payload(
            pack_paths[:1], sleep_op, cache=False, tenant="polite",
            kwargs={"duration": 0.01, "tag": 99}))
        results = await asyncio.gather(*flood, polite,
                                       return_exceptions=True)
        return results[-1]

    polite_result = run(main())
    assert isinstance(polite_result, dict) and polite_result["ok"]


def test_tenant_plan_cache_quota(pack_paths, fresh_cache):
    async def main():
        service = TraceService(tenant_quota=2)
        for i in range(5):
            await one(service, payload(
                pack_paths, "time_profile", streaming=True, tenant="alice",
                kwargs={"num_bins": 4 + i}))
        return service

    try:
        run(main())
        st = plancache.stats()
        assert st["tenant_quota"] == 2
        alice = st["tenants"]["alice"]
        assert alice["entries"] <= 2
        assert alice["evictions"] >= 3
    finally:
        plancache.configure(tenant_quota=0)


def test_interactive_lane_survives_bulk_saturation(pack_paths, fresh_cache,
                                                   sleep_op):
    """Starvation check: with the single bulk thread pinned by slow scans,
    an interactive query still completes on its reserved thread."""
    prev = set_scheduler(Scheduler(workers=2, interactive_workers=1))
    try:
        async def main():
            service = TraceService(per_tenant=8)
            bulk = [one(service, payload(
                pack_paths[:1], sleep_op, cache=False, lane="bulk",
                kwargs={"duration": 0.4, "tag": i})) for i in range(2)]
            bulk_tasks = [asyncio.ensure_future(b) for b in bulk]
            await asyncio.sleep(0.05)  # let bulk occupy its lane
            t0 = time.perf_counter()
            inter = await one(service, payload(
                pack_paths[1:2], sleep_op, cache=False, lane="interactive",
                kwargs={"duration": 0.01, "tag": 9}))
            latency = time.perf_counter() - t0
            await asyncio.gather(*bulk_tasks)
            return inter, latency

        inter, latency = run(main())
        assert inter["ok"]
        # the two 0.4 s bulk jobs serialize on the 1-thread bulk lane;
        # an interactive query that had to wait for it would take >0.35 s
        assert latency < 0.35
    finally:
        sched = set_scheduler(prev)
        if sched is not None:
            sched.shutdown()


# ---------------------------------------------------------------------------
# graceful shutdown
# ---------------------------------------------------------------------------

def test_drain_finishes_inflight_and_refuses_new(pack_paths, fresh_cache,
                                                 sleep_op):
    async def main():
        service = TraceService()
        slow = asyncio.ensure_future(one(service, payload(
            pack_paths[:1], sleep_op, cache=False,
            kwargs={"duration": 0.3})))
        await asyncio.sleep(0.05)
        drained = asyncio.ensure_future(service.drain(timeout=5))
        await asyncio.sleep(0.01)
        with pytest.raises(ServiceError) as exc:
            await one(service, payload(pack_paths[:1], "flat_profile"))
        slow_result = await slow
        return await drained, exc.value, slow_result

    drained, err, slow_result = run(main())
    assert drained is True
    assert err.status == 503 and err.code == "draining"
    assert slow_result["ok"]  # in-flight work finished, not cancelled


# ---------------------------------------------------------------------------
# handle pool
# ---------------------------------------------------------------------------

def test_handle_reopened_when_pack_rewritten(tmp_path, fresh_cache):
    out = tmp_path / "trc"
    big_trace(str(out), nprocs=1, events_per_proc=300, calls_per_iter=20,
              seed=1, format="pack")
    path = sorted(str(p) for p in out.glob("*.pack"))[0]

    async def main():
        service = TraceService()
        first = await one(service, payload([path], "flat_profile"))
        big_trace(str(out), nprocs=1, events_per_proc=300,
                  calls_per_iter=20, seed=2, format="pack")
        second = await one(service, payload([path], "flat_profile"))
        return service, first, second

    service, first, second = run(main())
    assert first["digest"] != second["digest"]
    assert service.handles.stats()["reopens"] == 1
    want = Trace.open(path).flat_profile()
    assert second["digest"] == result_digest(want)


def test_handle_pool_lru_bound(pack_paths, fresh_cache):
    async def main():
        service = TraceService(max_handles=2)
        for p in pack_paths[:3]:
            await one(service, payload([p], "flat_profile"))
        return service.handles.stats()

    st = run(main())
    assert st["open"] == 2
    assert st["evictions"] == 1


# ---------------------------------------------------------------------------
# request validation
# ---------------------------------------------------------------------------

def test_unknown_op_and_bad_requests(pack_paths, fresh_cache):
    async def main():
        service = TraceService()
        with pytest.raises(ProtocolError):
            await one(service, payload(pack_paths[:1], "no_such_op"))
        with pytest.raises(ProtocolError):
            await one(service, {"op": "flat_profile"})  # no open spec
        with pytest.raises(ProtocolError):
            # set-scope op on the single-trace endpoint
            await one(service, payload(pack_paths[:1], "diff_flat_profile"))
        with pytest.raises(ServiceError) as exc:
            await one(service, payload(["/no/such/file.pack"],
                                       "flat_profile"))
        assert exc.value.status == 404

    run(main())


# ---------------------------------------------------------------------------
# HTTP server + client round trip
# ---------------------------------------------------------------------------

def test_http_client_roundtrip(pack_paths, fresh_cache):
    local = Trace.open(pack_paths).query().flat_profile()
    windowed_local = (Trace.open(pack_paths[0]).query()
                      .slice_time(0.0, 30.0, trim="within").time_profile())

    async def main():
        server = await TraceServer(TraceService(), port=0).start()

        def client_work():
            with ServiceClient("127.0.0.1", server.port,
                               tenant="alice") as c:
                assert c.health()["ok"]
                assert {o["name"] for o in c.ops()} >= {"flat_profile",
                                                        "diff_flat_profile"}
                trace = c.open(pack_paths, streaming=True)
                prof = trace.query().flat_profile()
                w = (c.open(pack_paths[0]).query()
                     .slice_time(0.0, 30.0, trim="within").time_profile())
                digest = trace.query().flat_profile(digest_only=True)
                with pytest.raises(RemoteError) as exc:
                    trace.query().run("no_such_op")
                assert exc.value.status == 400
                stats = c.stats()
                return prof, w, digest, stats

        result = await asyncio.to_thread(client_work)
        await server.shutdown(grace=5)
        return result

    prof, w, digest, stats = run(main())
    assert result_digest(prof) == result_digest(local)
    assert result_digest(w) == result_digest(windowed_local)
    assert digest == result_digest(local)
    assert stats["service"]["requests"] >= 4
    assert "alice" in stats["tenants"]


def test_http_setquery_roundtrip(pack_paths, fresh_cache):
    local = TraceSet.open(pack_paths[:2]).query().run("diff_flat_profile")

    async def main():
        server = await TraceServer(TraceService(), port=0).start()

        def client_work():
            with ServiceClient("127.0.0.1", server.port) as c:
                tset = c.open_set(pack_paths[:2])
                return tset.query().diff_flat_profile()

        got = await asyncio.to_thread(client_work)
        await server.shutdown(grace=5)
        return got

    got = run(main())
    assert result_digest(got) == result_digest(local)


# ---------------------------------------------------------------------------
# /diagnose: the diagnostics suite through the service
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pathology_pack(tmp_path_factory):
    from repro.readers.pack import write_pack
    from repro.tracegen import pathology_trace
    tr, gt = pathology_trace("straggler", nprocs=3, iters=12,
                             magnitude=2.0, seed=4)
    p = str(tmp_path_factory.mktemp("diag_serve") / "patho.pack")
    write_pack(tr, p)
    return p, gt


def test_diagnose_endpoint_digest_equals_library(pathology_pack,
                                                 fresh_cache):
    path, gt = pathology_pack
    local = Trace.open(path).query().run("diagnose", cache=False)

    async def main():
        server = await TraceServer(TraceService(), port=0).start()

        def client_work():
            with ServiceClient("127.0.0.1", server.port, tenant="t") as c:
                trace = c.open(path)
                via_endpoint = trace.diagnose()
                via_query = trace.query().diagnose()
                subset = trace.diagnose(detectors=["stragglers"])
                return via_endpoint, via_query, subset

        result = await asyncio.to_thread(client_work)
        await server.shutdown(grace=5)
        return result

    via_endpoint, via_query, subset = run(main())
    assert result_digest(via_endpoint) == result_digest(local)
    assert result_digest(via_query) == result_digest(local)
    assert result_digest(subset) == result_digest(
        Trace.open(path).query().run("diagnose",
                                     detectors=["stragglers"], cache=False))
    # the served frame still names the injected culprit at top-1
    assert str(via_endpoint["detector"][0]) != ""
    f = subset
    assert int(f["process"][0]) == gt.process


def test_diagnose_requests_coalesce_and_cache(pathology_pack, fresh_cache):
    path, _ = pathology_pack

    async def main():
        service = TraceService()
        body = payload([path], "diagnose")
        results = await asyncio.gather(
            *[one(service, dict(body)) for _ in range(5)])
        again = await one(service, dict(body))
        return service, results, again

    service, results, again = run(main())
    # 5 identical in-flight diagnose plans -> 1 execution
    assert service.counters["executed"] == 1
    assert service.counters["coalesced"] == 4
    assert len({r["digest"] for r in results}) == 1
    # and a later identical request is a plan-cache hit
    assert again.get("cached")
    assert again["digest"] == results[0]["digest"]


def test_detector_ops_directly_callable(pathology_pack, fresh_cache):
    """Individual detectors are ordinary registered ops on the service."""
    path, gt = pathology_pack

    async def main():
        service = TraceService()
        return await one(service, payload(
            [path], "stragglers", kwargs={"threshold": 0.1}))

    resp = run(main())
    want = Trace.open(path).query().run("stragglers", threshold=0.1)
    assert resp["digest"] == result_digest(want)


def test_patterns_ops_through_plan_and_service(pack_paths, fresh_cache):
    """activity_series / detect_pattern are registered ops: callable as
    lazy-plan terminals and remotely through the service, with identical
    digests."""
    for op, kwargs in (("activity_series", {"num_bins": 64}),
                       ("detect_pattern", {"num_bins": 32,
                                           "max_patterns": 4})):
        assert registry.get_op(op) is not None, op
        local = Trace.open(pack_paths).query().run(op, **kwargs)

        async def main():
            service = TraceService()
            return await one(service, payload(pack_paths, op,
                                              kwargs=dict(kwargs)))

        resp = run(main())
        assert resp["digest"] == result_digest(local), op
        wire = protocol.decode_value(json.loads(json.dumps(resp["result"])))
        assert result_digest(wire) == result_digest(local), op


def test_breaker_recovers_after_repair(tmp_path, fresh_cache):
    """Circuit-breaker recovery: a pack whose opens trip the breaker keeps
    fast-failing 422 only until the operator repairs it — after the
    cooldown the half-open probe sees the repaired file and the breaker
    closes (it must not serve 422s forever)."""
    import os
    import subprocess
    import sys

    out = tmp_path / "trc"
    big_trace(str(out), nprocs=1, events_per_proc=300, calls_per_iter=20,
              seed=3, format="pack")
    path = sorted(str(p) for p in out.glob("*.pack"))[0]
    good = Trace.open(path).flat_profile()

    # damage: tear off the footer AND the tail of the last chunk group so
    # the strict open raises
    data = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(data[: int(len(data) * 0.6)])

    async def main():
        service = TraceService(breaker_threshold=2, breaker_cooldown=30.0)
        codes = []
        for _ in range(4):
            try:
                await one(service, payload([path], "flat_profile"))
                codes.append("ok")
            except ServiceError as e:
                codes.append((e.status, e.code))
        assert codes[1] == (422, "source_corrupt")    # breaker tripped
        assert codes[3] == (422, "source_corrupt")    # fast-fail, no open
        st = service.handles.stats()
        assert st["breaker_trips"] == 1
        assert st["breaker_fastfails"] >= 1
        assert st["breaker_open"] == 1

        # operator repairs the pack with the CLI, atomically swapping the
        # salvaged rewrite into place
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        fixed = path + ".fixed"
        proc = subprocess.run(
            [sys.executable, os.path.join(repo, "tools", "pack.py"),
             "--repair", path, "-o", fixed],
            capture_output=True, text=True, cwd=repo)
        assert proc.returncode == 0, proc.stderr
        os.replace(fixed, path)

        # before the cooldown lapses the breaker still fast-fails —
        # repair does not bypass the half-open schedule
        try:
            await one(service, payload([path], "flat_profile"))
            probed_early = True
        except ServiceError as e:
            probed_early = False
            assert e.code == "source_corrupt"
        assert not probed_early

        # cooldown lapses (aged directly rather than sleeping it out)
        for b in service.handles._fails.values():
            b["until"] = 0.0
        res = await one(service, payload([path], "flat_profile"))
        assert res["ok"]                              # probe closed it
        res2 = await one(service, payload([path], "flat_profile",
                                          kwargs={}))
        assert res2["ok"]
        assert service.handles.stats()["breaker_open"] == 0
        return res

    res = run(main())
    # the repaired pack serves the salvageable prefix: same op, fewer or
    # equal rows than the pristine original
    assert res["digest"] != "" and len(good) > 0
