"""Runtime tests: checkpoint/restart, fault tolerance, stragglers, data
determinism, serving consistency, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.data import SyntheticLMStream
from repro.distributed.compression import (ErrorFeedbackState, compress_int8,
                                           decompress_int8)
from repro.runtime import FaultInjector, Trainer, TrainLoopConfig
from repro.serving import Request, ServeEngine

# full-matrix jax suites: minutes, not seconds — slow tier only
pytestmark = pytest.mark.slow

CFG = get_smoke_config("pipit-lm-100m")


def test_checkpoint_roundtrip_and_integrity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    mgr.save(5, tree)
    mgr.save(9, tree)
    assert mgr.all_steps() == [5, 9]
    out = mgr.restore(9, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10))
    # corruption detection
    import numpy as np_
    path = os.path.join(str(tmp_path), "step_00000009", "arrays.npz")
    data = dict(np_.load(path))
    data["a"] = data["a"] + 1
    np_.savez(path, **data)
    with pytest.raises(IOError):
        mgr.restore(9, tree)


def test_checkpoint_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.zeros(2)})
    assert mgr.all_steps() == [3, 4]


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    mgr.save(1, {"x": jnp.zeros(2)})
    # fake a crashed write: directory without COMMITTED
    os.makedirs(os.path.join(str(tmp_path), "step_00000007"))
    assert mgr.latest_step() == 1


def test_fault_restart_resumes_from_checkpoint(tmp_path):
    loop = TrainLoopConfig(steps=10, ckpt_every=3, ckpt_dir=str(tmp_path),
                           peak_lr=1e-3, warmup_steps=2)
    tr = Trainer(CFG, loop)
    stream = SyntheticLMStream(CFG.vocab, batch=4, seq_len=16)
    fault = FaultInjector(fail_at_steps=[5])
    out = tr.run(stream, fault=fault)
    stream.close()
    assert out["restarts"] == 1
    assert out["steps"] == 10
    assert all(np.isfinite(out["losses"]))
    # trace recorded the fault + restore
    names = set(tr.tracer.name)
    assert "fault" in names and "restore" in names


def test_straggler_detection():
    loop = TrainLoopConfig(steps=1, straggler_factor=2.0)
    tr = Trainer(CFG, loop)
    flagged = []
    tr.straggler_callback = lambda s, ratio: flagged.append((s, ratio))
    for step, dt in enumerate([1.0, 1.0, 1.0, 1.0, 5.0, 1.0]):
        tr._observe_step_time(step, dt)
    assert tr.straggler_events == 1 and flagged[0][0] == 4


def test_data_determinism_and_seek():
    s1 = SyntheticLMStream(512, batch=4, seq_len=16, seed=7)
    s2 = SyntheticLMStream(512, batch=4, seq_len=16, seed=7)
    b1 = s1.batch_at(12)
    b2 = s2.batch_at(12)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    s1.close()
    s2.close()


def test_loss_decreases_on_structured_stream():
    loop = TrainLoopConfig(steps=50, peak_lr=5e-3, warmup_steps=5)
    tr = Trainer(CFG, loop)
    stream = SyntheticLMStream(CFG.vocab, batch=8, seq_len=32, seed=1)
    out = tr.run(stream)
    stream.close()
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.15, (first, last)


def test_microbatching_equivalence():
    """M=2 gradient accumulation ≈ M=1 on the same global batch."""
    l1 = TrainLoopConfig(steps=1, microbatches=1, peak_lr=1e-3, clip_norm=None)
    l2 = TrainLoopConfig(steps=1, microbatches=2, peak_lr=1e-3, clip_norm=None)
    t1 = Trainer(CFG, l1)
    t2 = Trainer(CFG, l2)
    stream = SyntheticLMStream(CFG.vocab, batch=8, seq_len=16)
    batch = stream.batch_at(0)
    stream.close()
    t1.train_one(batch, 0)
    t2.train_one(batch, 0)
    a = jax.tree_util.tree_leaves(t1.params)
    b = jax.tree_util.tree_leaves(t2.params)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=5e-3)


def test_serving_greedy_matches_forward():
    eng = ServeEngine(CFG, batch=2, cache_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab, 12).astype(np.int32)
               for _ in range(2)]
    reqs = [Request(i, p, max_new_tokens=4) for i, p in enumerate(prompts)]
    done = eng.generate(reqs)
    # oracle: greedy continuation via repeated full forward
    model, params = eng.model, eng.params
    for r, prompt in zip(done, prompts):
        toks = list(prompt)
        for j in range(4):
            logits, _ = model.forward(params, jnp.asarray([toks], jnp.int32))
            nxt = int(jnp.argmax(logits[0, -1, :CFG.vocab]))
            assert nxt == r.out_tokens[j], (r.rid, j)
            toks.append(nxt)


def test_int8_compression_error_feedback():
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 0.01
    q, scale, ef = compress_int8(g)
    deq = decompress_int8(q, scale, g.shape, jnp.float32)
    rel = float(jnp.linalg.norm(deq - g) / jnp.linalg.norm(g))
    assert rel < 0.02                      # int8 block quant ≈ 0.4% typical
    # error feedback: residual + dequantized == original (exactly)
    np.testing.assert_allclose(np.asarray(deq + ef.residual),
                               np.asarray(g), atol=1e-6)
