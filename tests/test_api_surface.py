"""Paper Table I — the 'This work' column, asserted as an API contract.

Every capability the paper claims for Pipit must exist as a callable on the
Trace object (or module-level op); this test is the capability matrix."""

import inspect

from repro.core.trace import Trace


CAPABILITIES = {
    # Table I columns → API entry points
    "events over time": ["plot_timeline"],
    "metrics over time": ["time_profile", "plot_time_profile"],
    "call stack": ["cct", "_match_caller_callee"],
    "flat profile": ["flat_profile"],
    "time profile": ["time_profile"],
    "outlier analysis": ["load_imbalance", "idle_time"],
    "comm matrix": ["comm_matrix", "plot_comm_matrix"],
    "msg size histogram": ["message_histogram", "plot_message_histogram"],
    "pattern detection": ["detect_pattern"],
    "guided multi-run": ["multirun_analysis"],
    "data reduction": ["filter", "slice_time", "filter_processes"],
    "advanced §IV-D": ["calculate_lateness", "critical_path_analysis",
                       "comm_comp_breakdown", "comm_by_process",
                       "comm_over_time"],
}

READERS = ["from_csv", "from_jsonl", "from_chrome", "from_otf2_json",
           "from_hlo", "from_events"]


def test_capability_matrix():
    missing = []
    for cap, names in CAPABILITIES.items():
        for n in names:
            if not hasattr(Trace, n):
                missing.append((cap, n))
    assert not missing, missing


def test_reader_constructors():
    for n in READERS:
        assert hasattr(Trace, n), n
        assert callable(getattr(Trace, n))


def test_metric_and_exc_inc_api():
    assert hasattr(Trace, "calc_inc_metrics")
    assert hasattr(Trace, "calc_exc_metrics")


def test_ops_take_documented_args():
    sig = inspect.signature(Trace.load_imbalance)
    assert "metric" in sig.parameters and "num_processes" in sig.parameters
    sig = inspect.signature(Trace.time_profile)
    assert "num_bins" in sig.parameters
    sig = inspect.signature(Trace.comm_matrix)
    assert "output" in sig.parameters       # size | count (paper §IV-C)
