"""pipitpack columnar store: round-trip fidelity, sidecar skip, index
pushdown, parallel units, plan-cache content identity, conversion paths."""

import os
import shutil

import numpy as np
import pytest

from repro import tracegen as tg
from repro.core import plancache, structure
from repro.core.constants import (DEPTH, DERIVED_COLUMNS, ET, EXC, INC,
                                  MATCH, MSG_SIZE, NAME, PARENT, PARTNER,
                                  PROC, TAG, THREAD, TS)
from repro.core.frame import EventFrame, concat
from repro.core.registry import RowSpan, get_reader, sniff_format
from repro.core.trace import Trace
from repro.readers import pack as packmod
from repro.readers.jsonl import write_jsonl
from repro.readers.pack import (PackWriter, io_stats, plan_units_pack,
                                read_footer, read_pack, reset_io_stats,
                                write_pack)
from repro.testing.hyp import given, settings, st

BASE_COLS = (TS, ET, NAME, PROC, THREAD, MSG_SIZE, PARTNER, TAG)


def base_equal(a, b, context=""):
    """Base event columns of two traces/frames are value-identical.

    Optional columns are normalized before comparing: whole-file reads drop
    an all-zero Thread / absent message triplet, chunked reads synthesize
    them — both render the same logical events.
    """
    ea = getattr(a, "events", a)
    eb = getattr(b, "events", b)
    assert len(ea) == len(eb), f"{context}: {len(ea)} vs {len(eb)} rows"
    n = len(ea)
    defaults = {THREAD: np.zeros(n), MSG_SIZE: np.full(n, np.nan),
                PARTNER: np.full(n, -1.0), TAG: np.zeros(n)}
    for c in BASE_COLS:
        va = ea[c] if c in ea else defaults[c]
        vb = eb[c] if c in eb else defaults[c]
        if np.asarray(va).dtype.kind in "UO" or np.asarray(vb).dtype.kind in "UO":
            assert list(map(str, va)) == list(map(str, vb)), f"{context}: {c}"
        else:
            np.testing.assert_array_equal(np.asarray(va, np.float64),
                                          np.asarray(vb, np.float64),
                                          err_msg=f"{context}: {c}")


@pytest.fixture()
def disk_trace(tmp_path):
    """A trace that went through disk once (integer-ns timestamps), plus
    its jsonl path — the canonical on-disk reference."""
    t = tg.gol(nprocs=3, iters=4, seed=7)
    j = str(tmp_path / "ref.jsonl")
    write_jsonl(t, j)
    return Trace.open(j), j


# ---------------------------------------------------------------------------
# round trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gen,kw", [
    (tg.gol, dict(nprocs=3, iters=4, seed=7)),
    (tg.tortuga, dict(nprocs=4, iters=2, seed=1)),
    (tg.loimos, dict(nprocs=8, iters=2, seed=3)),
])
def test_roundtrip_any_readable_trace(gen, kw, tmp_path):
    """any readable trace → pack → identical frame AND identical structure
    arrays to what reopening-and-deriving the text form produces."""
    j = str(tmp_path / "t.jsonl")
    write_jsonl(gen(**kw), j)
    ref = Trace.open(j)
    p = str(tmp_path / "t.pack")
    ref.save_pack(p, chunk_rows=64)
    got = read_pack(p)
    base_equal(ref, got, "pack roundtrip")
    # structure: the sidecar must equal a fresh derivation on the reference
    ref._ensure_structure()
    assert got._structured
    for col in (MATCH, DEPTH, PARENT):
        np.testing.assert_array_equal(
            np.asarray(ref.events.column(col), np.int64),
            np.asarray(got.events.column(col), np.int64), err_msg=col)
    for col in (INC, EXC):
        np.testing.assert_array_equal(
            np.asarray(ref.events.column(col), np.float64),
            np.asarray(got.events.column(col), np.float64), err_msg=col)


def test_roundtrip_trace_without_messages(tmp_path):
    t = tg.kripke_sweep(nprocs=4, iters=2, seed=0)
    j = str(tmp_path / "k.jsonl")
    write_jsonl(t, j)
    ref = Trace.open(j)
    p = str(tmp_path / "k.pack")
    ref.save_pack(p)
    got = read_pack(p)
    base_equal(ref, got, "no-message roundtrip")
    # chunked reads still emit the uniform column set
    ch = next(get_reader("pack").iter_chunks(p, 50, None))
    for c in (THREAD, MSG_SIZE, PARTNER, TAG):
        assert c in ch


def test_chunked_roundtrip_any_chunk_size(disk_trace, tmp_path):
    ref, _ = disk_trace
    p = str(tmp_path / "t.pack")
    ref.save_pack(p, chunk_rows=50)
    for rows in (7, 64, 10_000):
        chunks = list(get_reader("pack").iter_chunks(p, rows, None))
        assert all(len(c) <= rows for c in chunks)
        got = concat([c.drop(*DERIVED_COLUMNS) for c in chunks])
        base_equal(ref, got, f"chunked({rows})")


@settings(max_examples=8, deadline=None)
@given(chunk_rows=st.integers(min_value=3, max_value=200),
       read_rows=st.integers(min_value=3, max_value=200))
def test_roundtrip_property_chunking_invariance(chunk_rows, read_rows):
    """Property: footer chunking × read chunking never changes content."""
    import tempfile
    t = tg.gol(nprocs=2, iters=3, seed=11)
    with tempfile.TemporaryDirectory() as d:
        j = os.path.join(d, "t.jsonl")
        write_jsonl(t, j)
        ref = Trace.open(j)
        p = os.path.join(d, "t.pack")
        ref.save_pack(p, chunk_rows=chunk_rows)
        base_equal(ref, read_pack(p), "whole")
        chunks = list(get_reader("pack").iter_chunks(p, read_rows, None))
        base_equal(ref, concat([c.drop(*DERIVED_COLUMNS) for c in chunks]),
                   "chunked")


def test_streaming_conversion_equals_eager(disk_trace, tmp_path):
    ref, j = disk_trace
    pe = str(tmp_path / "eager.pack")
    ps = str(tmp_path / "stream.pack")
    ref.save_pack(pe, chunk_rows=64)
    Trace.open(j, streaming=True, chunk_rows=39,
               cache=False).save_pack(ps, chunk_rows=64)
    # the name tables may intern in different orders (sorted vs first-seen)
    # — the logical events and the derived analyses must be identical
    base_equal(read_pack(pe), read_pack(ps), "streaming conversion")
    np.testing.assert_array_equal(
        np.asarray(read_pack(pe).flat_profile()["time.exc"]),
        np.asarray(read_pack(ps).flat_profile()["time.exc"]))


def test_multi_append_equals_single(disk_trace, tmp_path):
    ref, _ = disk_trace
    p1 = str(tmp_path / "one.pack")
    pn = str(tmp_path / "many.pack")
    ref.save_pack(p1, chunk_rows=64)
    w = PackWriter(pn, chunk_rows=64)
    ev = ref.events
    for lo in range(0, len(ev), 37):
        w.append(ev.take(np.arange(lo, min(lo + 37, len(ev)))))
    w.finish(sidecar=True)
    assert read_footer(p1)["content_id"] == read_footer(pn)["content_id"]


def test_float_timestamps_quantize_consistently(tmp_path):
    """Float-ns sources (in-memory tracegen, HLO timelines) quantize to
    integer ns at write time; the sidecar and every reopened analysis must
    match a fresh derivation on the quantized events — never the float
    originals."""
    t = tg.gol(nprocs=2, iters=3, seed=9)  # float timestamps
    assert np.asarray(t.events[TS]).dtype.kind == "f"
    p = str(tmp_path / "f.pack")
    t.save_pack(p)
    got = read_pack(p)
    ev = t.events.drop(*DERIVED_COLUMNS)
    ev[TS] = np.asarray(ev[TS], np.int64)  # the storage quantization
    want = Trace(ev)
    np.testing.assert_array_equal(np.asarray(got.events[TS], np.int64),
                                  np.asarray(want.events[TS], np.int64))
    np.testing.assert_array_equal(
        np.asarray(want.flat_profile()["time.exc"]),
        np.asarray(got.flat_profile()["time.exc"]))


def test_packwriter_context_manager_aborts_partial(tmp_path):
    p = str(tmp_path / "x.pack")
    with pytest.raises(RuntimeError, match="boom"):
        with PackWriter(p) as w:
            w.append(tg.gol(nprocs=2, iters=1).events)
            raise RuntimeError("boom")
    assert not os.path.exists(p), "aborted write must not land"
    assert not any(f.startswith(".pack_") for f in os.listdir(tmp_path)), \
        "spool dir must be cleaned up"


def test_sniff_and_auto_open(disk_trace, tmp_path):
    ref, _ = disk_trace
    p = str(tmp_path / "weird_name.bin")  # no .pack extension
    ref.save_pack(p)
    assert sniff_format(p) == "pack"
    base_equal(ref, Trace.open(p, format="auto"), "auto")


def test_not_a_pack_raises(tmp_path):
    bad = str(tmp_path / "x.pack")
    with open(bad, "w") as f:
        f.write("this is not a pack\n")
    with pytest.raises(ValueError, match="not a pipitpack"):
        read_footer(bad)
    truncated = str(tmp_path / "y.pack")
    with open(truncated, "wb") as f:
        f.write(packmod.MAGIC + b"\x01\x02\x03")
    with pytest.raises(ValueError):
        read_footer(truncated)


def test_int32_overflow_refused(tmp_path):
    ev = EventFrame({TS: np.asarray([0, 1], np.int64),
                     ET: np.asarray(["Enter", "Leave"], object),
                     NAME: np.asarray(["f", "f"], object),
                     PROC: np.asarray([2 ** 40, 2 ** 40], np.int64)})
    with pytest.raises(ValueError, match="proc.*range"):
        write_pack(ev, str(tmp_path / "o.pack"))


# ---------------------------------------------------------------------------
# structure sidecar provably skips derive_structure
# ---------------------------------------------------------------------------

def test_sidecar_skips_derive_eager(disk_trace, tmp_path):
    ref, _ = disk_trace
    p = str(tmp_path / "t.pack")
    ref.save_pack(p)
    n0 = structure.DERIVE_CALLS
    t = Trace.open(p)
    prof = t.flat_profile()
    assert structure.DERIVE_CALLS == n0, "sidecar reopen must not derive"
    ref2 = Trace.open(p, sidecar=False)
    assert not ref2._structured
    prof2 = ref2.flat_profile()
    assert structure.DERIVE_CALLS == n0 + 1, "no-sidecar open derives once"
    np.testing.assert_array_equal(np.asarray(prof["time.exc"]),
                                  np.asarray(prof2["time.exc"]))


def test_sidecar_skips_derive_streaming(disk_trace, tmp_path):
    ref, j = disk_trace
    p = str(tmp_path / "t.pack")
    ref.save_pack(p, chunk_rows=40)
    n0 = structure.DERIVE_CALLS
    st = Trace.open(p, streaming=True, chunk_rows=64, cache=False)
    got = st.flat_profile()
    assert structure.DERIVE_CALLS == n0, \
        "pack streaming with sidecar must not derive per chunk"
    want = Trace.open(j, streaming=True, chunk_rows=64,
                      cache=False).flat_profile()
    assert structure.DERIVE_CALLS > n0  # jsonl streaming derives per chunk
    np.testing.assert_array_equal(np.asarray(want["time.exc"]),
                                  np.asarray(got["time.exc"]))
    assert list(map(str, want[NAME])) == list(map(str, got[NAME]))


def test_streaming_filtered_parity_strips_stale_structure(disk_trace,
                                                          tmp_path):
    """A row-dropping plan invalidates chunk-localized sidecar columns —
    results must still match jsonl streaming exactly (mask_frames strips,
    the stitcher re-derives)."""
    from repro.core.filters import Filter
    ref, j = disk_trace
    p = str(tmp_path / "t.pack")
    ref.save_pack(p, chunk_rows=40)
    f = Filter(NAME, "not-in", ["exchange_halo()"])
    got = (Trace.open(p, streaming=True, chunk_rows=64, cache=False)
           .query().filter(f).flat_profile())
    want = (Trace.open(j, streaming=True, chunk_rows=64, cache=False)
            .query().filter(f).flat_profile())
    np.testing.assert_array_equal(np.asarray(want["time.exc"]),
                                  np.asarray(got["time.exc"]))
    assert list(map(str, want[NAME])) == list(map(str, got[NAME]))


# ---------------------------------------------------------------------------
# index pushdown provably skips chunks
# ---------------------------------------------------------------------------

def test_pushdown_time_window_skips_chunks(disk_trace, tmp_path):
    ref, _ = disk_trace
    p = str(tmp_path / "t.pack")
    ref.save_pack(p, chunk_rows=20)
    n_chunks = len(read_footer(p)["chunks"])
    assert n_chunks >= 4
    st = Trace.open(p, streaming=True, chunk_rows=64, cache=False)
    ts = np.asarray(ref.events[TS], np.float64)
    t0 = float(ts.min())
    t1 = t0 + (float(ts.max()) - t0) * 0.1
    reset_io_stats()
    got = st.query().slice_time(t0, t1, trim="within").flat_profile()
    io = io_stats()
    assert io["chunks_skipped"] > 0, "narrow window must skip chunks"
    assert io["chunks_read"] < n_chunks
    assert io["chunks_read"] + io["chunks_skipped"] == n_chunks
    want = (ref.query().slice_time(t0, t1, trim="within")
            .collect().flat_profile())
    np.testing.assert_array_equal(np.asarray(want["time.exc"]),
                                  np.asarray(got["time.exc"]))


def test_pushdown_process_restriction_skips_chunks(tmp_path):
    """Per-proc event runs land in different chunks of one pack; a proc
    restriction skips the chunks whose proc set cannot match."""
    t = tg.gol(nprocs=3, iters=4, seed=7)
    j = str(tmp_path / "t.jsonl")
    write_jsonl(t, j)
    ref = Trace.open(j)
    # sort by process so chunks have distinct proc sets
    ev = ref.events.sort_by([PROC, TS])
    p = str(tmp_path / "byproc.pack")
    write_pack(ev, p, chunk_rows=20)
    n_chunks = len(read_footer(p)["chunks"])
    st = Trace.open(p, streaming=True, chunk_rows=64, cache=False)
    reset_io_stats()
    got = st.query().restrict_processes([0]).flat_profile()
    io = io_stats()
    assert io["chunks_skipped"] > 0
    assert io["chunks_read"] < n_chunks
    want = (Trace(ev).query().restrict_processes([0]).collect()
            .flat_profile())
    np.testing.assert_array_equal(np.asarray(want["time.exc"]),
                                  np.asarray(got["time.exc"]))


def test_shard_hint_from_footer(tmp_path):
    from repro.readers.parallel import select_shards
    paths = []
    for pid in range(3):
        t = tg.gol(nprocs=3, iters=2, seed=1).filter_processes([pid])
        pth = str(tmp_path / f"part{pid}.pack")  # name carries no rank hint
        t.save_pack(pth)
        paths.append(pth)
    kept = select_shards(paths, "pack", procs={1})
    assert kept == [paths[1]]


# ---------------------------------------------------------------------------
# parallel work units
# ---------------------------------------------------------------------------

def test_plan_units_partition_rows(disk_trace, tmp_path):
    ref, _ = disk_trace
    p = str(tmp_path / "t.pack")
    ref.save_pack(p, chunk_rows=16)
    rows = read_footer(p)["rows"]
    units = plan_units_pack(p, 4)
    assert units and all(isinstance(u, RowSpan) for u in units)
    assert units[0].lo == 0 and units[-1].hi == rows
    for a, b in zip(units, units[1:]):
        assert a.hi == b.lo
    # unit boundaries align to footer chunks
    edges = {c["lo"] for c in read_footer(p)["chunks"]} | {rows}
    for u in units:
        assert u.lo in edges and u.hi in edges
    # single chunk / single unit → unsplittable
    assert plan_units_pack(p, 1) is None


def test_parallel_units_byte_identical(disk_trace, tmp_path):
    from repro.core import executor, registry
    from repro.core.streaming import StreamingTrace
    ref, _ = disk_trace
    p = str(tmp_path / "t.pack")
    ref.save_pack(p, chunk_rows=16)
    serial = Trace.open(p, streaming=True, chunk_rows=64,
                        cache=False).flat_profile()
    h = StreamingTrace(p, chunk_rows=64, cache=False)
    spec = registry.get_op("flat_profile")
    for n_units in (2, 3, 5):
        r = executor.execute_parallel(h, (), spec, (), {}, spec.streaming(),
                                      n_units=n_units, use_pool=False)
        np.testing.assert_array_equal(np.asarray(serial["time.exc"]),
                                      np.asarray(r["time.exc"]))
        assert list(map(str, serial[NAME])) == list(map(str, r[NAME]))


def test_unit_frames_rowspan_covers_exactly(disk_trace, tmp_path):
    from repro.core.executor import _unit_frames
    ref, _ = disk_trace
    p = str(tmp_path / "t.pack")
    ref.save_pack(p, chunk_rows=16)
    units = plan_units_pack(p, 3)
    frames = [f.drop(*DERIVED_COLUMNS) for u in units
              for f in _unit_frames(u, "pack", 29, None, {})]
    base_equal(ref, concat(frames), "rowspan partition")


# ---------------------------------------------------------------------------
# plan-result cache: content identity
# ---------------------------------------------------------------------------

def test_plan_cache_keys_pack_by_content_id(disk_trace, tmp_path):
    ref, _ = disk_trace
    p = str(tmp_path / "a.pack")
    ref.save_pack(p)
    plancache.clear()
    r1 = Trace.open(p, streaming=True, chunk_rows=64).flat_profile()
    hits0 = plancache.stats()["hits"]
    # a byte-identical copy at another path/mtime: same content id → hit
    p2 = str(tmp_path / "b.pack")
    shutil.copy(p, p2)
    r2 = Trace.open(p2, streaming=True, chunk_rows=64).flat_profile()
    assert plancache.stats()["hits"] == hits0 + 1
    assert r2 is r1
    # different content at the same path → miss
    ref.query().restrict_processes([0]).collect().save_pack(p2)
    r3 = Trace.open(p2, streaming=True, chunk_rows=64).flat_profile()
    assert plancache.stats()["hits"] == hits0 + 1
    assert r3 is not r1
    plancache.clear()


def test_content_id_of_non_pack_is_none(tmp_path):
    j = str(tmp_path / "x.jsonl")
    with open(j, "w") as f:
        f.write('{"ts": 1, "et": "Enter", "name": "a", "proc": 0}\n')
    assert packmod.content_id(j) is None


# ---------------------------------------------------------------------------
# generation / materialization integration
# ---------------------------------------------------------------------------

def test_big_trace_pack_equals_jsonl(tmp_path):
    from repro.tracegen import big_trace
    pj = big_trace(str(tmp_path / "j"), nprocs=2, events_per_proc=2000,
                   format="jsonl")
    pp = big_trace(str(tmp_path / "p"), nprocs=2, events_per_proc=2000,
                   format="pack")
    sj = Trace.open(pj, streaming=True, cache=False)
    sp = Trace.open(pp, streaming=True, cache=False)
    fj, fp = sj.flat_profile(), sp.flat_profile()
    np.testing.assert_array_equal(np.asarray(fj["time.exc"]),
                                  np.asarray(fp["time.exc"]))
    assert list(map(str, fj[NAME])) == list(map(str, fp[NAME]))
    np.testing.assert_array_equal(sj.comm_matrix(cache=False),
                                  sp.comm_matrix(cache=False))
    # pack shards carry sidecars + footers
    for p in pp:
        f = read_footer(p)
        assert f["sidecar"] and f["chunks"]


def test_materialize_and_multi_shard_open(tmp_path):
    """Eager multi-shard pack open strips per-shard sidecars before the
    merged sort (indices would be garbage) and still analyzes correctly."""
    from repro.tracegen import big_trace
    pp = big_trace(str(tmp_path / "p"), nprocs=2, events_per_proc=1500,
                   format="pack")
    merged = Trace.open(pp)  # read_parallel path
    assert MATCH not in merged.events
    st = Trace.open(pp, streaming=True, cache=False)
    np.testing.assert_array_equal(
        np.asarray(merged.flat_profile()["time.exc"]),
        np.asarray(st.flat_profile()["time.exc"]))
    mat = st.materialize()
    assert MATCH not in mat.events or mat._structured
    assert len(mat) == len(merged)


def test_verify_key_includes_committed_group_count(tmp_path):
    """Append workloads can grow a pack within one stat granule: the
    verified-clean key must change whenever the committed-group count
    does, or a CRC sweep of the short file would vouch for bytes it never
    read (regression for the append/finalize protocol)."""
    p = str(tmp_path / "a.pack")
    w = PackWriter.open_append(p, fsync=False)
    ev = tg.gol(nprocs=2, iters=2, seed=3).events
    w.append(ev)
    w.commit()
    st = os.stat(p)
    k2 = packmod._verify_key(p, st, 2)
    k3 = packmod._verify_key(p, st, 3)
    assert k2 != k3                      # same stat, different prefix
    packmod._mark_verified(k2, "chunks")
    assert "chunks" not in packmod._VERIFIED_CLEAN.get(k3, ())

    # behavioral: finalize, verified read, then append-resume + refinalize
    # — the re-read must sweep (and see) the new group, not reuse the old
    # verified entry
    w.finalize(sidecar=False)
    rows1 = len(read_pack(p).events)
    w2 = PackWriter.open_append(p, fsync=False)
    w2.append(ev)
    w2.commit()
    w2.finalize(sidecar=False)
    assert len(read_pack(p).events) == 2 * rows1
