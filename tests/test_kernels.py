"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import (flash_attention_gqa, router_topk,
                               time_profile_matrix)
from repro.models.attention import chunked_attention

# full-matrix jax suites: minutes, not seconds — slow tier only
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("B,S,H,KVH,D", [
    (1, 64, 2, 1, 32), (2, 128, 4, 2, 64), (1, 192, 4, 4, 128),
    (1, 256, 8, 2, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, S, H, KVH, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KVH, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KVH, D), dtype)
    out = flash_attention_gqa(q, k, v, bq=64, bk=64)
    want = chunked_attention(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window,prefix", [(16, 0), (32, 8), (None, 0)])
def test_flash_attention_masks(window, prefix):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 160, 2, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 160, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 160, 2, 32), jnp.float32)
    out = flash_attention_gqa(q, k, v, window=window, prefix_len=prefix,
                              bq=64, bk=32)
    want = chunked_attention(q, k, v, window=window, prefix_len=prefix)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 96, 2, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 96, 1, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 96, 1, 32), jnp.float32)
    out = flash_attention_gqa(q, k, v, causal=False, bq=32, bk=32)
    want = chunked_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("N,F,NB", [(100, 7, 16), (1000, 13, 64), (53, 3, 8)])
def test_time_bin_kernel(N, F, NB):
    key = jax.random.PRNGKey(0)
    s = jax.random.uniform(key, (N,)) * 100
    e = s + jax.random.uniform(jax.random.PRNGKey(1), (N,)) * 10
    f = jax.random.randint(jax.random.PRNGKey(2), (N,), 0, F)
    out = time_profile_matrix(s, e, f, n_funcs=F, n_bins=NB, t0=0.0, t1=110.0)
    want = ref.time_bin_ref(s, e, f, n_funcs=F, n_bins=NB, t0=0.0, t1=110.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-3)
    # conservation: total binned time == total clipped durations
    assert float(np.asarray(out).sum()) == pytest.approx(
        float(np.asarray(want).sum()))


@pytest.mark.parametrize("T,E,k", [(64, 8, 2), (777, 64, 4), (32, 128, 8)])
def test_topk_gating_kernel(T, E, k):
    lg = jax.random.normal(jax.random.PRNGKey(0), (T, E), jnp.float32)
    idx, g = router_topk(lg, k)
    ri, rg = ref.topk_gating_ref(lg, k)
    assert (np.asarray(idx) == np.asarray(ri)).all()
    np.testing.assert_allclose(np.asarray(g), np.asarray(rg), atol=1e-6)
    np.testing.assert_allclose(np.asarray(g).sum(-1), 1.0, atol=1e-5)


def test_time_profile_pallas_backend_matches_numpy():
    """Trace.time_profile(backend='pallas') routes through the Pallas kernel
    and must equal the exact NumPy sweep."""
    from repro import tracegen as tg
    t = tg.tortuga(nprocs=4, iters=2)
    a = t.time_profile(num_bins=16)
    b = t.time_profile(num_bins=16, backend="pallas")
    cols = [c for c in a.columns if c not in ("bin_start", "bin_end")]
    assert cols == [c for c in b.columns if c not in ("bin_start", "bin_end")]
    for c in cols:
        np.testing.assert_allclose(np.asarray(b[c]), np.asarray(a[c]),
                                   rtol=1e-5, atol=1e-3)
