"""End-to-end system test: the paper's Fig. 1 trace through every §IV op."""

import numpy as np
import pytest

from repro.core import EventFrame, Filter, Trace
from repro.core.constants import (ET, EXC, INC, MSG_SIZE, NAME, PARTNER, PROC,
                                  TAG, TS)


def fig1_trace(nprocs=2):
    rows = []

    def add(ts, et, name, proc, **kw):
        rows.append(dict(ts=ts, et=et, name=name, proc=proc, **kw))

    for p in range(nprocs):
        add(0, "Enter", "main()", p)
        add(1, "Enter", "foo()", p)
        if p == 0:
            add(3, "Enter", "MPI_Send", p)
            add(4, "MpiSend", "MpiSend", p, partner=1, size=1000, tag=0)
            add(5, "Leave", "MPI_Send", p)
        else:
            add(3, "Enter", "MPI_Recv", p)
            add(5.8, "MpiRecv", "MpiRecv", p, partner=0, size=1000, tag=0)
            add(6, "Leave", "MPI_Recv", p)
        add(8, "Enter", "baz()", p)
        add(18, "Leave", "baz()", p)
        add(25, "Leave", "foo()", p)
        add(100, "Leave", "main()", p)
    ev = EventFrame({
        TS: np.array([r["ts"] for r in rows], np.float64),
        ET: np.array([r["et"] for r in rows]),
        NAME: np.array([r["name"] for r in rows]),
        PROC: np.array([r["proc"] for r in rows], np.int64),
        PARTNER: np.array([r.get("partner", -1) for r in rows], np.int64),
        MSG_SIZE: np.array([r.get("size", np.nan) for r in rows], np.float64),
        TAG: np.array([r.get("tag", 0) for r in rows], np.int64),
    })
    return Trace.from_events(ev, label="fig1")


def test_inc_exc_metrics():
    t = fig1_trace()
    t.calc_exc_metrics()
    ev = t.events
    inc = np.asarray(ev.column(INC))
    exc = np.asarray(ev.column(EXC))
    enters = ev.cat(ET).mask_eq("Enter")
    main_rows = np.nonzero(enters & ev.cat(NAME).mask_eq("main()"))[0]
    assert np.allclose(inc[main_rows], 100)
    assert np.allclose(exc[main_rows], 76)    # 100 − foo()'s [1, 25]
    foo_rows = np.nonzero(enters & ev.cat(NAME).mask_eq("foo()"))[0]
    assert np.allclose(inc[foo_rows], 24)


def test_flat_profile_totals():
    t = fig1_trace()
    fp = t.flat_profile()
    d = dict(zip(fp[NAME], fp["time.exc"]))
    assert d["main()"] == pytest.approx(152)   # 2 procs × 76
    assert d["baz()"] == pytest.approx(20)


def test_time_profile_conserves_time():
    t = fig1_trace()
    tp = t.time_profile(num_bins=8)
    func_cols = [c for c in tp.columns if c not in ("bin_start", "bin_end")]
    total = sum(np.asarray(tp[c]).sum() for c in func_cols)
    assert total == pytest.approx(200)         # 2 procs × 100 ns span


def test_comm_ops():
    t = fig1_trace()
    cm = t.comm_matrix()
    assert cm[0, 1] == 1000 and cm[1, 0] == 0
    counts, _ = t.message_histogram(bins=4)
    assert counts.sum() == 1
    byp = t.comm_by_process()
    assert byp["sent"][0] == 1000 and byp["received"][1] == 1000
    cmn = t.comm_matrix(output="count")
    assert cmn[0, 1] == 1


def test_filter_and_slice():
    t = fig1_trace()
    sub = t.filter(Filter(NAME, "==", "baz()"))
    assert len(sub) == 4
    assert len(t.slice_time(0, 6)) > 0
    assert t.filter_processes([0]).num_processes == 1


def test_cct_paths():
    t = fig1_trace()
    cct = t.cct
    names = {n.name for n in cct.nodes}
    assert {"main()", "foo()", "baz()"} <= names
    baz = [n for n in cct.nodes if n.name == "baz()"]
    assert len(baz) == 1                       # unified across processes
    assert baz[0].path() == ["main()", "foo()", "baz()"]


def test_idle_and_imbalance():
    t = fig1_trace()
    idle = t.idle_time()
    d = dict(zip(idle[PROC].tolist(), idle["idle_time"]))
    assert d[1] == pytest.approx(3)            # MPI_Recv span
    li = t.load_imbalance(num_processes=1)
    assert "time.exc.imbalance" in li.columns
