"""Distribution-layer tests: logical→physical spec mapping, per-arch rules,
and an 8-virtual-device pjit equivalence check (run in a subprocess so the
forced device count never leaks into other tests)."""

import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import (DEFAULT_RULES, logical_to_spec,
                                        rules_for)

# full-matrix jax suites: minutes, not seconds — slow tier only
pytestmark = pytest.mark.slow


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


M = FakeMesh({"data": 16, "model": 16})
MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_logical_to_spec_basics():
    s = logical_to_spec(("embed", "heads"), DEFAULT_RULES, M, (1024, 1024))
    assert s == P("data", "model")
    # missing pod axis silently dropped on single-pod mesh
    s = logical_to_spec(("embed",), DEFAULT_RULES, M, (1024,))
    assert s == P("data")
    s = logical_to_spec(("embed",), DEFAULT_RULES, MP, (1024,))
    assert s == P(("pod", "data"))


def test_divisibility_drops_axis():
    # 60 experts don't divide 16
    s = logical_to_spec(("experts", "embed"), DEFAULT_RULES, M, (60, 2048))
    assert s[0] is None
    # hymba 25-head flat dim divides nothing
    s = logical_to_spec(("heads",), DEFAULT_RULES, M, (25,))
    assert s == P()


def test_no_axis_reuse_across_dims():
    s = logical_to_spec(("embed", "batch"), DEFAULT_RULES, M, (1024, 1024))
    # both want "data" — only the first gets it
    assert s == P("data")


def test_rules_for_archs():
    hymba = rules_for(get_config("hymba-1.5b"), M)
    assert hymba.as_dict()["heads"] is None
    q2 = rules_for(get_config("qwen2-moe-a2.7b"), M)
    assert q2.as_dict()["experts"] is None      # 60 % 16 != 0
    assert q2.as_dict()["expert_mlp"] == "model"
    q3 = rules_for(get_config("qwen3-moe-235b-a22b"), M)
    assert q3.as_dict()["experts"] == "model"   # 128 % 16 == 0 → true EP
    g = rules_for(get_config("gemma3-27b"), M, long_context=True)
    assert g.as_dict()["kv"] == "model"         # 16 KV heads shard
    h = rules_for(get_config("hymba-1.5b"), M, long_context=True)
    assert h.as_dict()["kv_seq"] == "model"     # 5 KV heads → shard seq


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.distributed.sharding import (activation_sharding, rules_for,
                                            spec_tree)
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_smoke_config("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": labels}
    loss_1dev = float(model.loss(params, batch))

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = rules_for(cfg, mesh)
    specs = spec_tree(model.param_defs(), rules, mesh)
    pshard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
    bshard = {"tokens": NamedSharding(mesh, P("data")),
              "labels": NamedSharding(mesh, P("data"))}

    def loss_fn(p, b):
        with activation_sharding(mesh, rules):
            return model.loss(p, b)
    with mesh:
        f = jax.jit(loss_fn, in_shardings=(pshard, bshard))
        loss_8dev = float(f(params, batch))
    err = abs(loss_8dev - loss_1dev)
    assert err < 1e-4, (loss_1dev, loss_8dev)
    print("SPMD_EQUIV_OK", loss_1dev, loss_8dev)
""")


@pytest.mark.slow
def test_pjit_loss_matches_single_device():
    r = subprocess.run([sys.executable, "-c", SUBPROC], capture_output=True,
                       text=True, cwd=str(__import__("pathlib").Path(
                           __file__).parent.parent))
    assert "SPMD_EQUIV_OK" in r.stdout, r.stdout + r.stderr


SUBPROC_INT8DP = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.compression import pairwise_compressed_mean
    from repro.distributed.sharding import shard_map_compat

    mesh = jax.make_mesh((2,), ("pod",))
    g0 = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 0.01
    g1 = jax.random.normal(jax.random.PRNGKey(1), (1000,)) * 0.01
    g = jnp.stack([g0, g1])

    def f(g):
        def per_pod(g):
            out, _ = pairwise_compressed_mean(g[0], "pod", 2)
            return out[None]
        return shard_map_compat(per_pod, mesh, P("pod"), P("pod"))(g)
    with mesh:
        out = jax.jit(f, in_shardings=NamedSharding(mesh, P("pod")))(g)
    want = np.asarray((g0 + g1) / 2)
    got = np.asarray(out[0])
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 0.02, rel          # int8 wire quantization error budget
    # the wire format must be int8: look for an s8 ppermute in the HLO
    txt = jax.jit(f, in_shardings=NamedSharding(mesh, P("pod"))).lower(g).compile().as_text()
    assert any("collective-permute" in l and "s8[" in l for l in txt.splitlines())
    print("INT8DP_OK", rel)
""")


@pytest.mark.slow
def test_pairwise_compressed_mean_int8_wire():
    """The cross-pod gradient mean uses an int8 wire format (ppermute of s8)
    and stays within the quantization error budget."""
    r = subprocess.run([sys.executable, "-c", SUBPROC_INT8DP],
                       capture_output=True, text=True,
                       cwd=str(__import__("pathlib").Path(
                           __file__).parent.parent))
    assert "INT8DP_OK" in r.stdout, r.stdout + r.stderr
