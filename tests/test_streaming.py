"""Out-of-core streaming engine tests (tentpole of the streaming PR).

The contract under test: for every op with a combinable streaming form,
``Trace.open(path, streaming=True)`` produces results identical to the
fully materialized execution — at any chunk size, with plan selections
fused per chunk, across shards with process pushdown, and for TraceSet
comparison ops over streaming members.  Ops without a streaming form must
fail loudly with the escape hatches spelled out.
"""

import os

import numpy as np
import pytest

from repro import tracegen
from repro.core.constants import EXC, INC, NAME, PROC
from repro.core.diff import TraceSet
from repro.core.filters import Filter, time_window_filter
from repro.core.frame import optimize_dtypes
from repro.core.streaming import StreamingTrace, StreamingUnsupported
from repro.core.trace import Trace
from repro.readers.jsonl import write_jsonl
from repro.readers.parallel import split_jsonl_by_process


def assert_frames_equal(a, b, tol=False, context=""):
    assert a.columns == b.columns, f"{context}: {a.columns} vs {b.columns}"
    for c in a.columns:
        va, vb = a[c], b[c]
        if np.asarray(va).dtype.kind in "UO":
            assert list(map(str, va)) == list(map(str, vb)), \
                f"{context}: column {c}"
        elif tol:
            np.testing.assert_allclose(np.asarray(va, float),
                                       np.asarray(vb, float),
                                       rtol=1e-9, atol=1e-6,
                                       err_msg=f"{context}: column {c}")
        else:
            np.testing.assert_array_equal(np.asarray(va), np.asarray(vb),
                                          err_msg=f"{context}: column {c}")


@pytest.fixture(scope="module")
def trace_file(tmp_path_factory):
    d = tmp_path_factory.mktemp("stream")
    t = tracegen.tortuga(nprocs=4, iters=4, seed=3)
    path = str(d / "tortuga.jsonl")
    write_jsonl(t, path)
    return path


@pytest.fixture(scope="module")
def mem(trace_file):
    return Trace.open(trace_file)


@pytest.mark.parametrize("chunk_rows", [1, 17, 251, 10 ** 6])
def test_flat_profile_identical(trace_file, mem, chunk_rows):
    st = Trace.open(trace_file, streaming=True, chunk_rows=chunk_rows)
    a = mem.flat_profile(metrics=[EXC, INC])
    b = st.flat_profile(metrics=[EXC, INC])
    assert_frames_equal(a, b, context=f"chunk={chunk_rows}")


@pytest.mark.parametrize("chunk_rows", [17, 251])
def test_per_process_and_imbalance_identical(trace_file, mem, chunk_rows):
    st = Trace.open(trace_file, streaming=True, chunk_rows=chunk_rows)
    assert_frames_equal(mem.flat_profile(per_process=True),
                        st.flat_profile(per_process=True))
    assert_frames_equal(mem.load_imbalance(), st.load_imbalance())
    assert_frames_equal(mem.idle_time(), st.idle_time())


def test_time_profile_close(trace_file, mem):
    st = Trace.open(trace_file, streaming=True, chunk_rows=173)
    assert_frames_equal(mem.time_profile(num_bins=24),
                        st.time_profile(num_bins=24), tol=True)


def test_message_ops_identical(trace_file, mem):
    st = Trace.open(trace_file, streaming=True, chunk_rows=89)
    np.testing.assert_array_equal(mem.comm_matrix(), st.comm_matrix())
    assert_frames_equal(mem.comm_by_process(), st.comm_by_process())
    cm, em = mem.message_histogram(), st.message_histogram()
    np.testing.assert_array_equal(cm[0], em[0])
    np.testing.assert_allclose(cm[1], em[1])
    vm, vs = mem.comm_over_time(num_bins=16), st.comm_over_time(num_bins=16)
    np.testing.assert_allclose(vm[0], vs[0])
    np.testing.assert_allclose(vm[1], vs[1])


def test_fused_masks_per_chunk(trace_file, mem):
    """Selection chains fuse into one mask per chunk and match the eager
    in-memory chain exactly."""
    st = Trace.open(trace_file, streaming=True, chunk_rows=53)
    f = Filter(NAME, "not-in", ["MPI_Wait", "MPI_Isend"])
    a = (mem.query().filter(f).restrict_processes([0, 1, 3])
         .flat_profile())
    b = (st.query().filter(f).restrict_processes([0, 1, 3])
         .flat_profile())
    assert_frames_equal(a, b)


def test_within_window_pushdown(trace_file, mem):
    st = Trace.open(trace_file, streaming=True, chunk_rows=53)
    w = time_window_filter(1_000_000, 9_000_000, trim="within")
    np.testing.assert_array_equal(mem.query().filter(w).comm_matrix(),
                                  st.query().filter(w).comm_matrix())


def test_unsupported_op_raises(trace_file):
    st = Trace.open(trace_file, streaming=True, chunk_rows=100)
    with pytest.raises(StreamingUnsupported, match="collect"):
        st.detect_pattern()
    with pytest.raises(StreamingUnsupported, match="within"):
        st.query().slice_time(0, 10.0).flat_profile()
    with pytest.raises(StreamingUnsupported, match="derived"):
        st.query().filter(Filter(EXC, ">", 100.0)).flat_profile()


def test_collect_escape_hatch(trace_file, mem):
    """collect() materializes and then any op (even non-streaming) runs."""
    st = Trace.open(trace_file, streaming=True, chunk_rows=100)
    collected = st.query().collect()
    assert len(collected) == len(mem)
    patterns = collected.detect_pattern(start_event="time-loop")
    assert patterns is not None


def test_stats_and_len(trace_file, mem):
    st = Trace.open(trace_file, streaming=True, chunk_rows=64)
    assert len(st) == len(mem)
    assert st.num_processes == mem.num_processes


def test_sharded_pushdown(tmp_path):
    t = tracegen.gol(nprocs=4, iters=5, seed=1)
    whole = str(tmp_path / "g.jsonl")
    write_jsonl(t, whole)
    shards = split_jsonl_by_process(whole, str(tmp_path / "shards"))
    mem = Trace.open(shards)
    st = Trace.open(shards, streaming=True, chunk_rows=40)
    assert_frames_equal(mem.flat_profile(per_process=True),
                        st.flat_profile(per_process=True))
    # restricting processes must only surface the requested ranks
    prof = st.query().restrict_processes([2]).flat_profile(per_process=True)
    assert set(np.asarray(prof[PROC]).tolist()) == {2}


def test_traceset_streaming_diff(tmp_path):
    before, after = tracegen.regression_pair(
        "tortuga", func="computeRhs", factor=1.7, nprocs=4, iters=3)
    pb, pa = str(tmp_path / "b.jsonl"), str(tmp_path / "a.jsonl")
    write_jsonl(before, pb)
    write_jsonl(after, pa)
    ts_mem = TraceSet.open([pb, pa])
    ts_st = TraceSet.open([pb, pa], streaming=True, chunk_rows=128)
    assert all(isinstance(t, StreamingTrace) for t in ts_st)
    rm, rs = ts_mem.regression_report(), ts_st.regression_report()
    assert_frames_equal(rm, rs, context="regression_report")
    assert str(rs[NAME][0]) == "computeRhs"  # ground-truth regression wins
    assert_frames_equal(ts_mem.diff_flat_profile(), ts_st.diff_flat_profile())
    assert_frames_equal(ts_mem.scaling_analysis(), ts_st.scaling_analysis(),
                        tol=True)
    # shared plan binds onto streaming members
    f = Filter(NAME, "not-in", ["MPI_Wait"])
    assert_frames_equal(ts_mem.query().filter(f).regression_report(),
                        ts_st.query().filter(f).regression_report())


def test_unsorted_stream_raises(tmp_path):
    p = str(tmp_path / "unsorted.jsonl")
    with open(p, "w") as f:
        f.write('{"ts": 100, "et": "Enter", "name": "f", "proc": 0}\n')
        f.write('{"ts": 200, "et": "Leave", "name": "f", "proc": 0}\n')
        f.write('{"ts": 50, "et": "Enter", "name": "g", "proc": 0}\n')
        f.write('{"ts": 60, "et": "Leave", "name": "g", "proc": 0}\n')
    st = Trace.open(p, streaming=True, chunk_rows=2)
    with pytest.raises(StreamingUnsupported, match="time order"):
        st.flat_profile()


def test_optimize_dtypes_lossless():
    t = tracegen.gol(nprocs=3, iters=3)
    base = t.flat_profile()
    ev = optimize_dtypes(t.events.copy())
    assert ev.column(PROC).dtype.itemsize <= 4
    t2 = Trace(ev)
    assert_frames_equal(base, t2.flat_profile())


def test_streaming_ingest_dtypes(trace_file):
    """Chunked ingest downcasts id columns; results stay identical (covered
    elsewhere) and the storage is actually narrower."""
    st = Trace.open(trace_file, streaming=True, chunk_rows=10 ** 6)
    chunk = next(iter(st.iter_chunks()))
    assert chunk.column(PROC).dtype.itemsize <= 4


def test_chrome_nondense_pids_match_memory(tmp_path):
    """Chrome traces with arbitrary (non-dense) pids: the chunked reader
    must densify exactly like the whole-file reader."""
    import json
    p = str(tmp_path / "weird_pids.json")
    events = []
    for pid in (2000, 1000):
        events += [{"ph": "B", "name": "work", "pid": pid, "tid": 0,
                    "ts": 1.0},
                   {"ph": "E", "name": "work", "pid": pid, "tid": 0,
                    "ts": 50.0}]
    with open(p, "w") as f:
        json.dump({"traceEvents": events}, f)
    mem = Trace.open(p)
    st = Trace.open(p, streaming=True, chunk_rows=2)
    assert st.num_processes == mem.num_processes == 2
    assert_frames_equal(mem.flat_profile(per_process=True),
                        st.flat_profile(per_process=True))
    # pushdown operates on the densified ids, like the in-memory path
    a = mem.query().restrict_processes([1]).flat_profile(per_process=True)
    b = st.query().restrict_processes([1]).flat_profile(per_process=True)
    assert_frames_equal(a, b)


def test_csv_pushdown_does_not_change_column_types(tmp_path):
    """Process pushdown may drop the only rows whose values make a column
    non-numeric; the type decision must still match the whole-file read."""
    p = str(tmp_path / "phase.csv")
    with open(p, "w") as f:
        f.write("Timestamp (ns),Event Type,Name,Process,phase\n")
        f.write("0,Enter,f,0,1\n")
        f.write("5,Leave,f,0,1\n")
        f.write("0,Enter,g,1,warmup\n")
        f.write("9,Leave,g,1,warmup\n")
    mem = Trace.open(p).query().restrict_processes([0]).collect()
    st = Trace.open(p, streaming=True, chunk_rows=100)
    chunk = next(iter(st.with_steps(
        st.query().restrict_processes([0])._steps).iter_chunks()))
    # whole-file read types 'phase' over ALL rows -> categorical strings
    assert list(map(str, mem.events["phase"])) == ["1", "1"]
    assert list(map(str, chunk["phase"])) == ["1", "1"]


def test_chrome_bracket_at_block_boundary(tmp_path):
    """The incremental CTF parser must keep reading when the traceEvents
    '[' falls just past its read-block boundary."""
    import json
    p = str(tmp_path / "padded.json")
    pad = "x" * (65536 - len('{"metadata": "", "traceEvents"') - 3)
    events = [{"ph": "B", "name": "f", "pid": 0, "tid": 0, "ts": 1.0},
              {"ph": "E", "name": "f", "pid": 0, "tid": 0, "ts": 9.0}]
    with open(p, "w") as f:
        f.write('{"metadata": "%s", "traceEvents": %s}'
                % (pad, json.dumps(events)))
    st = Trace.open(p, format="chrome", streaming=True, chunk_rows=10)
    assert len(st) == 2
    mem = Trace.open(p, format="chrome")
    assert_frames_equal(mem.flat_profile(), st.flat_profile())


def test_comm_negative_partner_matches_memory(tmp_path):
    """Sends without a partner (-1) must land where the in-memory op puts
    them (np.add.at wraps -1 to the last process), not silently vanish."""
    import json
    p = str(tmp_path / "flows.json")
    events = []
    for pid in range(3):
        events += [{"ph": "B", "name": "w", "pid": pid, "tid": 0, "ts": 1.0},
                   {"ph": "s", "name": "flow", "pid": pid, "tid": 0,
                    "ts": 2.0, "id": 0, "args": {"size": 64.0}},
                   {"ph": "E", "name": "w", "pid": pid, "tid": 0, "ts": 9.0}]
    with open(p, "w") as f:
        json.dump({"traceEvents": events}, f)
    mem = Trace.open(p)
    st = Trace.open(p, streaming=True, chunk_rows=3)
    np.testing.assert_array_equal(mem.comm_matrix(), st.comm_matrix())
    assert mem.comm_matrix()[:, -1].sum() > 0  # the wrap actually happened
    assert_frames_equal(mem.comm_by_process(), st.comm_by_process())


def test_comm_partner_outside_selection_raises(tmp_path):
    """Restricting processes so that message partners fall outside the
    selection must fail loudly (the in-memory path raises too), never
    silently drop the traffic."""
    t = tracegen.gol(nprocs=4, iters=2, seed=2)
    p = str(tmp_path / "g.jsonl")
    write_jsonl(t, p)
    st = Trace.open(p, streaming=True, chunk_rows=32)
    with pytest.raises(IndexError, match="partner"):
        st.query().restrict_processes([0]).comm_matrix()
    with pytest.raises(IndexError, match="partner"):
        st.query().restrict_processes([0]).comm_by_process()


def test_scaling_total_on_unbalanced_trace(tmp_path):
    """scaling_analysis totals use per-row semantics: a function with one
    unmatched Enter still contributes its matched calls (streaming must
    match the eager branch, not the flat-profile group-zeroing rule)."""
    p = str(tmp_path / "unbal.jsonl")
    with open(p, "w") as f:
        for ts, et, name in [(0, "Enter", "f"), (10, "Leave", "f"),
                             (20, "Enter", "f")]:  # trailing open call
            f.write('{"ts": %d, "et": "%s", "name": "%s", "proc": 0}\n'
                    % (ts, et, name))
        f.write('{"ts": 0, "et": "Enter", "name": "g", "proc": 1}\n')
        f.write('{"ts": 30, "et": "Leave", "name": "g", "proc": 1}\n')
    from repro.core.diff import TraceSet
    mem_set = TraceSet.open([p, p])
    st_set = TraceSet.open([p, p], streaming=True, chunk_rows=2)
    a, b = mem_set.scaling_analysis(), st_set.scaling_analysis()
    np.testing.assert_allclose(np.asarray(a["time.exc.total"], float),
                               np.asarray(b["time.exc.total"], float))
    assert float(a["time.exc.total"][0]) > 0  # matched f call counted


def test_big_trace_generator_streams(tmp_path):
    paths = tracegen.big_trace(str(tmp_path / "big"), nprocs=2,
                               events_per_proc=4_000, calls_per_iter=120)
    assert [os.path.basename(p) for p in paths] == ["rank_0.jsonl",
                                                    "rank_1.jsonl"]
    mem = Trace.open(paths)
    st = Trace.open(paths, streaming=True, chunk_rows=500)
    assert_frames_equal(mem.flat_profile(), st.flat_profile())
    assert_frames_equal(mem.load_imbalance(), st.load_imbalance())
    np.testing.assert_array_equal(mem.comm_matrix(), st.comm_matrix())
    # wrappers span many chunks: main() and iteration must be profiled
    names = set(map(str, mem.flat_profile()[NAME]))
    assert {"main()", "iteration"} <= names
