"""Reader tests: every format into the uniform data model + round trips."""

import json
import os

import numpy as np
import pytest

from repro import tracegen as tg
from repro.core.constants import ET, NAME, PROC, TS
from repro.core.trace import Trace
from repro.readers import (read_chrome, read_csv, read_hlo, read_jsonl,
                           read_otf2_json, read_parallel, write_jsonl,
                           write_otf2_json)
from repro.readers.parallel import split_jsonl_by_process

FIG1_CSV = """Timestamp (s), Event Type, Name, Process
0, Enter, main(), 0
1, Enter, foo(), 0
3, Enter, MPI_Send, 0
5, Leave, MPI_Send, 0
8, Enter, baz(), 0
18, Leave, baz(), 0
25, Leave, foo(), 0
100, Leave, main(), 0
"""


def test_csv_fig1(tmp_path):
    p = tmp_path / "foo-bar.csv"
    p.write_text(FIG1_CSV)
    t = read_csv(str(p))
    assert len(t) == 8
    assert t.num_processes == 1
    assert list(t.events[NAME][:2]) == ["main()", "foo()"]
    # paper converts seconds → ns
    assert np.asarray(t.events[TS]).max() == pytest.approx(100e9)


def test_jsonl_roundtrip(tmp_path):
    t = tg.gol(nprocs=4, iters=3)
    p = str(tmp_path / "t.jsonl")
    write_jsonl(t, p)
    t2 = read_jsonl(p)
    assert len(t2) == len(t)
    assert np.allclose(t2.comm_matrix(), t.comm_matrix())
    fp1 = t.flat_profile()
    fp2 = t2.flat_profile()
    assert list(fp1[NAME]) == list(fp2[NAME])


def test_otf2_json_roundtrip(tmp_path):
    t = tg.amg_vcycle(nprocs=4, iters=2)
    p = str(tmp_path / "trace.otf2.json")
    write_otf2_json(t, p)
    t2 = read_otf2_json(p)
    assert len(t2) == len(t)
    assert np.allclose(t2.comm_matrix(), t.comm_matrix())


def test_chrome_reader(tmp_path):
    events = [
        {"name": "step", "ph": "X", "ts": 10, "dur": 100, "pid": 0, "tid": 0},
        {"name": "allreduce", "ph": "B", "ts": 50, "pid": 0, "tid": 1},
        {"name": "allreduce", "ph": "E", "ts": 90, "pid": 0, "tid": 1},
        {"name": "step", "ph": "X", "ts": 10, "dur": 90, "pid": 1, "tid": 0},
    ]
    p = tmp_path / "chrome.json"
    p.write_text(json.dumps({"traceEvents": events}))
    t = read_chrome(str(p))
    assert t.num_processes == 2
    fp = t.flat_profile()
    assert "step" in list(fp[NAME])


def test_parallel_reader(tmp_path):
    t = tg.gol(nprocs=4, iters=3)
    full = str(tmp_path / "full.jsonl")
    write_jsonl(t, full)
    shards = split_jsonl_by_process(full, str(tmp_path / "shards"))
    assert len(shards) == 4
    t2 = read_parallel(shards, kind="jsonl", processes=2)
    assert len(t2) == len(t)
    assert np.allclose(t2.comm_matrix(), t.comm_matrix())


HLO_MIN = """\
HloModule test_spmd

%body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,128] get-tuple-element(%p), index=1
  %d = f32[128,128] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,128] all-reduce(%d), replica_groups={{0,1,2,3}}, to_apply=%sum
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,128]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[128,128])) -> pred[] {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(3)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main_spmd (a: f32[128,128]) -> f32[128,128] {
  %a = f32[128,128] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[128,128]) tuple(%z, %a)
  %w = (s32[], f32[128,128]) while(%t0), condition=%cond, body=%body
  ROOT %r = f32[128,128] get-tuple-element(%w), index=1
}
"""


def test_hlo_reader_models_collectives():
    t = read_hlo(HLO_MIN, n_procs=4, group_size=4)
    fp = t.flat_profile()
    names = list(fp[NAME])
    assert "all-reduce" in names and "dot" in names
    # while body expanded 3×
    cm = t.comm_matrix()
    assert cm[0, 1] > 0                        # ring neighbor traffic
    assert (cm.diagonal() == 0).all()
    bd = t.comm_comp_breakdown()
    assert np.asarray(bd["comm_only"] + bd["overlap"]).sum() > 0


# ---------------------------------------------------------------------------
# format resolution errors (ISSUE 2 satellite)
# ---------------------------------------------------------------------------

def test_open_unrecognized_content_raises_valueerror(tmp_path):
    """An unrecognized file must raise ValueError listing the registered
    formats and their sniffers — never a bare KeyError from a reader the
    extension happened to match."""
    # extension matches chrome/otf2j, but no content sniffer accepts it
    p = tmp_path / "mystery.json"
    p.write_text('{"foo": 1, "bar": [2, 3]}')
    with pytest.raises(ValueError) as exc:
        Trace.open(str(p))
    msg = str(exc.value)
    assert "cannot determine trace format" in msg
    for fmt in ("chrome", "csv", "hlo", "jsonl", "otf2j"):
        assert fmt in msg
    assert "sniffer" in msg and "_sniff_chrome" in msg
    assert "format=" in msg  # tells the user the escape hatch

    # same for content with no extension hit at all
    q = tmp_path / "mystery.bin"
    q.write_text("\x00\x01 binary junk")
    with pytest.raises(ValueError, match="cannot determine trace format"):
        Trace.open(str(q))
