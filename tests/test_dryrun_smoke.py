"""Dry-run smoke: one full production-mesh lower+compile in a subprocess
(the 512-device XLA flag must not leak into this pytest process)."""

import pathlib
import subprocess
import sys
import textwrap

import pytest

# full-matrix jax suites: minutes, not seconds — slow tier only
pytestmark = pytest.mark.slow

ROOT = str(pathlib.Path(__file__).parent.parent)

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import sys; sys.path.insert(0, "src")
    import json
    from repro.launch.dryrun import run_cell
    r = run_cell("qwen1.5-0.5b", "decode_32k", {multi}, out_dir=None)
    rl = r["roofline"]
    assert r["chips"] == {chips}
    assert rl["compute_s"] > 0 and rl["memory_s"] > 0
    assert r["collectives_schedule"]["total"]["count"] > 0
    print("DRYRUN_OK", r["mesh"], rl["bottleneck"])
""")


@pytest.mark.slow
@pytest.mark.parametrize("multi,chips", [(False, 256), (True, 512)])
def test_dryrun_cell_compiles(multi, chips):
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(multi=multi, chips=chips)],
        capture_output=True, text=True, cwd=ROOT, timeout=900)
    assert "DRYRUN_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
