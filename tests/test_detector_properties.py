"""Property-based invariants of the diagnostics suite.

Three properties detectors must satisfy regardless of what trace they are
pointed at:

* **shuffle invariance** — findings are a function of the *event set*,
  not of row order: shuffling rows (timestamps distinct, so the canonical
  sort is unique) and re-sorting changes nothing, bit for bit;
* **rank-relabel equivariance** — renaming process ids permutes the
  ``process`` column of straggler/imbalance findings and changes nothing
  else (no detector secretly keys on rank numbering);
* **bounded efficiency** — every POP efficiency metric lies in [0, 1] on
  arbitrary random call forests.

Runs under real hypothesis when installed, the vendored minihyp fallback
otherwise (``repro.testing.hyp``).
"""

import numpy as np

from repro.testing.hyp import given, settings, st

from repro.core.constants import ET, NAME, PARTNER, PROC, TS
from repro.core.frame import EventFrame
from repro.core.trace import Trace
from repro.serving.protocol import result_digest
from repro.tracegen import baseline, inject


@st.composite
def call_forest(draw):
    """Random per-process call forest with distinct timestamps (the
    canonical (process, time) sort is then unique, so shuffle + re-sort is
    a pure row reordering)."""
    nprocs = draw(st.integers(1, 3))
    ts_list, et_list, name_list, proc_list = [], [], [], []

    def gen(proc, t, depth, budget):
        while budget[0] > 0 and draw(st.booleans()):
            budget[0] -= 1
            name = draw(st.sampled_from(
                ["work", "solve", "MPI_Wait", "MPI_Send"]))
            ts_list.append(t)
            et_list.append("Enter")
            name_list.append(name)
            proc_list.append(proc)
            t += draw(st.integers(1, 4))
            if depth < 3:
                t = gen(proc, t, depth + 1, budget)
            ts_list.append(t)
            et_list.append("Leave")
            name_list.append(name)
            proc_list.append(proc)
            t += draw(st.integers(1, 4))
        return t

    for p in range(nprocs):
        gen(p, draw(st.integers(0, 5)), 0, [draw(st.integers(1, 12))])
    if not ts_list:
        ts_list, et_list = [0, 1], ["Enter", "Leave"]
        name_list, proc_list = ["work", "work"], [0, 0]
    return EventFrame({
        TS: np.asarray(ts_list, np.float64),
        ET: np.asarray(et_list),
        NAME: np.asarray(name_list),
        PROC: np.asarray(proc_list, np.int64),
    }).sort_by([PROC, TS])


@given(ev=call_forest(), seed=st.integers(0, 2 ** 16))
@settings(max_examples=40, deadline=None)
def test_shuffle_invariance(ev, seed):
    want = result_digest(Trace(ev.copy()).diagnose())
    rng = np.random.default_rng(seed)
    shuffled = ev.take(rng.permutation(len(ev))).sort_by([PROC, TS])
    assert result_digest(Trace(shuffled).diagnose()) == want


@given(seed=st.integers(0, 2 ** 16), magnitude=st.integers(2, 6))
@settings(max_examples=15, deadline=None)
def test_rank_relabel_equivariance(seed, magnitude):
    """Relabeling ranks by a permutation permutes straggler/imbalance
    findings' ``process`` and leaves severities untouched."""
    ev, _ = inject(baseline(nprocs=4, iters=8), "straggler",
                   magnitude=float(magnitude), seed=seed)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(4)
    rel = ev.copy()
    rel[PROC] = perm[np.asarray(ev[PROC], np.int64)]
    if PARTNER in rel:
        partner = np.asarray(ev[PARTNER], np.int64)
        rel[PARTNER] = np.where(partner >= 0, perm[np.maximum(partner, 0)],
                                partner)
    for det in ("stragglers", "imbalance_root_cause"):
        base = Trace(ev.copy()).query().run(det, cache=False)
        moved = Trace(rel.copy()).query().run(det, cache=False)
        want = sorted((int(perm[p]), round(float(s), 9), str(f))
                      for p, s, f in zip(base["process"], base["severity"],
                                         base["function"]))
        got = sorted((int(p), round(float(s), 9), str(f))
                     for p, s, f in zip(moved["process"], moved["severity"],
                                        moved["function"]))
        assert got == want, det


@given(ev=call_forest(), windows=st.integers(1, 24))
@settings(max_examples=40, deadline=None)
def test_efficiency_metrics_bounded(ev, windows):
    m = Trace(ev).efficiency_metrics(num_windows=windows)
    for col in ("parallel_eff", "load_balance_eff", "comm_eff"):
        v = np.asarray(m[col], np.float64)
        assert ((v >= 0.0) & (v <= 1.0)).all(), col
    # parallel efficiency is the product of its factors
    np.testing.assert_allclose(
        np.asarray(m["parallel_eff"]),
        np.asarray(m["load_balance_eff"]) * np.asarray(m["comm_eff"]),
        rtol=1e-12)


@given(seed=st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_findings_severity_always_ranked(seed):
    """Whatever the trace, diagnose output is sorted by severity desc."""
    ev, _ = inject(baseline(nprocs=3, iters=8), "straggler",
                   magnitude=2.5, seed=seed)
    f = Trace(ev).diagnose()
    sev = np.asarray(f["severity"], np.float64)
    assert (np.diff(sev) <= 0).all()
