"""Lazy TraceQuery layer: plan fusion, structure remap, registry, sniffing.

Property tests use numpy RNG sweeps (hypothesis is optional in this
environment) over synthetic traces from repro.tracegen.
"""

import json
import os

import numpy as np
import pytest

from repro import tracegen as tg
from repro.core import (Filter, Trace, TraceQuery, list_ops, register_op,
                        scan, time_window_filter)
from repro.core.constants import (EXC, INC, MATCH, MATCH_TS, NAME, PARENT,
                                  PROC, TS)
from repro.core import structure
from repro.readers import write_jsonl, write_otf2_json
from repro.readers.parallel import select_shards, split_jsonl_by_process


def _col_eq(a, b):
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype.kind == "f" or b.dtype.kind == "f":
        return np.array_equal(a, b, equal_nan=True)
    return np.array_equal(a, b)


def assert_frames_equal(fa, fb):
    assert list(fa.columns) == list(fb.columns)
    for c in fa.columns:
        assert _col_eq(fa[c], fb[c]), c


# ---------------------------------------------------------------------------
# plan fusion
# ---------------------------------------------------------------------------

def test_fused_filters_equal_combined_filter():
    t = tg.tortuga(nprocs=8, iters=3)
    a = Filter(NAME, "!=", "computeRhs")
    b = Filter(PROC, "<", 5)
    lazy = t.query().filter(a).filter(b).collect()
    eager = t.filter(a & b)
    assert_frames_equal(lazy.events[[TS, NAME, PROC]],
                        eager.events[[TS, NAME, PROC]])


def test_fusion_property_random_filters():
    """trace.query().filter(a).filter(b).collect() == trace.filter(a & b)
    over a sweep of random predicate pairs."""
    t = tg.gol(nprocs=4, iters=4)
    names = list(dict.fromkeys(t.events[NAME]))
    rng = np.random.default_rng(0)
    ts = np.asarray(t.events[TS], np.float64)
    for _ in range(20):
        fa = Filter(NAME, "in", list(rng.choice(names, size=2)))
        lo, hi = np.sort(rng.uniform(ts.min(), ts.max(), 2))
        fb = Filter(TS, "between", (lo, hi))
        lazy = t.query().filter(fa).filter(fb).collect()
        eager = t.filter(fa & fb)
        assert len(lazy) == len(eager)
        assert_frames_equal(lazy.events[[TS, NAME, PROC]],
                            eager.events[[TS, NAME, PROC]])


def test_chain_profile_identical_to_eager():
    t_lazy = tg.tortuga(nprocs=8, iters=4)
    t_eager = tg.tortuga(nprocs=8, iters=4)
    ts = np.asarray(t_lazy.events[TS], np.float64)
    lo, hi = np.percentile(ts, 15), np.percentile(ts, 85)
    fp_lazy = (t_lazy.query().slice_time(lo, hi)
               .filter(Filter(NAME, "not-in", ["MPI_Send"]))
               .restrict_processes(range(6)).flat_profile())
    fp_eager = (t_eager.slice_time(lo, hi)
                .filter(Filter(NAME, "not-in", ["MPI_Send"]))
                .filter_processes(range(6)).flat_profile())
    assert_frames_equal(fp_lazy, fp_eager)


# ---------------------------------------------------------------------------
# structure reuse: remap vs recompute
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("select", ["procs", "window"])
def test_structure_remap_equals_recompute(select):
    t = tg.tortuga(nprocs=8, iters=4)
    ts = np.asarray(t.events[TS], np.float64)
    if select == "procs":
        t._ensure_structure()  # structured parent → remap path
        sub = t.query().restrict_processes(range(4)).collect()
    else:
        lo, hi = np.percentile(ts, 20), np.percentile(ts, 80)
        sub = t.query().slice_time(lo, hi).collect()
    assert sub._structured, "selection should have remapped structure"
    # recompute from scratch on a stripped copy and compare byte-for-byte
    fresh = Trace(Trace._strip_structure(sub.events).copy())
    fresh._ensure_structure()
    for c in (MATCH, PARENT, "_depth", INC, EXC, MATCH_TS):
        assert _col_eq(sub.events.column(c), fresh.events.column(c)), c


def test_remap_falls_back_when_pairs_break():
    t = tg.gol(nprocs=4, iters=3)
    t._ensure_structure()
    # dropping only Leave events breaks every enter/leave pair
    sub = t.query().filter(Filter("Event Type", "!=", "Leave")).collect()
    assert not sub._structured
    assert MATCH not in sub.events


def test_remapped_messages_match_recompute():
    t = tg.gol(nprocs=4, iters=3)
    t._ensure_structure()
    t._ensure_messages()
    sub = t.query().slice_time(0, np.inf).collect()  # keeps everything
    assert sub._msg_match is not None
    assert np.array_equal(sub._msg_match, structure.match_messages(sub.events))


def test_structure_computed_once_per_plan(monkeypatch):
    t = tg.tortuga(nprocs=8, iters=3)
    ts = np.asarray(t.events[TS], np.float64)
    calls = {"n": 0}
    orig = structure.match_events

    def counting(ev):
        calls["n"] += 1
        return orig(ev)

    monkeypatch.setattr(structure, "match_events", counting)
    (t.query().slice_time(np.percentile(ts, 10), np.percentile(ts, 90))
     .filter(Filter(NAME, "!=", "MPI_Send"))
     .restrict_processes(range(6)).flat_profile())
    assert calls["n"] == 1


# ---------------------------------------------------------------------------
# trim semantics (time_window_filter wired through planner + legacy path)
# ---------------------------------------------------------------------------

def test_time_window_trim_overlap_vs_within():
    t = tg.tortuga(nprocs=4, iters=3)
    ts = np.asarray(t.events[TS], np.float64)
    lo, hi = np.percentile(ts, 30), np.percentile(ts, 70)
    n_overlap = len(t.filter(time_window_filter(lo, hi, trim="overlap")))
    n_within = len(t.filter(time_window_filter(lo, hi, trim="within")))
    assert n_overlap > n_within  # overlap keeps whole boundary calls
    assert n_overlap == len(t.slice_time(lo, hi, trim="overlap"))
    assert n_within == len(t.slice_time(lo, hi, trim="within"))


def test_overlap_window_composes_with_and():
    t = tg.gol(nprocs=4, iters=3)
    ts = np.asarray(t.events[TS], np.float64)
    lo, hi = np.percentile(ts, 30), np.percentile(ts, 70)
    tw = time_window_filter(lo, hi, trim="overlap")
    composed = t.filter(tw & Filter(PROC, "==", 0))
    chained = t.query().slice_time(lo, hi).restrict_processes([0]).collect()
    assert len(composed) == len(chained)
    assert_frames_equal(composed.events[[TS, NAME, PROC]],
                        chained.events[[TS, NAME, PROC]])
    # overlap under | or ~ is ambiguous: loud error, not silent within-trim
    with pytest.raises(ValueError):
        t.filter(tw | Filter(PROC, "==", 0))
    with pytest.raises(ValueError):
        t.filter(~tw)


def test_process_bounds_float_thresholds():
    # integer process ids: fractional thresholds must round conservatively
    assert Filter(PROC, ">", 0.5).process_bounds() == (1, np.inf)
    assert Filter(PROC, "<", 0.5).process_bounds() == (-np.inf, 0)
    assert Filter(PROC, ">", 2).process_bounds() == (3, np.inf)
    assert Filter(PROC, "<", 2).process_bounds() == (-np.inf, 1)


def test_scan_float_threshold_pushdown_matches_eager(tmp_path):
    t = tg.gol(nprocs=4, iters=2)
    full = str(tmp_path / "full.jsonl")
    write_jsonl(t, full)
    shards = split_jsonl_by_process(full, str(tmp_path / "sh"))
    lazy = scan(shards, processes=1).filter(Filter(PROC, ">", 0.5)).collect()
    eager = Trace.open(shards, processes=1).filter(Filter(PROC, ">", 0.5))
    assert sorted(set(np.asarray(lazy.events[PROC]).tolist())) == [1, 2, 3]
    assert len(lazy) == len(eager)


def test_terminal_op_on_fully_pruned_scan(tmp_path):
    t = tg.gol(nprocs=4, iters=2)
    full = str(tmp_path / "full.jsonl")
    write_jsonl(t, full)
    shards = split_jsonl_by_process(full, str(tmp_path / "sh"))
    fp = scan(shards, processes=1).restrict_processes([99]).flat_profile()
    assert len(fp) == 0  # empty profile, not a crash


def test_derived_column_filter_sees_post_selection_values():
    """A predicate over time.exc after a window must see the *recomputed*
    exclusive times (boundary parents absorb dropped children), exactly as
    the eager chain does."""
    t_lazy = tg.tortuga(nprocs=8, iters=4)
    t_eager = tg.tortuga(nprocs=8, iters=4)
    ts = np.asarray(t_lazy.events[TS], np.float64)
    lo, hi = np.percentile(ts, 20), np.percentile(ts, 80)
    t_eager._ensure_structure()
    thr = float(np.nanmedian(np.asarray(t_eager.events.column(EXC))))
    f = Filter(EXC, ">", thr)
    lazy = t_lazy.query().slice_time(lo, hi).filter(f).collect()
    eager = t_eager.slice_time(lo, hi).filter(f)
    assert len(lazy) == len(eager)
    assert_frames_equal(lazy.events[[TS, NAME, PROC]],
                        eager.events[[TS, NAME, PROC]])


def test_zero_step_collect_is_identity():
    t = tg.gol(nprocs=2, iters=1)
    assert t.query().collect() is t  # documented: caches land on the source
    assert t.query().restrict_processes([0]).collect() is not t


def test_derived_conjunct_commutes_inside_one_filter():
    """`a & b` must equal `b & a` even when one conjunct reads a derived
    column — all conjuncts of one composite evaluate on the same frame."""
    t = tg.tortuga(nprocs=8, iters=4)
    t._ensure_structure()
    thr = float(np.nanmedian(np.asarray(t.events.column(EXC))))
    a = Filter(EXC, ">", thr)
    b = Filter(NAME, "!=", "computeRhs")
    x = t.filter(a & b)
    y = t.filter(b & a)
    assert len(x) == len(y)
    assert_frames_equal(x.events[[TS, NAME, PROC]],
                        y.events[[TS, NAME, PROC]])


def test_procs_then_window_fuses_single_materialization(monkeypatch):
    """explain() promises [restrict_processes, slice_time] fuses on a fully
    matched trace; collect() must deliver one structure pass, one take."""
    t = tg.tortuga(nprocs=8, iters=3)
    ts = np.asarray(t.events[TS], np.float64)
    calls = {"n": 0}
    orig = structure.match_events

    def counting(ev):
        calls["n"] += 1
        return orig(ev)

    monkeypatch.setattr(structure, "match_events", counting)
    sub = (t.query().restrict_processes(range(4))
           .slice_time(np.percentile(ts, 10), np.percentile(ts, 90))
           .collect())
    assert calls["n"] == 1
    assert sub._structured  # remapped, not stripped


def test_overlap_window_conjunction_commutes():
    t = tg.gol(nprocs=4, iters=3)
    ts = np.asarray(t.events[TS], np.float64)
    lo, hi = np.percentile(ts, 30), np.percentile(ts, 70)
    tw = time_window_filter(lo, hi, trim="overlap")
    pred = Filter("Event Type", "==", "Enter")
    a = t.filter(tw & pred)
    b = t.filter(pred & tw)  # window must see the same frame either way
    assert len(a) == len(b)
    assert_frames_equal(a.events[[TS, NAME, PROC]],
                        b.events[[TS, NAME, PROC]])


def test_rank_hint_anchored_to_stem(tmp_path):
    from repro.core.registry import rank_shard_procs
    assert rank_shard_procs("/x/rank_3.jsonl") == {3}
    assert rank_shard_procs("/x/rank-12.csv") == {12}
    # merely containing "rank" must NOT produce a hint (never skipped)
    assert rank_shard_procs("/x/lowrank_2.csv") is None
    assert rank_shard_procs("/x/prank_1.jsonl") is None
    assert rank_shard_procs("/x/rank_7") is None  # no extension → no match


def test_selection_never_aliases_source():
    # empty trace with canonical columns
    t = Trace.from_events(tg.gol(nprocs=2, iters=1).events.head(0))
    sub = t.filter(Filter(PROC, "==", 0))
    assert sub is not t
    assert len(sub) == 0


def test_time_window_filter_rejects_bad_trim():
    with pytest.raises(ValueError):
        time_window_filter(0, 1, trim="nope")
    with pytest.raises(ValueError):
        TraceQuery.from_trace(tg.gol(nprocs=2, iters=1)).slice_time(0, 1, "x")


# ---------------------------------------------------------------------------
# filter introspection + edge cases
# ---------------------------------------------------------------------------

def test_filter_columns_and_process_bounds():
    f = (Filter(NAME, "in", ["a"]) & Filter(PROC, "between", (2, 6))) \
        & Filter(PROC, "<", 5)
    assert f.columns() == {NAME, PROC}
    assert f.process_bounds() == (2, 4)
    g = Filter(PROC, "==", 3) | Filter(PROC, "==", 7)
    assert g.process_bounds() == (3, 7)
    assert (~g).process_bounds() is None
    assert Filter(NAME, "==", "x").process_bounds() is None


def test_filter_between_edges_inclusive():
    t = tg.gol(nprocs=2, iters=1)
    ts = np.asarray(t.events[TS], np.float64)
    lo, hi = float(ts.min()), float(ts.max())
    m = Filter(TS, "between", (lo, hi)).mask(t.events)
    assert m.all()
    m2 = Filter(TS, "between", (lo, lo)).mask(t.events)
    assert m2.sum() == (ts == lo).sum()


def test_categorical_not_in_unknown_values():
    t = tg.gol(nprocs=2, iters=1)
    # "in" an unknown category selects nothing; "not-in" selects everything
    assert len(t.filter(Filter(NAME, "in", ["no_such_fn"]))) == 0
    assert len(t.filter(Filter(NAME, "not-in", ["no_such_fn"]))) == len(t)
    known = t.events[NAME][0]
    n_not = len(t.filter(Filter(NAME, "not-in", [known, "no_such_fn"])))
    n_eq = len(t.filter(Filter(NAME, "==", known)))
    assert n_not == len(t) - n_eq


# ---------------------------------------------------------------------------
# op registry
# ---------------------------------------------------------------------------

def test_builtin_ops_registered():
    have = set(list_ops())
    assert {"flat_profile", "time_profile", "comm_matrix", "load_imbalance",
            "idle_time", "detect_pattern", "calculate_lateness",
            "critical_path_analysis", "comm_comp_breakdown"} <= have


def test_register_custom_op_and_chain():
    @register_op("enter_count_by_proc", needs_structure=True)
    def enter_count_by_proc(trace, top=None):
        ev = trace.events
        ent = ev.cat("Event Type").mask_eq("Enter")
        procs = np.asarray(ev[PROC], np.int64)[ent]
        out = np.bincount(procs, minlength=trace.num_processes)
        return out[:top] if top else out

    t = tg.gol(nprocs=4, iters=2)
    counts = t.query().restrict_processes([0, 1]).enter_count_by_proc()
    assert counts.sum() > 0 and len(counts) == 2
    with pytest.raises(AttributeError):
        t.query().no_such_op()
    with pytest.raises(ValueError):
        t.query().run("also_no_such_op")


# ---------------------------------------------------------------------------
# Trace.open sniffing — all five formats
# ---------------------------------------------------------------------------

def test_open_sniffs_all_formats(tmp_path):
    t = tg.gol(nprocs=4, iters=2)

    p_csv = tmp_path / "fig1.trace"  # wrong extension on purpose
    p_csv.write_text("Timestamp (s), Event Type, Name, Process\n"
                     "0, Enter, main(), 0\n1, Leave, main(), 0\n")
    assert len(Trace.open(str(p_csv))) == 2

    p_jsonl = tmp_path / "t.jsonl"
    write_jsonl(t, str(p_jsonl))
    assert len(Trace.open(str(p_jsonl))) == len(t)

    p_chrome = tmp_path / "chrome.json"
    p_chrome.write_text(json.dumps({"traceEvents": [
        {"name": "step", "ph": "X", "ts": 1, "dur": 5, "pid": 0}]}))
    assert len(Trace.open(str(p_chrome))) == 2

    p_otf2 = tmp_path / "trace.otf2.json"
    write_otf2_json(t, str(p_otf2))
    assert len(Trace.open(str(p_otf2))) == len(t)
    d_otf2 = tmp_path / "otf2dir"
    d_otf2.mkdir()
    write_otf2_json(t, str(d_otf2), split_locations=True)
    assert len(Trace.open(str(d_otf2))) == len(t)

    p_hlo = tmp_path / "prog.hlo"
    p_hlo.write_text(
        "HloModule m\n\nENTRY %main (a: f32[8,8]) -> f32[8,8] {\n"
        "  %a = f32[8,8] parameter(0)\n"
        "  ROOT %d = f32[8,8] dot(%a, %a), lhs_contracting_dims={1}, "
        "rhs_contracting_dims={0}\n}\n")
    assert len(Trace.open(str(p_hlo), n_procs=2)) > 0

    # pathlib.Path works everywhere a str path does
    assert len(Trace.open(p_jsonl)) == len(t)
    assert len(Trace.open(p_csv, format="csv")) == 2

    with pytest.raises(ValueError):
        bad = tmp_path / "mystery.bin"
        bad.write_text("???")
        Trace.open(str(bad))
    with pytest.raises(ValueError):
        Trace.open(str(p_jsonl), format="no_such_format")


# ---------------------------------------------------------------------------
# reader pushdown
# ---------------------------------------------------------------------------

def test_scan_pushes_process_restriction_into_shards(tmp_path):
    t = tg.gol(nprocs=4, iters=3)
    full = str(tmp_path / "full.jsonl")
    write_jsonl(t, full)
    shards = split_jsonl_by_process(full, str(tmp_path / "shards"))
    assert len(shards) == 4

    sel = select_shards(shards, "auto", procs={1, 2})
    assert sorted(os.path.basename(s) for s in sel) == \
        ["rank_1.jsonl", "rank_2.jsonl"]
    sel = select_shards(shards, "jsonl", proc_bounds=(0, 1))
    assert sorted(os.path.basename(s) for s in sel) == \
        ["rank_0.jsonl", "rank_1.jsonl"]
    # unknown shard names are never skipped
    anon = str(tmp_path / "events.jsonl")
    write_jsonl(t, anon)
    assert select_shards([anon], "jsonl", procs={99}) == [anon]

    sub = scan(shards, processes=1).filter(Filter(PROC, "in", [1])).collect()
    assert sorted(set(np.asarray(sub.events[PROC]).tolist())) == [1]
    # restriction contradiction → empty trace, no crash
    empty = (scan(shards, processes=1).restrict_processes([1])
             .filter(Filter(PROC, "==", 3)).collect())
    assert len(empty) == 0
