"""Live trace ingestion tests: crash-consistent appends, watermarked
incremental queries, rank-failure-tolerant degraded queries, and the
service's /live sessions.

The load-bearing properties:

* **commit record** — a chunk group is visible iff its trailer record is
  fully durable; any truncation/SIGKILL point yields exactly the
  committed prefix, with the same rows a clean writer stopped at that
  commit produces;
* **pinned snapshot** — a live handle executes over the committed prefix
  captured at ``refresh()``; eager == streaming == parallel digests hold
  on that prefix, and incremental re-query equals cold recompute;
* **degraded coverage** — killing ranks removes them from query results
  *explicitly* (named in the coverage report), never silently.
"""

import asyncio
import os
import time
import warnings

import numpy as np
import pytest

from repro.core import plancache
from repro.core.liveset import Coverage, LiveTraceSet
from repro.core.streaming import LiveTrace
from repro.core.trace import Trace
from repro.readers.pack import PackWriter, committed_prefix, read_pack
from repro.runtime.tracer import Tracer, read_heartbeat, write_heartbeat
from repro.serving.protocol import ProtocolError, result_digest
from repro.serving.tracequery import ServiceError, TraceService
from repro.tracegen.big import big_trace


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _events(n, proc=0, t0=0):
    """A synthetic nested-call event frame: n events, int-ns timestamps."""
    from repro.core.constants import (ENTER, ET, LEAVE, MSG_SIZE, NAME,
                                      PARTNER, PROC, TAG, TS)
    from repro.core.frame import EventFrame
    names = np.asarray([f"fn{i % 7}" for i in range(n)])
    et = np.asarray([ENTER if i % 2 == 0 else LEAVE for i in range(n)])
    # alternating Enter/Leave of the same name → always properly nested
    names = np.repeat(names[: (n + 1) // 2], 2)[:n]
    return EventFrame({
        TS: np.arange(t0, t0 + n, dtype=np.int64),
        ET: et, NAME: names,
        PROC: np.full(n, proc, np.int64),
        PARTNER: np.full(n, -1, np.int64),
        MSG_SIZE: np.full(n, np.nan), TAG: np.zeros(n, np.int64),
    })


def _grow(path, n_commits=3, rows_per=120, proc=0):
    """Append ``n_commits`` committed groups; returns the writer."""
    w = PackWriter.open_append(path, fsync=False)
    base = committed_prefix(path)["rows"]
    for c in range(n_commits):
        w.append(_events(rows_per, proc=proc, t0=(base + c * rows_per)))
        w.commit()
    return w


@pytest.fixture()
def fresh_cache():
    plancache.clear()
    yield
    plancache.clear()


# ---------------------------------------------------------------------------
# append / commit / finalize protocol
# ---------------------------------------------------------------------------

def test_append_commit_finalize_roundtrip(tmp_path):
    p = str(tmp_path / "a.pack")
    w = _grow(p, n_commits=3, rows_per=100)
    assert w.watermark["rows"] == 300
    assert w.watermark["groups"] == 3
    # committed prefix readable while the writer is still open
    snap = committed_prefix(p)
    assert snap["rows"] == 300 and not snap["finalized"]
    t = read_pack(p, live=True)
    assert len(t.events) == 300
    w.finalize(sidecar=False)
    snap = committed_prefix(p)
    assert snap["finalized"]
    # sealed shard is an ordinary pack
    assert len(Trace.open(p).events) == 300


def test_uncommitted_tail_is_invisible(tmp_path):
    p = str(tmp_path / "a.pack")
    w = _grow(p, n_commits=2, rows_per=100)
    # buffered rows past the last commit must not leak to readers
    w.append(_events(50, t0=200))
    assert committed_prefix(p)["rows"] == 200
    assert len(read_pack(p, live=True).events) == 200
    w.commit()
    assert committed_prefix(p)["rows"] == 250


def test_crash_consistency_any_truncation_point(tmp_path):
    """Property: truncating the shard at *any* byte yields exactly the
    longest prefix of whole commits — and the surviving rows match what a
    clean writer stopped at that commit wrote (digest equality)."""
    p = str(tmp_path / "full.pack")
    w = _grow(p, n_commits=4, rows_per=80)
    data = open(p, "rb").read()
    w.finalize(sidecar=False)

    # reference digests: clean writers stopped after k commits
    ref = {}
    for k in range(5):
        rp = str(tmp_path / f"ref{k}.pack")
        if k:
            _grow(rp, n_commits=k, rows_per=80).finalize(sidecar=False)
        else:
            PackWriter.open_append(rp, fsync=False)
        ref[k] = (committed_prefix(rp)["rows"],
                  result_digest(read_pack(rp, live=True).events)
                  if k else None)

    boundaries = sorted({0, len(data)} | set(range(0, len(data), 211)))
    seen_rows = set()
    for cut in boundaries:
        t = str(tmp_path / "cut.pack")
        with open(t, "wb") as f:
            f.write(data[:cut])
        plancache.clear()
        snap = committed_prefix(t)
        assert snap["rows"] % 80 == 0, f"partial commit visible at {cut}"
        k = snap["rows"] // 80
        seen_rows.add(k)
        if k:
            got = result_digest(read_pack(t, live=True).events)
            assert got == ref[k][1], f"cut at {cut}: prefix != clean stop"
    # the sweep actually exercised several distinct commit counts
    assert len(seen_rows) >= 3


def test_resume_append_after_torn_tail(tmp_path):
    p = str(tmp_path / "a.pack")
    w = _grow(p, n_commits=2, rows_per=100)
    w._out.close()
    # tear: garbage + half a group beyond the last commit
    with open(p, "ab") as f:
        f.write(os.urandom(37))
    w2 = PackWriter.open_append(p, fsync=False)
    assert w2.watermark["rows"] == 200   # resume truncated the tear
    w2.append(_events(60, t0=200))
    w2.commit()
    w2.finalize(sidecar=False)
    assert len(Trace.open(p).events) == 260


def test_committed_prefix_missing_and_empty(tmp_path):
    missing = str(tmp_path / "nope.pack")
    assert committed_prefix(missing)["rows"] == 0
    p = str(tmp_path / "empty.pack")
    PackWriter.open_append(p, fsync=False)
    assert committed_prefix(p)["rows"] == 0
    lt = LiveTrace([missing, p])
    assert lt.watermark.rows == 0
    prof = lt.query().flat_profile()
    assert len(prof) == 0


# ---------------------------------------------------------------------------
# watermarked incremental queries
# ---------------------------------------------------------------------------

def test_livetrace_pinning_and_refresh(tmp_path, fresh_cache):
    p = str(tmp_path / "a.pack")
    w = _grow(p, n_commits=2, rows_per=100)
    lt = LiveTrace([p])
    assert lt.watermark.rows == 200
    w.append(_events(100, t0=200))
    w.commit()
    # pinned: the old snapshot does not see the new commit ...
    assert lt.watermark.rows == 200
    assert len(lt.query().run("flat_profile")) > 0
    # ... until refresh
    wm = lt.refresh()
    assert wm.rows == 300


def test_incremental_requery_equals_cold(tmp_path, fresh_cache):
    p = str(tmp_path / "a.pack")
    w = _grow(p, n_commits=2, rows_per=120)
    lt = LiveTrace([p])
    d1 = result_digest(lt.query().run("flat_profile"))
    st = plancache.stats()
    assert st["live_entries"] == 1 and st["live_misses"] >= 1
    for _ in range(3):
        w.append(_events(120, t0=committed_prefix(p)["rows"]))
        w.commit()
        lt.refresh()
        inc = lt.query().run("flat_profile")
        cold = LiveTrace([p], cache=False).query().run("flat_profile",
                                                       cache=False)
        assert result_digest(inc) == result_digest(cold)
    assert plancache.stats()["live_hits"] >= 3
    assert d1 != result_digest(lt.query().run("flat_profile"))


def test_eager_streaming_parallel_agree_on_prefix(tmp_path, fresh_cache):
    shard_dir = tmp_path / "fleet"
    big_trace(str(shard_dir), nprocs=3, events_per_proc=900,
              calls_per_iter=30, seed=5, format="pack")
    paths = sorted(str(q) for q in shard_dir.glob("*.pack"))
    lt = LiveTrace(paths)
    eager_trace = Trace.open(paths)
    assert lt.watermark.rows == len(eager_trace.events)
    serial = lt.query().run("flat_profile")
    par = LiveTrace(paths, processes=2,
                    executor="parallel").query().run("flat_profile")
    eager = eager_trace.query().flat_profile()
    assert result_digest(serial) == result_digest(par)
    assert result_digest(serial) == result_digest(eager)


def test_run_with_watermark(tmp_path, fresh_cache):
    p = str(tmp_path / "a.pack")
    _grow(p, n_commits=2, rows_per=100)
    lt = LiveTrace([p])
    value, wm = lt.run_with_watermark("flat_profile")
    assert wm.rows == 200 and not wm.finalized
    assert len(value) > 0
    assert wm.as_dict()["per_path"][os.path.abspath(p)]["rows"] == 200


def test_incremental_invalidated_by_rewrite(tmp_path, fresh_cache):
    p = str(tmp_path / "a.pack")
    w = _grow(p, n_commits=2, rows_per=100)
    lt = LiveTrace([p])
    lt.query().run("flat_profile")
    w._out.close()
    os.unlink(p)
    _grow(p, n_commits=1, rows_per=64)     # different content, same path
    lt.refresh()
    got = lt.query().run("flat_profile")
    cold = LiveTrace([p], cache=False).query().run("flat_profile",
                                                   cache=False)
    assert result_digest(got) == result_digest(cold)


def test_open_live_via_trace_open(tmp_path, fresh_cache):
    p = str(tmp_path / "a.pack")
    _grow(p, n_commits=1, rows_per=100)
    lt = Trace.open(p, live=True)
    assert isinstance(lt, LiveTrace)
    assert lt.watermark.rows == 100
    with pytest.raises(ValueError):
        Trace.open(p, live=True, format="csv")


# ---------------------------------------------------------------------------
# tracer: bounded buffer, heartbeats
# ---------------------------------------------------------------------------

def test_tracer_bounded_buffer_spills_to_shard(tmp_path):
    sink = str(tmp_path / "rank_0.pack")
    tr = Tracer(process=0, sink=sink, flush_every=64, fsync=False)
    for i in range(400):
        tr.instant("tick")
        assert len(tr.ts) < 64          # the buffer never exceeds the bound
    snap = committed_prefix(sink)
    assert snap["rows"] + len(tr.ts) == 400
    hb = read_heartbeat(sink)
    assert hb["rank"] == 0 and hb["events"] == snap["rows"]
    assert not hb["final"]
    tr.close()
    assert read_heartbeat(sink)["final"]
    assert len(Trace.open(sink).events) == 400


def test_tracer_heartbeat_on_wall_clock(tmp_path):
    fake = [1000.0]
    sink = str(tmp_path / "rank_0.pack")
    tr = Tracer(process=1, sink=sink, flush_every=100_000,
                heartbeat_interval=1.0, fsync=False,
                wall_clock=lambda: fake[0])
    for i in range(300):
        tr.instant("x")
    assert committed_prefix(sink)["rows"] == 0   # under both thresholds
    fake[0] += 5.0
    for i in range(300):                          # next 256-boundary flushes
        tr.instant("x")
    assert committed_prefix(sink)["rows"] > 0
    tr.close(finalize=False)
    # unfinalized shard still reads fully via the committed prefix
    assert committed_prefix(sink)["rows"] == 600
    assert not committed_prefix(sink)["finalized"]


def test_tracer_without_sink_warns_once_keeps_events(tmp_path):
    tr = Tracer(max_buffer_events=10)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        for i in range(25):
            tr.instant("x")
    warned = [w for w in caught if issubclass(w.category, RuntimeWarning)]
    assert len(warned) == 1
    assert "sink" in str(warned[0].message)
    assert len(tr.to_trace().events) == 25       # nothing dropped


# ---------------------------------------------------------------------------
# rank-failure tolerance
# ---------------------------------------------------------------------------

def _fleet(tmp_path, nranks, clock, rows=120):
    tracers = []
    for r in range(nranks):
        tr = Tracer(process=r, sink=str(tmp_path / f"rank_{r}.pack"),
                    flush_every=50, fsync=False, wall_clock=clock)
        for i in range(rows):
            with tr.span(f"fn{i % 5}", proc=r):
                pass
        tr.flush()
        tracers.append(tr)
    return tracers


def test_liveset_classification_and_degraded_query(tmp_path, fresh_cache):
    fake = [1000.0]
    clock = lambda: fake[0]                                     # noqa: E731
    tracers = _fleet(tmp_path, 4, clock)
    ls = LiveTraceSet(str(tmp_path), lag_timeout=2.0, dead_timeout=10.0,
                      clock=clock)
    cov = ls.coverage
    assert cov.included == [0, 1, 2, 3] and not cov.degraded
    base_rows = ls.watermark.rows

    # rank 3 stops heartbeating; the rest keep committing
    fake[0] += 5.0
    for r in range(3):
        tracers[r].instant("t", proc=r)
        tracers[r].flush()
    cov = ls.refresh()
    assert cov.per_rank[3]["status"] == "lagging"
    assert 3 in cov.included                     # laggards still included

    fake[0] += 8.0
    for r in range(3):
        tracers[r].flush()
    val, cov, wm = ls.run("flat_profile")
    assert cov.per_rank[3]["status"] == "dead"
    assert cov.missing == [3] and cov.degraded
    assert cov.per_rank[3]["rows"] > 0           # its prefix still reported
    assert wm.rows == base_rows - cov.per_rank[3]["rows"] + 3
    assert len(val) > 0
    assert cov.staleness_spread >= 0
    d = cov.as_dict()
    assert d["missing"] == [3] and d["per_rank"]["3"]["status"] == "dead"


def test_liveset_survivor_digest_matches_direct_open(tmp_path, fresh_cache):
    fake = [1000.0]
    clock = lambda: fake[0]                                     # noqa: E731
    _fleet(tmp_path, 3, clock)
    # kill rank 1's heartbeat only
    write_heartbeat(str(tmp_path / "rank_1.pack"), 1, 240, 1, 1,
                    wall=fake[0] - 100.0)
    ls = LiveTraceSet(str(tmp_path), clock=clock)
    val, cov, wm = ls.run("flat_profile")
    assert cov.missing == [1]
    direct = LiveTrace([str(tmp_path / "rank_0.pack"),
                        str(tmp_path / "rank_2.pack")],
                       cache=False).query().run("flat_profile", cache=False)
    assert result_digest(val) == result_digest(direct)


def test_liveset_final_heartbeat_never_goes_dead(tmp_path, fresh_cache):
    fake = [1000.0]
    clock = lambda: fake[0]                                     # noqa: E731
    tracers = _fleet(tmp_path, 2, clock)
    tracers[1].close()                            # clean shutdown
    fake[0] += 100.0
    tracers[0].flush()
    ls = LiveTraceSet(str(tmp_path), clock=clock)
    assert ls.coverage.per_rank[1]["status"] == "live"
    assert ls.coverage.per_rank[1]["finalized"]
    assert not ls.coverage.degraded


def test_liveset_all_dead_raises(tmp_path, fresh_cache):
    fake = [1000.0]
    clock = lambda: fake[0]                                     # noqa: E731
    _fleet(tmp_path, 2, clock)
    fake[0] += 1000.0
    ls = LiveTraceSet(str(tmp_path), clock=clock)
    assert ls.coverage.missing == [0, 1]
    with pytest.raises(RuntimeError, match="no surviving ranks"):
        ls.run("flat_profile")
    # empty dir is also a hard error, not an empty result
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(RuntimeError):
        LiveTraceSet(str(empty), clock=clock).run("flat_profile")


def test_coverage_report_shape():
    cov = Coverage({
        0: {"status": "live", "path": "a", "rows": 10, "ts_max": 100,
            "finalized": False, "heartbeat_age": 0.1},
        1: {"status": "dead", "path": "b", "rows": 4, "ts_max": 40,
            "finalized": False, "heartbeat_age": 99.0},
        2: {"status": "lagging", "path": "c", "rows": 8, "ts_max": 70,
            "finalized": False, "heartbeat_age": 3.0},
    })
    assert cov.ranks_total == 3
    assert cov.included == [0, 2] and cov.missing == [1]
    assert cov.staleness_spread == 30            # 100 - 70, dead excluded
    assert cov.degraded


# ---------------------------------------------------------------------------
# service live sessions
# ---------------------------------------------------------------------------

def run(coro):
    return asyncio.run(coro)


def test_service_live_poll_backpressure_and_growth(tmp_path, fresh_cache):
    p = str(tmp_path / "rank_0.pack")
    w = _grow(p, n_commits=2, rows_per=100)
    svc = TraceService()
    body = {"open": {"path": p, "mode": "live"}, "op": "flat_profile",
            "tenant": "t"}
    out = run(svc.live(body))
    assert out["ok"] and out["watermark"]["rows"] == 200
    assert out["advanced_rows"] == 200 and not out["partial"]

    # same session, no growth → 429 watermark_stalled with retry hint
    with pytest.raises(ServiceError) as exc:
        run(svc.live(body))
    assert exc.value.status == 429
    assert exc.value.code == "watermark_stalled"
    assert exc.value.extra["retry_after_ms"] > 0
    assert svc.counters["live_stalled"] == 1

    # a different session is admitted independently
    out2 = run(svc.live(dict(body, session="other")))
    assert out2["ok"]

    # growth unblocks the stalled session
    w.append(_events(80, t0=200))
    w.commit()
    out3 = run(svc.live(body))
    assert out3["watermark"]["rows"] == 280 and out3["advanced_rows"] == 80
    assert svc.counters["live_polls"] == 4


def test_service_liveset_partial_responses(tmp_path, fresh_cache):
    for r in range(3):
        tr = Tracer(process=r, sink=str(tmp_path / f"rank_{r}.pack"),
                    flush_every=40, fsync=False)
        for i in range(80):
            with tr.span(f"fn{i % 5}", proc=r):
                pass
        tr.flush()
    svc = TraceService()
    body = {"open": {"path": str(tmp_path), "mode": "liveset",
                     "lag_timeout": 5.0, "dead_timeout": 60.0},
            "op": "flat_profile", "min_advance_rows": 0, "tenant": "t"}
    out = run(svc.live(body))
    assert not out["partial"] and out["coverage"]["included"] == [0, 1, 2]

    # back-date rank 2's heartbeat past dead_timeout → 206-style partial
    write_heartbeat(str(tmp_path / "rank_2.pack"), 2, 160, 1, 9,
                    wall=time.time() - 120.0)
    out = run(svc.live(body))
    assert out["partial"] and out["missing_ranks"] == [2]
    assert out["coverage"]["per_rank"]["2"]["status"] == "dead"
    assert svc.counters["live_partial"] == 1

    # all ranks dead → 503 no_survivors, coverage attached to the error
    for r in (0, 1):
        write_heartbeat(str(tmp_path / f"rank_{r}.pack"), r, 160, 1, 9,
                        wall=time.time() - 120.0)
    with pytest.raises(ServiceError) as exc:
        run(svc.live(body))
    assert exc.value.status == 503 and exc.value.code == "no_survivors"
    assert exc.value.extra["coverage"]["missing"] == [0, 1, 2]


def test_query_endpoint_rejects_live_modes(tmp_path, fresh_cache):
    p = str(tmp_path / "a.pack")
    _grow(p, n_commits=1, rows_per=50)
    svc = TraceService()
    with pytest.raises(ProtocolError, match="/live"):
        run(svc.query({"open": {"path": p, "mode": "live"},
                       "op": "flat_profile"}))
    with pytest.raises(ProtocolError):
        run(svc.live({"open": {"path": p, "mode": "set"},
                      "op": "flat_profile"}))


def test_live_handle_not_reopened_on_growth(tmp_path, fresh_cache):
    p = str(tmp_path / "a.pack")
    w = _grow(p, n_commits=1, rows_per=100)
    svc = TraceService()
    body = {"open": {"path": p, "mode": "live"}, "op": "flat_profile",
            "tenant": "t"}
    run(svc.live(body))
    for _ in range(3):
        w.append(_events(60, t0=committed_prefix(p)["rows"]))
        w.commit()
        run(svc.live(body))
    st = svc.handles.stats()
    assert st["opens"] == 1 and st["reopens"] == 0
