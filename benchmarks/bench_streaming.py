"""Out-of-core streaming vs in-memory execution: peak RSS and wall time.

Acceptance benchmark for the streaming engine: ``flat_profile`` over a
10M-event sharded JSONL trace must return **byte-identical** results under
streaming execution at **>= 2x lower peak RSS** than the fully
materialized path.

Each phase runs in its own subprocess so ``ru_maxrss`` is a clean
per-phase high-water mark; the parent compares SHA-256 digests of the
result frames (names + counts + metric bytes).

Run standalone::

    PYTHONPATH=src python -m benchmarks.bench_streaming [--events N]

or through ``benchmarks/run.py``.  BENCH_STREAM_EVENTS overrides the
default event count (the full 10M takes a few minutes to generate+parse;
CI smoke runs use ~1M).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import resource
import subprocess
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_EVENTS = int(os.environ.get("BENCH_STREAM_EVENTS", 10_000_000))
NPROCS = 8


def _chunk_rows(events: int) -> int:
    # scale chunks with the benchmark size so the streaming phase's peak is
    # dominated by the chunk, not the Python/numpy import baseline, at
    # smoke sizes too
    return min(250_000, max(events // 8, 10_000))


def _digest(prof) -> str:
    import numpy as np
    h = hashlib.sha256()
    h.update("\x00".join(map(str, prof["Name"])).encode())
    h.update(np.ascontiguousarray(np.asarray(prof["count"],
                                             np.int64)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(prof["time.exc"],
                                             np.float64)).tobytes())
    return h.hexdigest()


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run_phase(mode: str, shard_dir: str, chunk_rows: int) -> None:
    """Child process: one execution mode, JSON result on stdout."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.core.trace import Trace
    shards = sorted(os.path.join(shard_dir, f) for f in os.listdir(shard_dir))
    t0 = time.time()
    if mode == "memory":
        trace = Trace.open(shards)
        prof = trace.flat_profile()
    else:
        handle = Trace.open(shards, streaming=True, chunk_rows=chunk_rows)
        prof = handle.flat_profile()
    dt = time.time() - t0
    print(json.dumps({"mode": mode, "seconds": round(dt, 2),
                      "peak_rss_mb": round(_peak_rss_mb(), 1),
                      "rows": len(prof), "digest": _digest(prof)}))


def bench(events: int = DEFAULT_EVENTS) -> dict:
    from repro.tracegen import big_trace
    chunk_rows = _chunk_rows(events)
    out = {"events": events, "chunk_rows": chunk_rows, "nprocs": NPROCS}
    with tempfile.TemporaryDirectory(prefix="bench_stream_") as d:
        shard_dir = os.path.join(d, "shards")
        t0 = time.time()
        big_trace(shard_dir, nprocs=NPROCS,
                  events_per_proc=max(events // NPROCS, 1000))
        out["gen_seconds"] = round(time.time() - t0, 1)
        out["trace_mb"] = round(sum(
            os.path.getsize(os.path.join(shard_dir, f))
            for f in os.listdir(shard_dir)) / 1e6, 1)
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src")
                   + os.pathsep + os.environ.get("PYTHONPATH", ""))
        for mode in ("memory", "stream"):
            r = subprocess.run(
                [sys.executable, "-m", "benchmarks.bench_streaming",
                 "--phase", mode, "--shards", shard_dir,
                 "--chunk-rows", str(chunk_rows)],
                capture_output=True, text=True, cwd=REPO, env=env,
                check=True)
            out[mode] = json.loads(r.stdout.strip().splitlines()[-1])
    out["identical"] = out["memory"]["digest"] == out["stream"]["digest"]
    mem_rss = out["memory"]["peak_rss_mb"]
    stream_rss = out["stream"]["peak_rss_mb"]
    out["rss_ratio"] = round(mem_rss / max(stream_rss, 1e-9), 2)
    out["rss_target_met"] = out["rss_ratio"] >= 2.0
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--events", type=int, default=DEFAULT_EVENTS)
    ap.add_argument("--phase", choices=["memory", "stream"])
    ap.add_argument("--shards")
    ap.add_argument("--chunk-rows", type=int, default=250_000)
    args = ap.parse_args(argv)
    if args.phase:
        run_phase(args.phase, args.shards, args.chunk_rows)
        return 0
    res = bench(args.events)
    print(json.dumps(res, indent=1))
    if not res["identical"]:
        print("FAIL: streaming result differs from in-memory", file=sys.stderr)
        return 1
    if not res["rss_target_met"]:
        print("FAIL: peak-RSS ratio below 2x", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
