"""Columnar pack store vs text ingest: cache-miss execution speed and
identical digests (acceptance benchmark of the pipitpack format).

Generates the 10M-event sharded ``tracegen.big_trace`` as JSONL, converts
the shards to pack once (:meth:`StreamingTrace.save_pack`, structure
sidecars included — the "convert once" cost is reported), then runs the
exactly-combinable op suite (the same seven-op digest as bench_parallel)
twice in separate subprocesses with the plan-result cache off:

* **jsonl** — serial streaming over the text shards: every op re-decodes
  645 MB of JSON (the cache-miss cost this PR attacks);
* **pack** — serial streaming over the pack shards: chunk reads are memmap
  slices (zero parse) and the structure sidecar replaces the per-chunk
  ``derive_structure`` lexsort.

Digests must match byte for byte; the target is **>= 5x** end-to-end.  A
pushdown probe also runs on the pack side: a process-restricted plan must
*skip* footer chunks (index pushdown) and read strictly fewer than a full
scan.

Run standalone::

    PYTHONPATH=src python -m benchmarks.bench_pack [--events N]
        [--json PATH]

BENCH_PACK_EVENTS overrides the default (CI smoke uses ~1M events).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_EVENTS = int(os.environ.get("BENCH_PACK_EVENTS", 10_000_000))
NPROCS = 8
CHUNK_ROWS = 250_000
SPEEDUP_TARGET = 5.0


def _dir_mb(d: str) -> float:
    return round(sum(os.path.getsize(os.path.join(d, f))
                     for f in os.listdir(d)) / 1e6, 1)


def run_phase(mode: str, shard_dir: str) -> None:
    """Child process: one format's digest suite, JSON result on stdout."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    from benchmarks.bench_parallel import _digest_ops
    from repro.core.trace import Trace
    shards = sorted(os.path.join(shard_dir, f) for f in os.listdir(shard_dir))
    handle = Trace.open(shards, streaming=True, chunk_rows=CHUNK_ROWS,
                        cache=False)
    t0 = time.time()
    digest = _digest_ops(handle)
    dt = time.time() - t0
    out = {"mode": mode, "seconds": round(dt, 2), "digest": digest}
    if mode == "pack":
        from repro.core import structure
        from repro.readers import pack as packmod
        out["derive_calls"] = structure.DERIVE_CALLS  # sidecar ⇒ stays 0
        # pushdown probes.  Process restriction: per-rank shards are
        # skipped whole via the footer shard hint.  Time window: each
        # shard's chunk index is time-ordered, so a narrow within-window
        # must skip most chunks *inside* the surviving shards.
        packmod.reset_io_stats()
        handle.query().restrict_processes([0]).flat_profile(cache=False)
        restricted = packmod.io_stats()
        st = handle.stats()
        t0w = st.ts_min
        t1w = st.ts_min + (st.ts_max - st.ts_min) * 0.05
        packmod.reset_io_stats()
        handle.query().slice_time(t0w, t1w,
                                  trim="within").flat_profile(cache=False)
        window = packmod.io_stats()
        packmod.reset_io_stats()
        handle.flat_profile(cache=False)
        full = packmod.io_stats()
        out["pushdown"] = {
            "full_chunks": full["chunks_read"],
            "restricted_chunks": restricted["chunks_read"],
            "window_chunks": window["chunks_read"],
            "window_skipped": window["chunks_skipped"],
        }
    print(json.dumps(out))


def bench(events: int = DEFAULT_EVENTS) -> dict:
    from repro.core.trace import Trace
    from repro.tracegen import big_trace
    out = {"events": events, "chunk_rows": CHUNK_ROWS, "nprocs": NPROCS,
           "cpu_count": os.cpu_count()}
    with tempfile.TemporaryDirectory(prefix="bench_pack_") as d:
        jdir = os.path.join(d, "jsonl")
        pdir = os.path.join(d, "pack")
        os.makedirs(pdir)
        t0 = time.time()
        shards = big_trace(jdir, nprocs=NPROCS,
                           events_per_proc=max(events // NPROCS, 1000))
        out["gen_seconds"] = round(time.time() - t0, 1)
        out["jsonl_mb"] = _dir_mb(jdir)
        # convert once (streaming, sidecar on) — the amortized cost.  The
        # footer index gets >= ~8 chunks per shard at any scale so the
        # pushdown probe has real skip granularity to exercise.
        pack_chunk = max(min(CHUNK_ROWS, events // NPROCS // 8), 1000)
        out["pack_chunk_rows"] = pack_chunk
        t0 = time.time()
        for s in shards:
            dst = os.path.join(
                pdir, os.path.basename(s).replace(".jsonl", ".pack"))
            Trace.open(s, streaming=True, chunk_rows=CHUNK_ROWS,
                       cache=False).save_pack(dst, chunk_rows=pack_chunk)
        out["convert_seconds"] = round(time.time() - t0, 1)
        out["pack_mb"] = _dir_mb(pdir)
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src")
                   + os.pathsep + os.environ.get("PYTHONPATH", ""))
        for mode, sdir in (("jsonl", jdir), ("pack", pdir)):
            r = subprocess.run(
                [sys.executable, "-m", "benchmarks.bench_pack",
                 "--phase", mode, "--shards", sdir],
                capture_output=True, text=True, cwd=REPO, env=env,
                check=True)
            out[mode] = json.loads(r.stdout.strip().splitlines()[-1])
    out["identical"] = out["jsonl"]["digest"] == out["pack"]["digest"]
    out["speedup"] = round(out["jsonl"]["seconds"]
                           / max(out["pack"]["seconds"], 1e-9), 2)
    pd = out["pack"]["pushdown"]
    out["pushdown_effective"] = (
        pd["restricted_chunks"] < pd["full_chunks"]
        and pd["window_skipped"] > 0
        and pd["window_chunks"] < pd["full_chunks"])
    out["sidecar_skips_derive"] = out["pack"]["derive_calls"] == 0
    out["target_met"] = out["speedup"] >= SPEEDUP_TARGET
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--events", type=int, default=DEFAULT_EVENTS)
    ap.add_argument("--json", dest="json_path",
                    help="write the result dict to PATH as JSON")
    ap.add_argument("--phase", choices=["jsonl", "pack"])
    ap.add_argument("--shards")
    args = ap.parse_args(argv)
    if args.phase:
        run_phase(args.phase, args.shards)
        return 0
    res = bench(args.events)
    print(json.dumps(res, indent=1))
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(res, f, indent=1)
    ok = True
    if not res["identical"]:
        print("FAIL: pack digests differ from jsonl streaming",
              file=sys.stderr)
        ok = False
    if not res["target_met"]:
        print(f"FAIL: speedup {res['speedup']}x below "
              f"{SPEEDUP_TARGET}x target", file=sys.stderr)
        ok = False
    if not res["pushdown_effective"]:
        print("FAIL: restricted plan did not skip pack chunks",
              file=sys.stderr)
        ok = False
    if not res["sidecar_skips_derive"]:
        print("FAIL: pack streaming still called derive_structure",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
