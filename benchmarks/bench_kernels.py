"""Kernel-level roofline: static VMEM working-set and arithmetic-intensity
analysis of the Pallas kernels across block-size candidates.

No TPU is attached, so this reports the quantities the BlockSpecs *claim* —
working set vs the ~16 MiB/core VMEM budget and FLOPs:bytes vs the v5e
ridge point (197e12 / 819e9 ≈ 241 FLOP/byte) — plus an interpret-mode
correctness spot-check per configuration.  The chosen defaults (bq=128,
bk=256) sit comfortably under budget with double-buffering headroom.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

VMEM_BUDGET = 16 * 2**20
RIDGE = 197e12 / 819e9


def flash_attention_table(D=128, dtype_bytes=2):
    rows = []
    for bq in (128, 256, 512):
        for bk in (128, 256, 512):
            # q,k,v blocks + f32 scratch (m,l,acc) + score tile
            vmem = (bq * D + 2 * bk * D) * dtype_bytes \
                + (bq + bq + bq * D) * 4 + bq * bk * 4
            flops = 2 * bq * bk * D * 2              # QK^T + PV
            bytes_moved = (bq * D + 2 * bk * D) * dtype_bytes + bq * D * 4
            rows.append({
                "bq": bq, "bk": bk,
                "vmem_kib": round(vmem / 1024, 1),
                "fits_vmem": vmem * 2 < VMEM_BUDGET,   # ×2 double buffering
                "intensity": round(flops / bytes_moved, 1),
                "mxu_bound": flops / bytes_moved > RIDGE,
            })
    return rows


def correctness_spot_checks():
    from repro.kernels.ops import flash_attention_gqa
    from repro.models.attention import chunked_attention
    out = []
    for bq, bk in ((64, 64), (128, 128)):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 256, 2, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
        got = flash_attention_gqa(q, k, v, bq=bq, bk=bk)
        want = chunked_attention(q, k, v)
        out.append({"bq": bq, "bk": bk,
                    "max_err": float(np.abs(np.asarray(got) -
                                            np.asarray(want)).max())})
    return out


def bench() -> dict:
    return {
        "vmem_budget_mib": VMEM_BUDGET / 2**20,
        "v5e_ridge_flop_per_byte": round(RIDGE, 1),
        "flash_attention_blocks": flash_attention_table(),
        "interpret_mode_spot_checks": correctness_spot_checks(),
        "note": "defaults bq=128, bk=256 fit VMEM with double-buffering and "
                "sit past the ridge point (MXU-bound), the target regime",
    }


if __name__ == "__main__":
    print(json.dumps(bench(), indent=1))
