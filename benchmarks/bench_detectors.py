"""Diagnostics suite acceptance: closed-loop recovery + path-identical
detector execution at scale.

Two phases, each with a hard gate:

* **closed loop** — every pathology in
  :mod:`repro.tracegen.pathologies` is injected into the clean baseline
  app; the matched detector's **top-1** finding must name the injected
  culprit (rank / function / overlapping window), and the clean baseline
  must yield **zero** findings from the full ``diagnose`` sweep.
* **scale** — a straggler-injected trace at the ``--events`` scale is
  packed and diagnosed through the eager and the out-of-core streaming
  path; digests must be **identical** and both wall-times are reported
  (this is the number the README quotes for "diagnose a 10M-event
  trace").

Run standalone::

    PYTHONPATH=src python -m benchmarks.bench_detectors [--events N]
        [--json PATH]

or as part of ``python -m benchmarks.run`` (the ``--events`` knob is
forwarded).  ``BENCH_DETECT_EVENTS`` overrides the default scale.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

DEFAULT_EVENTS = int(os.environ.get("BENCH_DETECT_EVENTS", 10_000_000))
NPROCS = 8
GATE_EVENTS_CAP = 200_000
CHUNK_ROWS = 250_000

# magnitude per pathology: comfortably above each detector's default
# threshold (mirrors the mid magnitudes of tests/test_detectors.py)
MAGNITUDES = {
    "late_sender": 4.0,
    "straggler": 2.0,
    "serialization": 5.0,
    "imbalance": 4.0,
    "efficiency_drop": 0.6,
}


def _iters_for(events: int, nprocs: int) -> int:
    """Baseline iteration count that lands near ``events`` total rows."""
    from repro.tracegen import baseline
    probe = baseline(nprocs=nprocs, iters=8, seed=0)
    per_iter = max(1.0, len(probe.events) / (8.0))
    return max(16, int(round(events / per_iter)))


def _top(findings):
    return {c: findings[c][0] for c in findings.columns}


def _matches(findings, gt) -> bool:
    if len(findings) == 0:
        return False
    top = _top(findings)
    if str(top["detector"]) != gt.detector:
        return False
    if gt.process != -1 and int(top["process"]) != gt.process:
        return False
    if gt.function and str(top["function"]) != gt.function:
        return False
    return (float(top["t_start"]) < gt.t_end
            and float(top["t_end"]) > gt.t_start)


def phase_closed_loop(gate_events: int) -> dict:
    from repro.tracegen import PATHOLOGIES, baseline, pathology_trace
    from repro.core.trace import Trace

    iters = _iters_for(gate_events, 4)
    clean = Trace(baseline(nprocs=4, iters=iters, seed=0).events)
    n_clean = len(clean.diagnose())

    out = {"iters": iters, "clean_findings": n_clean,
           "pathologies": {}, "ok": n_clean == 0}
    for pathology in sorted(PATHOLOGIES):
        tr, gt = pathology_trace(pathology, nprocs=4, iters=iters,
                                 magnitude=MAGNITUDES[pathology], seed=0)
        t0 = time.time()
        findings = tr.query().run(gt.detector, cache=False)
        detect_s = time.time() - t0
        recovered = _matches(findings, gt)
        out["pathologies"][pathology] = {
            "detector": gt.detector,
            "events": len(tr.events),
            "top1_recovered": recovered,
            "severity": (round(float(findings["severity"][0]), 4)
                         if len(findings) else None),
            "detect_s": round(detect_s, 3),
        }
        out["ok"] = out["ok"] and recovered
    return out


def phase_scale(events: int, tmp: str) -> dict:
    from repro.core.trace import Trace
    from repro.readers.pack import write_pack
    from repro.serving.protocol import result_digest
    from repro.tracegen import pathology_trace

    iters = _iters_for(events // NPROCS * NPROCS, NPROCS)
    t0 = time.time()
    tr, gt = pathology_trace("straggler", nprocs=NPROCS, iters=iters,
                             magnitude=2.0, seed=0)
    generate_s = time.time() - t0
    pack = os.path.join(tmp, "straggler.pack")
    write_pack(tr, pack)

    t0 = time.time()
    eager = Trace.open(pack).query().run("diagnose", cache=False)
    eager_s = time.time() - t0

    t0 = time.time()
    stream = (Trace.open(pack, streaming=True, chunk_rows=CHUNK_ROWS)
              .query().run("diagnose", cache=False))
    stream_s = time.time() - t0

    identical = result_digest(eager) == result_digest(stream)
    # a straggler legitimately fires the imbalance detectors too, so the
    # gate is on the matched detector's own top row within the combined
    # ranked frame, not on the overall winner
    rows = [i for i in range(len(eager))
            if str(eager["detector"][i]) == gt.detector]
    recovered = bool(rows) and int(eager["process"][rows[0]]) == gt.process
    return {"events": len(tr.events), "nprocs": NPROCS,
            "generate_s": round(generate_s, 1),
            "eager_diagnose_s": round(eager_s, 3),
            "stream_diagnose_s": round(stream_s, 3),
            "digests_identical": identical,
            "top1_recovered_at_scale": recovered,
            "ok": identical and recovered}


def bench(events: int = DEFAULT_EVENTS) -> dict:
    result = {"events": events, "phases": {}}
    result["phases"]["closed_loop"] = phase_closed_loop(
        min(events, GATE_EVENTS_CAP))
    with tempfile.TemporaryDirectory(prefix="bench_detect_") as tmp:
        result["phases"]["scale"] = phase_scale(events, tmp)
    result["ok"] = all(p["ok"] for p in result["phases"].values())
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=DEFAULT_EVENTS)
    ap.add_argument("--json", default=None,
                    help="write the result document here")
    args = ap.parse_args()

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(repo, "src"))

    result = bench(events=args.events)
    print(json.dumps(result, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
    if not result["ok"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
