"""Backend-registry parity smoke: every registered op backend against the
numpy reference at scale, digest-checked across execution paths.

For every op with a registered ``pallas`` backend (flat_profile,
time_profile, load_imbalance, comm_matrix, message_histogram, stragglers):

* **numerics gate** — the pallas result must agree with the exact numpy
  result to f32 rounding (``rtol=1e-4`` plus an absolute tolerance scaled
  to the result's largest magnitude, since f32 accumulation error follows
  the accumulated mass, not a cell's net value);
  ``message_histogram`` counts must be *exactly* equal.
* **path gate** — the pallas result must be digest-identical between the
  eager pack path and the out-of-core streaming path (the canonical-order
  contract of docs/kernels.md).

Run standalone::

    PYTHONPATH=src python -m benchmarks.bench_backends [--events N]
        [--json PATH]

or as part of ``python -m benchmarks.run`` (the ``--events`` knob is
forwarded).  ``BENCH_BACKENDS_EVENTS`` overrides the default scale.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

DEFAULT_EVENTS = int(os.environ.get("BENCH_BACKENDS_EVENTS", 1_000_000))
NPROCS = 8
CHUNK_ROWS = 250_000

# op → kwargs for one representative invocation per op
OP_CASES = {
    "flat_profile": {"metrics": ("time.exc", "time.inc")},
    "time_profile": {"num_bins": 32},
    "load_imbalance": {},
    "comm_matrix": {},
    "message_histogram": {"bins": 16},
    "stragglers": {},
}


def _iters_for(events: int, nprocs: int) -> int:
    from repro.tracegen import baseline
    probe = baseline(nprocs=nprocs, iters=8, seed=0)
    per_iter = max(1.0, len(probe.events) / 8.0)
    return max(16, int(round(events / per_iter)))


def _tolerant_equal(op, a, b) -> bool:
    """pallas vs numpy: f32 rounding on sums, exact everywhere else.

    f32 accumulation error scales with the *accumulated magnitude*, not a
    cell's net value (a nearly-empty time-profile cell next to a full one
    carries the full bin's rounding), so the absolute tolerance is scaled
    by the result's largest float value."""
    if op == "comm_matrix":
        scale = max(float(np.abs(a).max()), 1.0)
        return bool(np.allclose(a, b, rtol=1e-4, atol=1e-6 * scale))
    if op == "message_histogram":
        return bool((a[0] == b[0]).all() and (a[1] == b[1]).all())
    if list(a.columns) != list(b.columns) or len(a) != len(b):
        return False
    scale = 1.0
    for c in a.columns:
        va = np.asarray(a[c])
        if va.dtype.kind == "f" and len(va):
            scale = max(scale, float(np.abs(va).max()))
    for c in a.columns:
        va, vb = np.asarray(a[c]), np.asarray(b[c])
        if va.dtype.kind == "f":
            if not np.allclose(va, vb, rtol=1e-4, atol=1e-6 * scale):
                return False
        elif va.dtype == object:
            if not all(x == y for x, y in zip(va, vb)):
                return False
        elif not (va == vb).all():
            return False
    return True


def bench(events: int = DEFAULT_EVENTS) -> dict:
    from repro.core import registry
    from repro.core.trace import Trace
    from repro.readers.pack import write_pack
    from repro.serving.protocol import result_digest
    from repro.tracegen import pathology_trace

    iters = _iters_for(events, NPROCS)
    tr, _gt = pathology_trace("straggler", nprocs=NPROCS, iters=iters,
                              magnitude=2.0, seed=0)
    out = {"events": len(tr.events), "nprocs": NPROCS, "ops": {}, "ok": True}
    with tempfile.TemporaryDirectory() as tmp:
        pack = os.path.join(tmp, "backends.pack")
        write_pack(tr, pack)
        eager = Trace.open(pack)
        stream = Trace.open(pack, streaming=True, chunk_rows=CHUNK_ROWS)
        for op, kwargs in OP_CASES.items():
            backends = registry.list_backends(op)
            ref = eager.query().run(op, cache=False, backend="numpy",
                                    **kwargs)
            rec = {"backends": backends}
            for b in backends:
                if b == "numpy":
                    continue
                t0 = time.perf_counter()
                res = eager.query().run(op, cache=False, backend=b,
                                        **kwargs)
                rec[f"{b}_eager_s"] = round(time.perf_counter() - t0, 3)
                rec[f"{b}_matches_numpy"] = _tolerant_equal(op, ref, res)
                t0 = time.perf_counter()
                sres = stream.query().run(op, cache=False, backend=b,
                                          **kwargs)
                rec[f"{b}_stream_s"] = round(time.perf_counter() - t0, 3)
                rec[f"{b}_digest_identical"] = (
                    result_digest(res) == result_digest(sres))
                out["ok"] = (out["ok"] and rec[f"{b}_matches_numpy"]
                             and rec[f"{b}_digest_identical"])
            out["ops"][op] = rec
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--events", type=int, default=DEFAULT_EVENTS)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    res = bench(args.events)
    print(json.dumps(res, indent=1, default=str))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2, default=str)
    return 0 if res["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
