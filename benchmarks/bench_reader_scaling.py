"""Paper Fig. 5 reproduction: reader + op scaling with trace size, parallel
reader speedup, and reader memory growth.

The paper's claims: (left) reader and comm_matrix time scale *linearly* with
rows; (center) the parallel reader scales with cores; (right) memory grows
linearly with rows.  We reproduce all three on generated AMG/Laghos-analog
traces and report the measured scaling exponents.
"""

from __future__ import annotations

import json
import os
import tempfile
import time
import tracemalloc

import numpy as np

from repro import tracegen as tg
from repro.readers import read_jsonl, read_parallel, write_jsonl
from repro.readers.parallel import split_jsonl_by_process


def bench(sizes=(2, 4, 8, 16), iters_base=4) -> dict:
    rows, t_read, t_comm, mem = [], [], [], []
    with tempfile.TemporaryDirectory() as d:
        for mult in sizes:
            tr = tg.stencil3d(nprocs=16, iters=iters_base * mult)
            p = os.path.join(d, f"t{mult}.jsonl")
            write_jsonl(tr, p)
            tracemalloc.start()
            t0 = time.perf_counter()
            t = read_jsonl(p)
            t_read.append(time.perf_counter() - t0)
            _, peak = tracemalloc.get_traced_memory()
            tracemalloc.stop()
            mem.append(peak / 2**20)
            t0 = time.perf_counter()
            t.comm_matrix()
            t_comm.append(time.perf_counter() - t0)
            rows.append(len(t))
        # parallel reader speedup on the largest trace
        tr = tg.stencil3d(nprocs=16, iters=iters_base * sizes[-1])
        full = os.path.join(d, "full.jsonl")
        write_jsonl(tr, full)
        shards = split_jsonl_by_process(full, os.path.join(d, "shards"))
        t0 = time.perf_counter()
        read_parallel(shards, processes=1)
        t_serial = time.perf_counter() - t0
        t0 = time.perf_counter()
        read_parallel(shards, processes=min(4, os.cpu_count() or 1))
        t_par = time.perf_counter() - t0

    def slope(x, y):
        return float(np.polyfit(np.log(x), np.log(np.maximum(y, 1e-9)), 1)[0])

    return {
        "rows": rows,
        "read_s": [round(x, 4) for x in t_read],
        "comm_matrix_s": [round(x, 5) for x in t_comm],
        "reader_mem_mib": [round(x, 2) for x in mem],
        "read_scaling_exponent": round(slope(rows, t_read), 2),
        "comm_matrix_scaling_exponent": round(slope(rows, t_comm), 2),
        "mem_scaling_exponent": round(slope(rows, mem), 2),
        "parallel_reader": {"serial_s": round(t_serial, 3),
                            "parallel_s": round(t_par, 3),
                            "speedup": round(t_serial / max(t_par, 1e-9), 2),
                            "note": "container has 1 core; speedup ≈1 here, "
                                    "scales with cores on a real node"},
    }


if __name__ == "__main__":
    print(json.dumps(bench(), indent=1))
