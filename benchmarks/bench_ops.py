"""Per-operation scaling benchmark (paper §VI): every §IV op timed against
increasing trace sizes; reports the log-log scaling exponent (claim: ≈1)."""

from __future__ import annotations

import json
import time

import numpy as np

from repro import tracegen as tg


def _time(fn, reps=3):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def bench(sizes=(2, 4, 8)) -> dict:
    results = {}
    rows = []
    ops = {
        "flat_profile": lambda t: t.flat_profile(),
        "time_profile": lambda t: t.time_profile(num_bins=64),
        "comm_matrix": lambda t: t.comm_matrix(),
        "message_histogram": lambda t: t.message_histogram(),
        "comm_by_process": lambda t: t.comm_by_process(),
        "load_imbalance": lambda t: t.load_imbalance(),
        "idle_time": lambda t: t.idle_time(),
        "comm_comp_breakdown": lambda t: t.comm_comp_breakdown(),
        "lateness": lambda t: t.calculate_lateness(),
        "critical_path": lambda t: t.critical_path_analysis(),
    }
    times = {k: [] for k in ops}
    for mult in sizes:
        tr = tg.tortuga(nprocs=16, iters=4 * mult)
        tr._ensure_structure()
        rows.append(len(tr))
        for name, fn in ops.items():
            times[name].append(_time(lambda: fn(tr)))
    results["rows"] = rows
    for name in ops:
        y = times[name]
        expo = float(np.polyfit(np.log(rows),
                                np.log(np.maximum(y, 1e-9)), 1)[0])
        results[name] = {"seconds": [round(x, 5) for x in y],
                         "scaling_exponent": round(expo, 2)}
    return results


if __name__ == "__main__":
    print(json.dumps(bench(), indent=1))
