"""Case-study benchmark (paper §VII analogues): runs each study end-to-end
and asserts/reports the paper's qualitative finding on our generated traces.
One entry per paper figure — this is the 'tables' harness for §VII."""

from __future__ import annotations

import json

import numpy as np

from repro import tracegen as tg
from repro.core.constants import NAME, PROC
from repro.core.trace import Trace


def study_load_imbalance():
    t = tg.loimos(nprocs=128, iters=4)
    li = t.load_imbalance(num_processes=5)
    idx = {n: i for i, n in enumerate(li[NAME])}
    i = idx["ComputeInteractions()"]
    return {"figure": "Fig.7", "top_imbalance": float(li["time.exc.imbalance"][i]),
            "top_processes": [int(p) for p in li["Top processes"][i]],
            "finding": "hot actors 21-29 overloaded (paper: same set)"}


def study_patterns():
    t = tg.tortuga(nprocs=16, iters=6)
    pats = t.detect_pattern(start_event="time-loop")
    return {"figure": "Fig.8", "iterations_detected": len(pats),
            "expected": 6}


def study_idle_time():
    t = tg.loimos(nprocs=64, iters=4)
    idle = t.idle_time(k=8)
    most = idle[PROC][:3].tolist()
    filtered = t.filter_processes([int(p) for p in most])
    return {"figure": "Fig.9", "most_idle": [int(p) for p in most],
            "reduced_rows": len(filtered), "full_rows": len(t)}


def study_critical_path():
    t = tg.gol(nprocs=4, iters=10)
    cp = t.critical_path_analysis()[0]
    return {"figure": "Fig.10", "path_len": len(cp),
            "procs_on_path": sorted(set(int(p) for p in cp[PROC]))}


def study_lateness():
    t = tg.gol(nprocs=8, iters=8, imbalance=0.4)
    lb = t.lateness_by_process()
    return {"figure": "Fig.11",
            "max_lateness_proc": int(lb[PROC][0]),
            "max_lateness_ns": float(lb["max_lateness"][0])}


def study_overlap():
    out = {}
    for v in (0, 1, 2):
        t = tg.axonn_training(nprocs=8, iters=6, version=v)
        bd = t.comm_comp_breakdown()
        out[f"v{v}"] = {k: float(np.asarray(bd[k]).mean())
                        for k in ("comp_only", "overlap", "comm_only")}
    return {"figure": "Fig.13", "versions": out,
            "finding": "v1 cuts comm volume; v2 overlaps the remainder"}


def study_multirun():
    traces = [tg.tortuga(nprocs=n, iters=3) for n in (16, 32, 64, 128)]
    df = Trace.multirun_analysis(traces, top_n=5)
    return {"figure": "Fig.12",
            "functions": [c for c in df.columns if c != "num_processes"][:5],
            "computeRhs_by_procs": [float(x) for x in df["computeRhs"]]}


STUDIES = {
    "load_imbalance": study_load_imbalance,
    "patterns": study_patterns,
    "idle_time": study_idle_time,
    "critical_path": study_critical_path,
    "lateness": study_lateness,
    "overlap": study_overlap,
    "multirun": study_multirun,
}


def bench() -> dict:
    return {name: fn() for name, fn in STUDIES.items()}


if __name__ == "__main__":
    print(json.dumps(bench(), indent=1))
