"""Roofline table builder: reads the dry-run artifacts and renders the
EXPERIMENTS.md §Roofline table (one row per arch × shape × mesh)."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")

COLS = ("arch", "shape", "mesh", "bottleneck", "compute_s", "memory_s",
        "collective_s", "step_time_s", "useful_flop_frac", "mfu_bound")


def load_records(art_dir: str = ART_DIR) -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt(x, nd=3):
    if isinstance(x, float):
        return f"{x:.3e}" if (abs(x) < 1e-2 or abs(x) > 1e4) else f"{x:.3f}"
    return str(x)


def table(records: List[Dict], mesh: str = None) -> str:
    rows = []
    for r in records:
        if mesh and r["mesh"] != mesh:
            continue
        rl = r["roofline"]
        mem = r["memory_analysis"]
        rows.append([
            r["arch"], r["shape"], r["mesh"], rl["bottleneck"],
            fmt(rl["compute_s"]), fmt(rl["memory_s"]),
            fmt(rl["collective_s"]), fmt(rl["step_time_s"]),
            f"{rl.get('useful_flop_frac', 0):.3f}",
            f"{rl.get('mfu_bound', 0) * 100:.2f}%",
            f"{(mem['peak_size'] or 0) / 2**30:.2f}",
        ])
    hdr = ["arch", "shape", "mesh", "bound", "compute[s]", "memory[s]",
           "collective[s]", "step≥[s]", "useful/HLO", "MFU-bound",
           "peak GiB/dev"]
    lines = ["| " + " | ".join(hdr) + " |",
             "|" + "|".join(["---"] * len(hdr)) + "|"]
    for row in rows:
        lines.append("| " + " | ".join(row) + " |")
    return "\n".join(lines)


def main():
    recs = load_records()
    if not recs:
        print("no dry-run artifacts found — run repro.launch.dryrun first")
        return
    print(f"# Roofline — BASELINE ({len(recs)} cells)\n")
    for mesh in ("pod16x16", "pod2x16x16"):
        sub = [r for r in recs if r["mesh"] == mesh]
        if sub:
            print(f"\n## mesh {mesh} ({len(sub)} cells)\n")
            print(table(sub))
    # bottleneck census
    census: Dict[str, int] = {}
    for r in recs:
        census[r["roofline"]["bottleneck"]] = census.get(
            r["roofline"]["bottleneck"], 0) + 1
    print("\nbottleneck census:", census)

    opt_dir = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "dryrun_opt")
    opt = load_records(opt_dir) if os.path.isdir(opt_dir) else []
    if opt:
        print(f"\n# Roofline — OPTIMIZED archs after §Perf ({len(opt)} cells)\n")
        print(table(opt))
        base = {(r["arch"], r["shape"], r["mesh"]): r for r in recs}
        print("\n## step-bound improvement vs baseline\n")
        for r in opt:
            b = base.get((r["arch"], r["shape"], r["mesh"]))
            if b:
                s0 = b["roofline"]["step_time_s"]
                s1 = r["roofline"]["step_time_s"]
                print(f"  {r['arch']:22s} {r['shape']:12s} {r['mesh']:10s} "
                      f"{s0:9.3f}s → {s1:9.3f}s  ({s0 / max(s1, 1e-12):5.2f}×)")


if __name__ == "__main__":
    main()
