"""Roofline table builder.

Two modes:

* default — reads the dry-run artifacts and renders the EXPERIMENTS.md
  §Roofline table (one row per arch × shape × mesh).
* ``--ops`` — **op-bandwidth roofline** for the analysis-op backend
  registry: generates a pack-suite trace at ``--events`` scale, runs every
  registered backend of every kernel-backed op, and reports achieved vs.
  peak bytes/s (peak = a measured host STREAM-copy rate; on a real TPU the
  HBM roofline applies instead).  ``--json`` writes the records for CI
  artifact upload.

Run standalone::

    PYTHONPATH=src python -m benchmarks.roofline [--ops] [--events N]
        [--json PATH]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import tempfile
import time
from typing import Dict, List

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")

# single source of truth for the dry-run roofline table: artifact key →
# rendered column header, in display order (the row builder below is
# checked against it, so the two can no longer drift apart)
COLUMNS = (
    ("arch", "arch"),
    ("shape", "shape"),
    ("mesh", "mesh"),
    ("bottleneck", "bound"),
    ("compute_s", "compute[s]"),
    ("memory_s", "memory[s]"),
    ("collective_s", "collective[s]"),
    ("step_time_s", "step≥[s]"),
    ("useful_flop_frac", "useful/HLO"),
    ("mfu_bound", "MFU-bound"),
    ("peak_gib_per_dev", "peak GiB/dev"),
)
COLS = tuple(key for key, _hdr in COLUMNS)

DEFAULT_OPS_EVENTS = int(os.environ.get("ROOFLINE_OPS_EVENTS", 10_000_000))
OPS_NPROCS = 8

# bytes each backend must stream per record at minimum: the canonical
# record fields the kernels consume (see docs/kernels.md) — call-record
# ops read (start, end, proc, code, value) f64/i64, comm_matrix reads
# (src, dst, size, ts), message_histogram just the sizes
OP_RECORD_BYTES = {
    "flat_profile": 40,
    "time_profile": 40,
    "load_imbalance": 40,
    "stragglers": 40,
    "comm_matrix": 32,
    "message_histogram": 8,
}


def load_records(art_dir: str = ART_DIR) -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt(x, nd=3):
    if isinstance(x, float):
        return f"{x:.3e}" if (abs(x) < 1e-2 or abs(x) > 1e4) else f"{x:.3f}"
    return str(x)


def table(records: List[Dict], mesh: str = None) -> str:
    rows = []
    for r in records:
        if mesh and r["mesh"] != mesh:
            continue
        rl = r["roofline"]
        mem = r["memory_analysis"]
        cells = {
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "bottleneck": rl["bottleneck"],
            "compute_s": fmt(rl["compute_s"]),
            "memory_s": fmt(rl["memory_s"]),
            "collective_s": fmt(rl["collective_s"]),
            "step_time_s": fmt(rl["step_time_s"]),
            "useful_flop_frac": f"{rl.get('useful_flop_frac', 0):.3f}",
            "mfu_bound": f"{rl.get('mfu_bound', 0) * 100:.2f}%",
            "peak_gib_per_dev": f"{(mem['peak_size'] or 0) / 2**30:.2f}",
        }
        rows.append([cells[key] for key in COLS])
    hdr = [h for _key, h in COLUMNS]
    lines = ["| " + " | ".join(hdr) + " |",
             "|" + "|".join(["---"] * len(hdr)) + "|"]
    for row in rows:
        lines.append("| " + " | ".join(row) + " |")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# --ops: analysis-op backend bandwidth roofline
# ---------------------------------------------------------------------------

def measured_peak_bytes_s() -> float:
    """Host memory-bandwidth ceiling: best of a few big STREAM-style copies
    (read + write counted, like STREAM's Copy kernel)."""
    import numpy as np
    a = np.random.default_rng(0).random(1 << 25)  # 256 MiB
    b = np.empty_like(a)
    best = 0.0
    for _ in range(3):
        t0 = time.perf_counter()
        np.copyto(b, a)
        best = max(best, 2 * a.nbytes / (time.perf_counter() - t0))
    return best


def _ops_trace(events: int, tmp: str):
    """A packed straggler trace near ``events`` rows (the 10M-event pack
    suite of the detector benchmarks), opened eagerly."""
    from repro.core.trace import Trace
    from repro.readers.pack import write_pack
    from repro.tracegen import baseline, pathology_trace

    probe = baseline(nprocs=OPS_NPROCS, iters=8, seed=0)
    per_iter = max(1.0, len(probe.events) / 8.0)
    iters = max(16, int(round(events / per_iter)))
    tr, _gt = pathology_trace("straggler", nprocs=OPS_NPROCS, iters=iters,
                              magnitude=2.0, seed=0)
    pack = os.path.join(tmp, "roofline_ops.pack")
    write_pack(tr, pack)
    return Trace.open(pack)


def op_bandwidth(events: int = DEFAULT_OPS_EVENTS) -> Dict:
    """Achieved vs. peak bytes/s for every registered backend of every
    kernel-backed op at ``events`` scale."""
    import numpy as np
    from repro.core import registry
    from repro.core.constants import ENTER, ET, MPI_SEND, NAME

    peak = measured_peak_bytes_s()
    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        trace = _ops_trace(events, tmp)
        ev = trace.events
        is_enter = ev.cat(ET).mask_eq(ENTER)
        match = np.asarray(ev.column("_matching_event"), np.int64)
        n_calls = int((is_enter & (match >= 0)).sum())
        n_sends = int(ev.cat(NAME).mask_eq(MPI_SEND).sum())
        n_records = {"comm_matrix": n_sends, "message_histogram": n_sends}
        q = trace.query()
        for op in sorted(OP_RECORD_BYTES):
            backends = registry.list_backends(op)
            nrec = n_records.get(op, n_calls)
            nbytes = nrec * OP_RECORD_BYTES[op]
            for b in backends:
                t0 = time.perf_counter()
                q.run(op, cache=False, backend=b)
                wall = time.perf_counter() - t0
                rows.append({
                    "op": op, "backend": b, "records": nrec,
                    "bytes": nbytes, "wall_s": round(wall, 3),
                    "achieved_gib_s": round(nbytes / wall / 2**30, 3),
                    "frac_of_peak": round(nbytes / wall / peak, 6),
                })
        n_events = len(ev)
    return {"mode": "op_bandwidth", "events": n_events,
            "nprocs": OPS_NPROCS, "peak_gib_s": round(peak / 2**30, 2),
            "interpret_mode": os.environ.get("REPRO_PALLAS_COMPILE",
                                             "0") != "1",
            "rows": rows, "ok": True}


def ops_table(report: Dict) -> str:
    hdr = ["op", "backend", "records", "wall[s]", "achieved GiB/s",
           "peak GiB/s", "% of peak"]
    lines = ["| " + " | ".join(hdr) + " |",
             "|" + "|".join(["---"] * len(hdr)) + "|"]
    for r in report["rows"]:
        lines.append(
            f"| {r['op']} | {r['backend']} | {r['records']} "
            f"| {r['wall_s']:.3f} | {r['achieved_gib_s']:.3f} "
            f"| {report['peak_gib_s']:.1f} "
            f"| {r['frac_of_peak'] * 100:.3f}% |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ops", action="store_true",
                    help="op-backend bandwidth roofline instead of the "
                         "dry-run table")
    ap.add_argument("--events", type=int, default=DEFAULT_OPS_EVENTS,
                    help="trace size for --ops (default %(default)s)")
    ap.add_argument("--json", default=None,
                    help="also write the --ops records to this path")
    args = ap.parse_args(argv)

    if args.ops:
        report = op_bandwidth(args.events)
        print(f"# Op-backend bandwidth — {report['events']} events, "
              f"interpret={report['interpret_mode']}\n")
        print(ops_table(report))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(report, f, indent=2)
            print(f"\nwrote {args.json}")
        return

    recs = load_records()
    if not recs:
        print("no dry-run artifacts found — run repro.launch.dryrun first")
        return
    print(f"# Roofline — BASELINE ({len(recs)} cells)\n")
    for mesh in ("pod16x16", "pod2x16x16"):
        sub = [r for r in recs if r["mesh"] == mesh]
        if sub:
            print(f"\n## mesh {mesh} ({len(sub)} cells)\n")
            print(table(sub))
    # bottleneck census
    census: Dict[str, int] = {}
    for r in recs:
        census[r["roofline"]["bottleneck"]] = census.get(
            r["roofline"]["bottleneck"], 0) + 1
    print("\nbottleneck census:", census)

    opt_dir = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "dryrun_opt")
    opt = load_records(opt_dir) if os.path.isdir(opt_dir) else []
    if opt:
        print(f"\n# Roofline — OPTIMIZED archs after §Perf ({len(opt)} cells)\n")
        print(table(opt))
        base = {(r["arch"], r["shape"], r["mesh"]): r for r in recs}
        print("\n## step-bound improvement vs baseline\n")
        for r in opt:
            b = base.get((r["arch"], r["shape"], r["mesh"]))
            if b:
                s0 = b["roofline"]["step_time_s"]
                s1 = r["roofline"]["step_time_s"]
                print(f"  {r['arch']:22s} {r['shape']:12s} {r['mesh']:10s} "
                      f"{s0:9.3f}s → {s1:9.3f}s  ({s0 / max(s1, 1e-12):5.2f}×)")


if __name__ == "__main__":
    main()
