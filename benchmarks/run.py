"""Benchmark runner: ``PYTHONPATH=src python -m benchmarks.run``

Benchmarks are auto-enumerated: every ``benchmarks/bench_*.py`` module
exposing a ``bench()`` callable runs as one section (alphabetical order),
followed by the roofline table.  Adding a benchmark file is enough — no
index list to update.

``--json PATH`` additionally writes every section's result dict (keyed by
module name) as one JSON document — CI uploads it as the perf artifact,
and repo-root ``BENCH_PR<N>.json`` snapshots are taken the same way.

``--only NAME`` runs a single section (e.g. ``--only bench_parallel``).

``--events N`` scales every trace-generating section down (or up): each
``bench()`` whose signature accepts an ``events`` parameter gets it passed
through.  The full suite at the 10M default takes tens of minutes on a
small container; ``--events 1000000`` is the CI/local smoke preset.
"""

from __future__ import annotations

import argparse
import importlib
import inspect
import json
import pkgutil
import sys
import time


def discover() -> list:
    """Names of every bench_* module in this package (no import cost)."""
    import benchmarks
    return sorted(m.name for m in pkgutil.iter_modules(benchmarks.__path__)
                  if m.name.startswith("bench_"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", dest="json_path",
                    help="write all section results to PATH as JSON")
    ap.add_argument("--only", help="run a single section by module name")
    ap.add_argument("--events", type=int, default=None,
                    help="event-count scale knob forwarded to every "
                    "bench() that accepts an events parameter")
    args = ap.parse_args(argv)

    t0 = time.time()
    print("=" * 72)
    print("repro benchmarks — Pipit on TPU")
    print("=" * 72)

    names = discover()
    if args.only:
        if args.only not in names + ["roofline"]:
            print(f"unknown benchmark {args.only!r}; available: "
                  f"{names + ['roofline']}", file=sys.stderr)
            return 2
        names = [args.only] if args.only != "roofline" else []
    total = len(names) + (0 if args.only and args.only != "roofline" else 1)
    results = {}
    for i, name in enumerate(names, 1):
        mod = importlib.import_module(f"benchmarks.{name}")
        title = (mod.__doc__ or name).strip().splitlines()[0].rstrip(".")
        if not callable(getattr(mod, "bench", None)):
            # standalone drivers (e.g. bench_serve spawns its own server
            # subprocess) run via python -m, not from this loop
            print(f"\n## [{i}/{total}] {name}: {title} "
                  f"(standalone driver, skipped)")
            continue
        print(f"\n## [{i}/{total}] {name}: {title}")
        kwargs = {}
        if args.events is not None and "events" in inspect.signature(
                mod.bench).parameters:
            kwargs["events"] = args.events
        res = mod.bench(**kwargs)
        results[name] = res
        print(json.dumps(res, indent=1, default=str))

    if not args.only or args.only == "roofline":
        from . import roofline
        print(f"\n## [{total}/{total}] roofline: table from dry-run "
              f"artifacts")
        roofline.main([])  # explicit argv: don't re-parse run.py's flags
        results["roofline"] = "rendered to stdout (reads dry-run artifacts)"

    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(results, f, indent=1, default=str)
        print(f"\nwrote {args.json_path}")

    print(f"\nall benchmarks done in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
