"""Benchmark runner: ``PYTHONPATH=src python -m benchmarks.run``

Sections (one per paper table/figure + the roofline deliverable):
  1. reader/op scaling (Fig. 5)          — bench_reader_scaling
  2. per-op scaling exponents (§VI)      — bench_ops
  3. lazy query plans vs eager (§IV-E)   — bench_query_plan
  4. TraceDiff shared-plan diffs (§IV-D) — bench_diff
  5. out-of-core streaming vs in-memory  — bench_streaming
  6. case studies (§VII, Figs. 7-13)     — bench_case_studies
  7. Pallas kernel roofline              — bench_kernels
  8. roofline table (all dry-run cells)  — roofline
"""

from __future__ import annotations

import json
import sys
import time


def main():
    t0 = time.time()
    print("=" * 72)
    print("repro benchmarks — Pipit on TPU")
    print("=" * 72)

    from . import bench_reader_scaling
    print("\n## [1/8] Reader & op scaling vs trace size (paper Fig. 5)")
    print(json.dumps(bench_reader_scaling.bench(), indent=1))

    from . import bench_ops
    print("\n## [2/8] Per-operation scaling exponents (paper §VI)")
    print(json.dumps(bench_ops.bench(), indent=1))

    from . import bench_query_plan
    print("\n## [3/8] Lazy query plans: fused chain vs eager seed path (§IV-E)")
    print(json.dumps(bench_query_plan.bench(), indent=1))

    from . import bench_diff
    print("\n## [4/8] TraceDiff: shared-plan N-trace diff vs sequential runs (§IV-D)")
    print(json.dumps(bench_diff.bench(), indent=1))

    from . import bench_streaming
    print("\n## [5/8] Out-of-core streaming vs in-memory (peak RSS, identical results)")
    print(json.dumps(bench_streaming.bench(), indent=1))

    from . import bench_case_studies
    print("\n## [6/8] Case studies (paper §VII, Figs. 7-13)")
    print(json.dumps(bench_case_studies.bench(), indent=1))

    from . import bench_kernels
    print("\n## [7/8] Pallas kernel block-size roofline")
    print(json.dumps(bench_kernels.bench(), indent=1))

    from . import roofline
    print("\n## [8/8] Roofline table (from dry-run artifacts)")
    roofline.main()

    print(f"\nall benchmarks done in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
