"""Fused lazy query plans vs the eager filter chain (ISSUE 1 acceptance).

A 3-step data-reduction chain (call-interval window → trimmed time-window
filter → process restriction) followed by ``flat_profile`` on a ~1M-event
synthetic trace, timed three ways:

* **seed eager path**: what the seed Trace methods did — every step
  materializes a sub-frame and strips all derived columns, so enter/leave
  matching re-runs at each structure-dependent step and once more for the
  profile (3× total here);
* **current eager methods**: the same chain through today's Trace methods,
  which are one-step query plans (structure is remapped, not recomputed);
* **lazy plan** (``trace.query()``): masks fuse into one application, the
  plan materializes a single sub-frame, and structure is computed exactly
  once.

Acceptance: lazy ≥ 2× over the seed path with byte-identical profiles.
Also reports a pure 3-filter fusion chain (no structure dependence).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import Filter, Trace, time_window_filter
from repro.core.constants import ET, NAME, PROC, TS
from repro.core.frame import Categorical, EventFrame
from repro.core.query import _overlap_mask

_FUNCS = ("compute()", "exchange()", "reduce()", "io()", "solve()")


def synth_trace(n_events: int = 1_000_000, nprocs: int = 32,
                seed: int = 0) -> EventFrame:
    """Vectorized balanced call forest: per process, repeated
    outer(inner) call pairs over a handful of function names."""
    rng = np.random.default_rng(seed)
    per_proc = max(n_events // (4 * nprocs), 1)   # 4 events per iteration
    n = per_proc * 4 * nprocs
    # per-process pattern: Enter f / Enter g / Leave g / Leave f
    et = np.tile(np.asarray([0, 0, 1, 1], np.int32), per_proc * nprocs)
    outer = rng.integers(0, len(_FUNCS), size=per_proc * nprocs)
    inner = rng.integers(0, len(_FUNCS), size=per_proc * nprocs)
    name_codes = np.empty(n, np.int32)
    name_codes[0::4] = outer
    name_codes[1::4] = inner
    name_codes[2::4] = inner
    name_codes[3::4] = outer
    proc = np.repeat(np.arange(nprocs, dtype=np.int64), per_proc * 4)
    # strictly increasing per-process clocks with jittered durations
    dur = rng.integers(1, 1000, size=per_proc * nprocs * 4).astype(np.int64)
    ts = np.empty(n, np.int64)
    for p in range(nprocs):
        lo, hi = p * per_proc * 4, (p + 1) * per_proc * 4
        ts[lo:hi] = np.cumsum(dur[lo:hi])
    ev = EventFrame({
        TS: ts,
        ET: Categorical.from_codes(et, np.asarray(["Enter", "Leave"])),
        NAME: Categorical.from_codes(name_codes, np.asarray(_FUNCS)),
        PROC: proc,
    })
    return ev


def _seed_select(trace, mask):
    # the seed's data-reduction strategy: materialize + strip derived columns
    return Trace(Trace._strip_structure(trace.events.mask(mask)),
                 definitions=trace.definitions, label=trace.label)


def _chain_seed(trace, w1, w2, procs):
    """The seed eager path: strip-and-recompute at every step (with the
    fixed call-interval trim semantics, for byte-identical outputs)."""
    trace._ensure_structure()
    t1 = _seed_select(trace, _overlap_mask(trace, *w1))
    t1._ensure_structure()                      # recompute #2
    t2 = _seed_select(t1, _overlap_mask(t1, *w2))
    t3 = _seed_select(
        t2, np.isin(np.asarray(t2.events[PROC], np.int64), procs))
    return t3.flat_profile()                    # recompute #3


def _chain_eager(trace, w1, w2, procs):
    return (trace.slice_time(*w1)
            .filter(time_window_filter(*w2, trim="overlap"))
            .filter_processes(procs)
            .flat_profile())


def _chain_lazy(trace, w1, w2, procs):
    return (trace.query()
            .slice_time(*w1)
            .filter(time_window_filter(*w2, trim="overlap"))
            .restrict_processes(procs)
            .flat_profile())


def _filters_eager(trace, f1, f2, f3):
    return trace.filter(f1).filter(f2).filter(f3).flat_profile()


def _filters_lazy(trace, f1, f2, f3):
    return trace.query().filter(f1).filter(f2).filter(f3).flat_profile()


def _time(fn, ev_master, reps=3):
    best, out = np.inf, None
    for _ in range(reps):
        trace = Trace(ev_master.copy())     # fresh: no cached structure
        t0 = time.perf_counter()
        out = fn(trace)
        best = min(best, time.perf_counter() - t0)
    return best, out


def _identical(fa, fb) -> bool:
    if list(fa.columns) != list(fb.columns):
        return False
    for c in fa.columns:
        a, b = np.asarray(fa[c]), np.asarray(fb[c])
        same = (np.array_equal(a, b, equal_nan=True)
                if a.dtype.kind == "f" else np.array_equal(a, b))
        if not same:
            return False
    return True


def bench(n_events: int = 1_000_000, reps: int = 5) -> dict:
    ev = synth_trace(n_events)
    ts = np.asarray(ev[TS], np.float64)
    lo, hi = float(ts.min()), float(ts.max())
    w1 = (lo + 0.05 * (hi - lo), lo + 0.95 * (hi - lo))
    w2 = (lo + 0.10 * (hi - lo), lo + 0.90 * (hi - lo))
    procs = list(range(24))

    t_seed, fp_seed = _time(
        lambda t: _chain_seed(t, w1, w2, procs), ev, reps)
    t_eager, fp_eager = _time(
        lambda t: _chain_eager(t, w1, w2, procs), ev, reps)
    t_lazy, fp_lazy = _time(
        lambda t: _chain_lazy(t, w1, w2, procs), ev, reps)
    identical = _identical(fp_seed, fp_lazy) and _identical(fp_eager, fp_lazy)

    f1 = Filter(NAME, "not-in", ["io()"])
    f2 = Filter(TS, "between", w1)
    f3 = Filter(PROC, "<", 24)
    tf_eager, ff_eager = _time(
        lambda t: _filters_eager(t, f1, f2, f3), ev, reps)
    tf_lazy, ff_lazy = _time(
        lambda t: _filters_lazy(t, f1, f2, f3), ev, reps)

    out = {
        "events": len(ev),
        "window_chain": {
            "seed_eager_s": round(t_seed, 4),
            "eager_methods_s": round(t_eager, 4),
            "lazy_s": round(t_lazy, 4),
            "speedup_vs_seed": round(t_seed / t_lazy, 2),
            "speedup_vs_eager_methods": round(t_eager / t_lazy, 2),
            "identical_results": bool(identical),
        },
        "pure_filter_chain": {
            "eager_s": round(tf_eager, 4),
            "lazy_s": round(tf_lazy, 4),
            "speedup": round(tf_eager / tf_lazy, 2),
            "identical_results": bool(_identical(ff_eager, ff_lazy)),
        },
    }
    out["acceptance_2x"] = bool(
        out["window_chain"]["speedup_vs_seed"] >= 2.0 and identical)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(bench(), indent=1))
