"""Parallel plan execution vs serial streaming: wall-clock and identical
digests (acceptance benchmark of the multi-core executor).

Generates the 10M-event sharded ``tracegen.big_trace`` (written shard by
shard, never held in memory), then runs the combinable-op suite twice in
separate subprocesses:

* **serial** — ``Trace.open(shards, streaming=True)``: one process folds
  every chunk;
* **parallel** — ``executor="parallel", processes=N``: work units (whole
  shards and/or byte ranges) fan over a spawn pool; partial aggregates and
  cross-seam call carries merge in the parent.

Every exactly-combinable op (flat_profile, per-process profile,
load_imbalance, idle_time, comm_matrix, comm_by_process,
message_histogram) is SHA-256-digested in both modes; digests must match
byte for byte.  The parallel phase also times a repeated ``flat_profile``
to report the plan-result cache hit cost.

Target: >= 3x speedup over serial streaming at >= 4 workers (enforced only
when the machine actually has that many cores — on smaller containers the
measured speedup and core count are reported as-is).

Run standalone::

    PYTHONPATH=src python -m benchmarks.bench_parallel [--events N]
        [--workers N] [--json PATH]

BENCH_PAR_EVENTS / BENCH_PAR_WORKERS override the defaults (CI smoke uses
~1M events at 2 workers).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import subprocess
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_EVENTS = int(os.environ.get("BENCH_PAR_EVENTS", 10_000_000))
DEFAULT_WORKERS = int(os.environ.get(
    "BENCH_PAR_WORKERS", min(4, os.cpu_count() or 1)))
NPROCS = 8
CHUNK_ROWS = 250_000
SPEEDUP_TARGET = 3.0


def _digest_ops(handle) -> str:
    """One SHA-256 over every exactly-combinable op's result."""
    import numpy as np
    h = hashlib.sha256()

    def frame(prof):
        for c in prof.columns:
            v = prof[c]
            if np.asarray(v).dtype.kind in "UO":
                h.update("\x00".join(map(str, v)).encode())
            else:
                h.update(np.ascontiguousarray(np.asarray(v,
                                                         np.float64)).tobytes())

    frame(handle.flat_profile(metrics=["time.exc", "time.inc"]))
    frame(handle.flat_profile(per_process=True))
    frame(handle.load_imbalance())
    frame(handle.idle_time())
    h.update(np.ascontiguousarray(handle.comm_matrix()).tobytes())
    frame(handle.comm_by_process())
    counts, edges = handle.message_histogram()
    h.update(np.ascontiguousarray(counts).tobytes())
    h.update(np.ascontiguousarray(edges).tobytes())
    return h.hexdigest()


def run_phase(mode: str, shard_dir: str, workers: int) -> None:
    """Child process: one execution mode, JSON result on stdout."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.core.trace import Trace
    shards = sorted(os.path.join(shard_dir, f) for f in os.listdir(shard_dir))
    if mode == "serial":
        handle = Trace.open(shards, streaming=True, chunk_rows=CHUNK_ROWS,
                            cache=False)
    else:
        handle = Trace.open(shards, streaming=True, chunk_rows=CHUNK_ROWS,
                            executor="parallel", processes=workers,
                            cache=False)
    t0 = time.time()
    digest = _digest_ops(handle)
    dt = time.time() - t0
    out = {"mode": mode, "seconds": round(dt, 2), "digest": digest}
    if mode == "parallel":
        # plan-result cache: repeat one op cold vs cached
        handle.cache = True
        t0 = time.time()
        handle.flat_profile()
        out["cache_miss_seconds"] = round(time.time() - t0, 3)
        t0 = time.time()
        handle.flat_profile()
        out["cache_hit_seconds"] = round(time.time() - t0, 6)
    print(json.dumps(out))


def bench(events: int = DEFAULT_EVENTS, workers: int = DEFAULT_WORKERS) -> dict:
    from repro.tracegen import big_trace
    out = {"events": events, "workers": workers,
           "cpu_count": os.cpu_count(), "chunk_rows": CHUNK_ROWS,
           "nprocs": NPROCS}
    with tempfile.TemporaryDirectory(prefix="bench_par_") as d:
        shard_dir = os.path.join(d, "shards")
        t0 = time.time()
        big_trace(shard_dir, nprocs=NPROCS,
                  events_per_proc=max(events // NPROCS, 1000))
        out["gen_seconds"] = round(time.time() - t0, 1)
        out["trace_mb"] = round(sum(
            os.path.getsize(os.path.join(shard_dir, f))
            for f in os.listdir(shard_dir)) / 1e6, 1)
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src")
                   + os.pathsep + os.environ.get("PYTHONPATH", ""))
        for mode in ("serial", "parallel"):
            r = subprocess.run(
                [sys.executable, "-m", "benchmarks.bench_parallel",
                 "--phase", mode, "--shards", shard_dir,
                 "--workers", str(workers)],
                capture_output=True, text=True, cwd=REPO, env=env,
                check=True)
            out[mode] = json.loads(r.stdout.strip().splitlines()[-1])
    out["identical"] = out["serial"]["digest"] == out["parallel"]["digest"]
    out["speedup"] = round(out["serial"]["seconds"]
                           / max(out["parallel"]["seconds"], 1e-9), 2)
    cache_hit = out["parallel"].get("cache_hit_seconds", 0.0)
    cache_miss = out["parallel"].get("cache_miss_seconds", 0.0)
    out["cache_speedup"] = round(cache_miss / max(cache_hit, 1e-9), 1)
    # the 3x gate needs the workers to actually exist as cores
    out["speedup_gate_active"] = (workers >= 4
                                  and (os.cpu_count() or 1) >= workers)
    out["target_met"] = (not out["speedup_gate_active"]
                         or out["speedup"] >= SPEEDUP_TARGET)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--events", type=int, default=DEFAULT_EVENTS)
    ap.add_argument("--workers", type=int, default=DEFAULT_WORKERS)
    ap.add_argument("--json", dest="json_path",
                    help="write the result dict to PATH as JSON")
    ap.add_argument("--phase", choices=["serial", "parallel"])
    ap.add_argument("--shards")
    args = ap.parse_args(argv)
    if args.phase:
        run_phase(args.phase, args.shards, args.workers)
        return 0
    res = bench(args.events, args.workers)
    print(json.dumps(res, indent=1))
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(res, f, indent=1)
    if not res["identical"]:
        print("FAIL: parallel digests differ from serial streaming",
              file=sys.stderr)
        return 1
    if not res["target_met"]:
        print(f"FAIL: speedup {res['speedup']}x below "
              f"{SPEEDUP_TARGET}x target at {res['workers']} workers",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
