"""TraceDiff shared-plan execution vs N sequential single-trace runs (ISSUE 2).

The comparison workflow runs several diff ops (here: regression_report,
diff_flat_profile, diff_load_imbalance) over the same selected window of N
traces.  Two ways to pay for it:

* **sequential single-trace runs** (what scripting without TraceSet looks
  like): for every op, for every trace, re-run the eager selection chain and
  the per-trace analysis, then combine — each (op, trace) pair re-pays
  selection and enter/leave matching;
* **shared plan** (``TraceSet.query()``): ONE lazy plan is materialized per
  member (fused masks, structure remapped once) and *cached across the
  ops*, so the three comparisons reuse the same prepared members.

Also reports the optional process-parallel preparation path
(``processes=4``), which fans per-member collect+matching over a pool.

Acceptance: shared-plan >= 2x over the sequential path with identical
reports.
"""

from __future__ import annotations

import time

import numpy as np

from repro import tracegen as tg
from repro.core import Filter, TraceSet
from repro.core.constants import NAME, TS
from repro.core.diff import (diff_flat_profile, diff_load_imbalance,
                             regression_report)


def _make_traces(n_traces: int, nprocs: int, iters: int):
    """Half unperturbed, half with a known computeRhs regression."""
    out = []
    for i in range(n_traces):
        perturb = {"computeRhs": 1.5} if i % 2 else None
        t = tg.tortuga(nprocs=nprocs, iters=iters, seed=i // 2,
                       perturb=perturb)
        t.label = f"run{i}{'+regress' if perturb else ''}"
        out.append(t)
    return out


def _window(traces):
    ts = np.asarray(traces[0].events[TS], np.float64)
    return float(np.percentile(ts, 5)), float(np.percentile(ts, 95))


# exclude structural wrappers (the root call's exclusive time absorbs
# whatever the window cuts off, which differs between runs of different
# length) — the same move an analyst scripts when diffing leaf work
_FILTER = Filter(NAME, "not-in", ["MPI_Isend", "main()", "time-loop"])


def _sequential(traces, lo, hi):
    """Per op, per trace: fresh eager chain + per-trace analysis."""
    results = {}
    for key, setop in (("regression", regression_report),
                       ("profile", diff_flat_profile),
                       ("imbalance", diff_load_imbalance)):
        selected = [t.slice_time(lo, hi).filter(_FILTER) for t in traces]
        results[key] = setop(selected)
    return results

def _shared(traces, lo, hi, processes=None):
    q = TraceSet(traces).query().slice_time(lo, hi).filter(_FILTER)
    return {
        "regression": q.run("regression_report", processes=processes),
        "profile": q.run("diff_flat_profile", processes=processes),
        "imbalance": q.run("diff_load_imbalance", processes=processes),
    }


def _strip_structure(traces):
    """Fresh Trace objects with no cached derivations (fair re-timing)."""
    from repro.core.trace import Trace
    out = []
    for t in traces:
        nt = Trace(Trace._strip_structure(t.events).copy(), label=t.label)
        out.append(nt)
    return out


def _time(fn, traces, reps):
    best, out = np.inf, None
    for _ in range(reps):
        fresh = _strip_structure(traces)
        t0 = time.perf_counter()
        out = fn(fresh)
        best = min(best, time.perf_counter() - t0)
    return best, out


def _identical(a, b) -> bool:
    for key in a:
        fa, fb = a[key], b[key]
        if list(fa.columns) != list(fb.columns):
            return False
        for c in fa.columns:
            x, y = np.asarray(fa[c]), np.asarray(fb[c])
            same = (np.array_equal(x, y, equal_nan=True)
                    if x.dtype.kind == "f" else np.array_equal(x, y))
            if not same:
                return False
    return True


def bench(n_traces: int = 4, nprocs: int = 32, iters: int = 24,
          reps: int = 3) -> dict:
    master = _make_traces(n_traces, nprocs, iters)
    lo, hi = _window(master)

    t_seq, r_seq = _time(lambda ts: _sequential(ts, lo, hi), master, reps)
    t_shared, r_shared = _time(lambda ts: _shared(ts, lo, hi), master, reps)
    t_par, r_par = _time(lambda ts: _shared(ts, lo, hi, processes=4),
                         master, reps)

    identical = _identical(r_seq, r_shared)
    top = str(r_shared["regression"][NAME][0])
    out = {
        "traces": n_traces,
        "events_per_trace": len(master[0]),
        "ops_per_diff": 3,
        "sequential_single_trace_s": round(t_seq, 4),
        "shared_plan_s": round(t_shared, 4),
        "shared_plan_parallel4_s": round(t_par, 4),
        "speedup_shared_vs_sequential": round(t_seq / t_shared, 2),
        "speedup_parallel_vs_sequential": round(t_seq / t_par, 2),
        "identical_results": bool(identical),
        "injected_regression_recovered": top == "computeRhs",
        "parallel_note": "spawn startup dominates at this trace size; "
                         "processes=N pays off for multi-M-event members",
    }
    out["acceptance_2x"] = bool(
        out["speedup_shared_vs_sequential"] >= 2.0 and identical
        and out["injected_regression_recovered"])
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(bench(), indent=1))
