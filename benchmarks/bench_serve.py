"""Trace-query service acceptance benchmark: coalescing, warm handles,
admission control.

Generates the sharded ``tracegen.big_trace`` directly as pack (10M events
by default; ``BENCH_SERVE_EVENTS`` / ``--events`` override — CI smoke
uses ~1M), launches the service (:mod:`repro.launch.trace_serve`) as a
subprocess, and drives it with concurrent stdlib clients
(:mod:`repro.serving.client`).  Three phases, each with a hard target:

* **coalesce** — K identical concurrent plans (plan cache bypassed) must
  produce **exactly one** execution: the other K-1 coalesce onto the
  in-flight future and return the same digest.
* **warm** — windowed queries against the service's pooled streaming
  handle vs the same queries through a *cold* per-request
  ``Trace.open`` of the pack.  The pooled handle (mmap + chunk-index
  pushdown, no per-request open) must be **>= 10x** faster per request,
  with identical digests.
* **starve** — interactive windowed queries while bulk full scans
  saturate the service: the interactive lane's reserved threads must
  keep p95 within **3x** of its unloaded p95.

Run standalone::

    PYTHONPATH=src python -m benchmarks.bench_serve [--events N]
        [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import sys
import tempfile
import threading
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_EVENTS = int(os.environ.get("BENCH_SERVE_EVENTS", 10_000_000))
NPROCS = 8
COALESCE_K = 8
WARM_TARGET = 10.0
STARVE_TARGET = 3.0
WINDOW_FRACTION = 0.02


def _client(port, tenant="bench"):
    from repro.serving.client import ServiceClient
    return ServiceClient("127.0.0.1", port, tenant=tenant)


def start_server(extra=()):
    """Launch the service subprocess; returns (Popen, port)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.trace_serve", "--port", "0",
         "--announce", "--max-active", "64", "--per-tenant", "32",
         *extra],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)
    line = proc.stdout.readline()
    if not line.startswith("SERVING "):
        rest = proc.stdout.read()
        raise RuntimeError(f"server failed to start: {line!r} {rest[:2000]}")
    return proc, json.loads(line.split(None, 1)[1])["port"]


def time_range(shard):
    """(ts_min, ts_max) from one shard — sets the interactive window."""
    import numpy as np
    from repro.core.trace import Trace
    ts = np.asarray(Trace.open(shard).events["Timestamp (ns)"], np.float64)
    return float(ts.min()), float(ts.max())


def phase_coalesce(port, shards):
    """K identical concurrent plans -> exactly one execution."""
    stats0 = _client(port).stats()["service"]
    barrier = threading.Barrier(COALESCE_K)
    digests, errors = [], []

    def worker():
        c = _client(port)
        try:
            barrier.wait()
            d = (c.open(shards, streaming=True).query()
                 .flat_profile(cache=False, digest_only=True))
            digests.append(d)
        except Exception as e:  # noqa: BLE001 - reported in results
            errors.append(repr(e))
        finally:
            c.close()

    threads = [threading.Thread(target=worker) for _ in range(COALESCE_K)]
    t0 = time.time()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.time() - t0
    stats1 = _client(port).stats()["service"]
    executed = stats1["executed"] - stats0["executed"]
    coalesced = stats1["coalesced"] - stats0["coalesced"]
    return {"clients": COALESCE_K, "executed": executed,
            "coalesced": coalesced, "wall_s": round(wall, 3),
            "distinct_digests": len(set(digests)), "errors": errors,
            "ok": (not errors and executed == 1
                   and coalesced == COALESCE_K - 1
                   and len(set(digests)) == 1)}


def warm_target(events: int) -> float:
    """The >=10x warm-handle bar is calibrated at the 10M-event scale,
    where a cold ``Trace.open`` pays seconds of materialization; at CI
    smoke scale (~1M) the cold open is too cheap for that ratio, so the
    gate relaxes to a sanity bound while digest equality stays strict."""
    return WARM_TARGET if events >= 5_000_000 else 1.5


def phase_warm(port, shards, window, events):
    """Pooled streaming handle vs cold per-request Trace.open."""
    from repro.core.trace import Trace
    from repro.serving.protocol import result_digest
    t0w, t1w = window

    c = _client(port)
    handle = c.open(shards, streaming=True)
    q = handle.query().slice_time(t0w, t1w, trim="within")
    t0 = time.time()
    q.time_profile(cache=False)
    first_request_s = time.time() - t0  # includes the one-time handle open
    warm_times = []
    for _ in range(10):
        t0 = time.time()
        warm_result = q.time_profile(cache=False)
        warm_times.append(time.time() - t0)
    c.close()

    cold_times = []
    for _ in range(3):
        t0 = time.time()
        cold_trace = Trace.open(shards)
        cold_result = (cold_trace.query().slice_time(t0w, t1w, trim="within")
                       .run("time_profile", cache=False))
        cold_times.append(time.time() - t0)
        del cold_trace

    warm_s = statistics.mean(warm_times)
    cold_s = statistics.mean(cold_times)
    speedup = cold_s / warm_s if warm_s > 0 else float("inf")
    digests_equal = result_digest(warm_result) == result_digest(cold_result)
    target = warm_target(events)
    return {"warm_mean_s": round(warm_s, 4),
            "cold_mean_s": round(cold_s, 4),
            "speedup": round(speedup, 1), "target": target,
            "digests_equal": digests_equal,
            "first_request_s": round(first_request_s, 4),
            "ok": digests_equal and speedup >= target}


def _interactive_latencies(port, shards, window, n):
    """n windowed interactive queries; distinct windows defeat caching."""
    t0w, t1w = window
    span = t1w - t0w
    c = _client(port)
    handle = c.open(shards, streaming=True)
    out = []
    for i in range(n):
        lo = t0w + (i % 7) * span * 0.01
        q = handle.query().slice_time(lo, lo + span, trim="within")
        t0 = time.time()
        q.run("time_profile", cache=False, lane="interactive")
        out.append(time.time() - t0)
    c.close()
    return out


def phase_starve(port, shards, window, full_range):
    """Interactive p95 alone vs under saturating bulk full scans."""
    unloaded = _interactive_latencies(port, shards, window, 20)

    stop = threading.Event()

    def bulk_worker(tag):
        c = _client(port, tenant=f"bulk{tag}")
        handle = c.open(shards, streaming=True)
        i = 0
        while not stop.is_set():
            # distinct num_bins defeats cache + coalescing: every request
            # is a genuine full scan
            try:
                handle.query().run("time_profile", cache=False,
                                   lane="bulk",
                                   num_bins=64 + (tag * 1000 + i) % 512)
            except Exception:  # noqa: BLE001 - saturation refusals are fine
                time.sleep(0.02)
            i += 1
        c.close()

    bulks = [threading.Thread(target=bulk_worker, args=(i,))
             for i in range(4)]
    for b in bulks:
        b.start()
    time.sleep(1.0)  # let the bulk lane saturate
    try:
        loaded = _interactive_latencies(port, shards, window, 20)
    finally:
        stop.set()
        for b in bulks:
            b.join()

    def p95(xs):
        return sorted(xs)[max(0, int(len(xs) * 0.95) - 1)]

    p95_un, p95_ld = p95(unloaded), p95(loaded)
    ratio = p95_ld / p95_un if p95_un > 0 else float("inf")
    return {"unloaded_p95_s": round(p95_un, 4),
            "loaded_p95_s": round(p95_ld, 4),
            "ratio": round(ratio, 2), "target": STARVE_TARGET,
            "ok": ratio <= STARVE_TARGET}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=DEFAULT_EVENTS)
    ap.add_argument("--json", default=None,
                    help="write the result document here")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.tracegen.big import big_trace

    result = {"events": args.events, "nprocs": NPROCS, "phases": {}}
    with tempfile.TemporaryDirectory(prefix="bench_serve_") as tmp:
        shard_dir = os.path.join(tmp, "pack")
        t0 = time.time()
        big_trace(shard_dir, nprocs=NPROCS,
                  events_per_proc=args.events // NPROCS, format="pack")
        result["generate_s"] = round(time.time() - t0, 1)
        shards = sorted(os.path.join(shard_dir, f)
                        for f in os.listdir(shard_dir))
        ts_min, ts_max = time_range(shards[0])
        span = (ts_max - ts_min) * WINDOW_FRACTION
        window = (ts_min, ts_min + span)

        proc, port = start_server()
        try:
            print(f"server on :{port}; {args.events:,} events in "
                  f"{len(shards)} pack shards", flush=True)
            result["phases"]["coalesce"] = phase_coalesce(port, shards)
            print("coalesce:", json.dumps(result["phases"]["coalesce"]),
                  flush=True)
            result["phases"]["warm"] = phase_warm(port, shards, window,
                                                  args.events)
            print("warm:", json.dumps(result["phases"]["warm"]), flush=True)
            result["phases"]["starve"] = phase_starve(
                port, shards, window, (ts_min, ts_max))
            print("starve:", json.dumps(result["phases"]["starve"]),
                  flush=True)
            result["stats"] = _client(port).stats()["service"]
            _client(port).shutdown(grace=10)
            proc.wait(timeout=30)
        finally:
            if proc.poll() is None:
                proc.kill()

    result["ok"] = all(p["ok"] for p in result["phases"].values())
    print(json.dumps(result, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
    if not result["ok"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
