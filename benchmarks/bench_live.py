"""Live-ingestion acceptance benchmark: incremental re-query cost and
straggler detection latency.

Two phases, each with a hard target:

* **incremental** — a live handle's re-query after the writer appends
  +25% more events must cost **< 25%** of a cold recompute over the full
  committed prefix (the incremental path folds only the new groups into
  the cached running aggregate), with digest equality against the cold
  recompute.  The 25% bar is calibrated at the multi-million-event
  scale; at CI smoke scale fixed per-query overhead (plan key, digest)
  dominates, so the gate relaxes while digest equality stays strict.
* **straggler** — over an 8-rank live fleet, one rank stops
  heartbeating: a single ``LiveTraceSet.refresh()`` sweep must classify
  it (lagging) and complete in **< 2 s** wall — detection latency is one
  poll period, not a function of fleet data volume.

Run standalone::

    PYTHONPATH=src python -m benchmarks.bench_live [--events N]
        [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

DEFAULT_EVENTS = int(os.environ.get("BENCH_LIVE_EVENTS", 4_000_000))
INCREMENTAL_TARGET = 0.25
STRAGGLER_TARGET_S = 2.0
NRANKS = 8


def incremental_target(events: int) -> float:
    return INCREMENTAL_TARGET if events >= 2_000_000 else 0.6


def _gen(n: int, proc: int, t0: int):
    """n synthetic events: properly nested Enter/Leave pairs over a small
    name pool, integer-ns timestamps starting at t0."""
    import numpy as np

    from repro.core.constants import (ENTER, ET, LEAVE, MSG_SIZE, NAME,
                                      PARTNER, PROC, TAG, TS)
    from repro.core.frame import EventFrame
    pool = np.asarray([f"fn{i}" for i in range(23)])
    names = np.repeat(pool[np.random.default_rng(proc * 7919 + t0)
                           .integers(0, len(pool), (n + 1) // 2)], 2)[:n]
    et = np.empty(n, dtype=object)
    et[0::2] = ENTER
    et[1::2] = LEAVE
    return EventFrame({
        TS: np.arange(t0, t0 + n, dtype=np.int64),
        ET: np.asarray(et, str), NAME: names,
        PROC: np.full(n, proc, np.int64),
        PARTNER: np.full(n, -1, np.int64),
        MSG_SIZE: np.full(n, np.nan),
        TAG: np.zeros(n, np.int64),
    })


def phase_incremental(workdir: str, events: int) -> dict:
    from repro.core import plancache
    from repro.core.streaming import LiveTrace
    from repro.readers.pack import PackWriter
    from repro.serving.protocol import result_digest

    plancache.clear()
    path = os.path.join(workdir, "rank_0.pack")
    grow = max(events // 4, 10_000)
    group = max(grow // 4, 2_500)

    w = PackWriter.open_append(path, chunk_rows=group, fsync=False)
    written = 0
    while written < events:
        n = min(group, events - written)
        w.append(_gen(n, 0, written))
        written += n
        w.commit()

    lt = LiveTrace([path])
    t0 = time.time()
    base = lt.query().run("flat_profile")
    cold_initial_s = time.time() - t0

    # writer appends +25%; the live handle re-queries incrementally
    w.append(_gen(grow, 0, written))
    w.commit()
    lt.refresh()
    t0 = time.time()
    inc = lt.query().run("flat_profile")
    incremental_s = time.time() - t0

    # cold recompute over the same committed prefix (no cached aggregate)
    cold_handle = LiveTrace([path], cache=False)
    t0 = time.time()
    cold = cold_handle.query().run("flat_profile", cache=False)
    cold_s = time.time() - t0

    ratio = incremental_s / cold_s if cold_s > 0 else float("inf")
    target = incremental_target(events)
    digests_equal = result_digest(inc) == result_digest(cold)
    st = plancache.stats()
    return {"events": events, "grow_events": grow,
            "rows_final": lt.watermark.rows,
            "cold_initial_s": round(cold_initial_s, 4),
            "incremental_s": round(incremental_s, 4),
            "cold_recompute_s": round(cold_s, 4),
            "ratio": round(ratio, 4), "target": target,
            "digests_equal": digests_equal,
            "live_hits": st["live_hits"], "live_misses": st["live_misses"],
            "base_digest_changed": result_digest(base) != result_digest(inc),
            "ok": (digests_equal and ratio < target
                   and st["live_hits"] >= 1)}


def phase_straggler(workdir: str, events: int) -> dict:
    from repro.core.liveset import LiveTraceSet
    from repro.runtime.tracer import Tracer

    fleet_dir = os.path.join(workdir, "fleet")
    os.makedirs(fleet_dir, exist_ok=True)
    fake = [1000.0]
    per_rank = max(events // (NRANKS * 8), 2_000)
    tracers = []
    for r in range(NRANKS):
        tr = Tracer(process=r, sink=os.path.join(fleet_dir,
                                                 f"rank_{r}.pack"),
                    flush_every=max(per_rank // 2, 500), fsync=False,
                    wall_clock=lambda: fake[0])
        for i in range(per_rank):
            tr.instant("tick", proc=r)
        tr.flush()
        tracers.append(tr)

    ls = LiveTraceSet(fleet_dir, lag_timeout=2.0, dead_timeout=60.0,
                      clock=lambda: fake[0])
    healthy = list(ls.coverage.included)

    # rank 5 stalls: everyone else heartbeats, it does not
    fake[0] += 5.0
    for r in range(NRANKS):
        if r != 5:
            tracers[r].flush()
    t0 = time.time()
    cov = ls.refresh()
    detect_s = time.time() - t0
    lagging = [r for r, i in cov.per_rank.items()
               if i["status"] == "lagging"]
    return {"ranks": NRANKS, "events_per_rank": per_rank,
            "healthy_at_start": len(healthy),
            "detect_sweep_s": round(detect_s, 4),
            "target_s": STRAGGLER_TARGET_S,
            "lagging_detected": lagging,
            "still_included": 5 in cov.included,
            "ok": (len(healthy) == NRANKS and lagging == [5]
                   and 5 in cov.included
                   and detect_s < STRAGGLER_TARGET_S)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=DEFAULT_EVENTS)
    ap.add_argument("--json", default=None,
                    help="also write the result object to this path")
    args = ap.parse_args()

    result = {"events": args.events, "phases": {}}
    with tempfile.TemporaryDirectory(prefix="bench_live_") as workdir:
        result["phases"]["incremental"] = phase_incremental(workdir,
                                                            args.events)
        print("incremental:", json.dumps(result["phases"]["incremental"]),
              flush=True)
        result["phases"]["straggler"] = phase_straggler(workdir,
                                                        args.events)
        print("straggler:", json.dumps(result["phases"]["straggler"]),
              flush=True)

    result["ok"] = all(p["ok"] for p in result["phases"].values())
    print(json.dumps(result, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
    if not result["ok"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
