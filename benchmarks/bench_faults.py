"""Fault-tolerance acceptance benchmark: salvage-open overhead and client
latency under injected connection resets.

Two phases, each with a hard target:

* **salvage** — the CRC-verifying ``on_error="salvage"`` open of a *clean*
  10M-event pack vs the default zero-scan strict open, same digest op on
  both.  Steady-state integrity checking must cost **< 10%** end to end:
  the first open pays one sequential crc32 sweep (reported as
  ``cold_overhead``), after which the verified-clean cache skips the
  sweep until the file changes on disk — the reopen pattern a serving
  handle pool actually exhibits.  A damaged-shard probe then bit-flips
  one shard and salvage-opens it to show exact quarantine accounting
  (strict stays zero-scan by design and does not notice body damage).
* **resets** — windowed queries driven through
  :class:`repro.testing.faults.FaultProxy` killing every 20th request
  (5%) with an RST mid-stream.  The client's idempotent retry must absorb
  every fault: zero request failures, faulted digests identical to the
  clean run, and p95 latency within **2.5x** of the clean p95 (deterministic
  every-20th dooming puts the retried requests right at the p95 edge).

Run standalone::

    PYTHONPATH=src python -m benchmarks.bench_faults [--events N]
        [--json PATH]

``BENCH_FAULTS_EVENTS`` overrides the default (CI smoke uses ~1M).
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_EVENTS = int(os.environ.get("BENCH_FAULTS_EVENTS", 10_000_000))
NPROCS = 8
SALVAGE_OVERHEAD_TARGET = 0.10
RESET_EVERY = 20          # 5% of requests doomed
RESET_REQUESTS = 60
RESET_P95_TARGET = 2.5
WINDOW_FRACTION = 0.02


def salvage_overhead_target(events: int) -> float:
    """The <10% bar is calibrated at the 10M-event scale where the CRC
    pass amortizes against real column I/O; at CI smoke scale fixed
    per-open costs dominate both sides, so the gate relaxes while the
    row/digest identity checks stay strict."""
    return SALVAGE_OVERHEAD_TARGET if events >= 5_000_000 else 0.50


def _digest_open(shards, on_error: str) -> tuple:
    """(digest, seconds) for one cache-miss streaming flat-profile pass."""
    from repro.core.trace import Trace
    from repro.serving.protocol import result_digest
    t0 = time.time()
    handle = Trace.open(shards, streaming=True, cache=False,
                        on_error=on_error)
    prof = handle.query().run("flat_profile", cache=False)
    return result_digest(prof), time.time() - t0


def phase_salvage(shards, events: int) -> dict:
    from repro.readers import pack as packmod
    from repro.readers.pack import read_pack
    from repro.testing.faults import bit_flip

    packmod._VERIFIED_CLEAN.clear()
    strict_s, salvage_s = [], []
    digests = set()
    for _ in range(3):
        d, dt = _digest_open(shards, "strict")
        digests.add(d)
        strict_s.append(dt)
        d, dt = _digest_open(shards, "salvage")
        digests.add(d)
        salvage_s.append(dt)
    strict = min(strict_s)
    salvage = min(salvage_s)  # reps 2+ reuse the verified-clean sweep
    overhead = salvage / strict - 1.0 if strict > 0 else 0.0
    cold_overhead = (salvage_s[0] / strict_s[0] - 1.0
                     if strict_s[0] > 0 else 0.0)
    target = salvage_overhead_target(events)

    # damaged-shard probe: flip a byte inside a known chunk group's body
    # and require exactly that group quarantined, with the loss accounted
    from repro.readers.pack import read_footer
    victim = shards[0]
    bad = victim + ".damaged"
    chunks = read_footer(victim)["chunks"]
    target_chunk = chunks[len(chunks) // 2]
    bit_flip(victim, bad,
             offsets=[target_chunk["offset"] + target_chunk["nbytes"] // 2])
    packmod.reset_io_stats()
    t = read_pack(bad, on_error="salvage")
    stats = packmod.io_stats()
    rpt = t.ingest_report()
    lost = target_chunk["hi"] - target_chunk["lo"]
    clean_rows = sum(c["hi"] - c["lo"] for c in chunks)
    probe = {"rows_survived": len(t.events), "rows_lost": lost,
             "chunks_quarantined": stats["chunks_quarantined"],
             "report_clean": rpt.clean,
             "accounted": (stats["chunks_quarantined"] == 1
                           and not rpt.clean
                           and len(t.events) == clean_rows - lost)}
    os.remove(bad)

    return {"strict_s": round(strict, 3), "salvage_s": round(salvage, 3),
            "overhead": round(overhead, 4),
            "cold_overhead": round(cold_overhead, 4), "target": target,
            "digests_equal": len(digests) == 1, "damaged_probe": probe,
            "ok": (len(digests) == 1 and overhead <= target
                   and probe["accounted"])}


def _windowed_queries(port, shards, window, n) -> tuple:
    """n distinct-window time profiles; ([latency], [digest])."""
    from repro.serving.client import ServiceClient
    from repro.serving.protocol import result_digest
    t0w, t1w = window
    span = t1w - t0w
    c = ServiceClient("127.0.0.1", port, tenant="faults",
                      retries=4, backoff=0.02)
    handle = c.open(shards, streaming=True)
    lats, digs = [], []
    for i in range(n):
        lo = t0w + (i % 7) * span * 0.01
        q = handle.query().slice_time(lo, lo + span, trim="within")
        t0 = time.time()
        res = q.run("time_profile", cache=False)
        lats.append(time.time() - t0)
        digs.append(result_digest(res))
    retries = c.retry_count
    c.close()
    return lats, digs, retries


def _p95(xs):
    return sorted(xs)[max(0, int(len(xs) * 0.95) - 1)]


def phase_resets(port, shards, window) -> dict:
    from repro.testing.faults import FaultProxy

    clean_lat, clean_dig, _ = _windowed_queries(port, shards, window,
                                                RESET_REQUESTS)
    with FaultProxy("127.0.0.1", port, reset_every=RESET_EVERY,
                    reset_after_bytes=64) as proxy:
        fault_lat, fault_dig, retries = _windowed_queries(
            proxy.port, shards, window, RESET_REQUESTS)
        stats = dict(proxy.stats)

    p95_clean, p95_fault = _p95(clean_lat), _p95(fault_lat)
    ratio = p95_fault / p95_clean if p95_clean > 0 else float("inf")
    return {"requests": RESET_REQUESTS, "reset_every": RESET_EVERY,
            "proxy": stats, "client_retries": retries,
            "clean_p95_s": round(p95_clean, 4),
            "faulted_p95_s": round(p95_fault, 4),
            "p95_ratio": round(ratio, 2), "target": RESET_P95_TARGET,
            "digests_equal": fault_dig == clean_dig,
            "ok": (fault_dig == clean_dig and stats["resets"] > 0
                   and ratio <= RESET_P95_TARGET)}


def bench(events: int = DEFAULT_EVENTS) -> dict:
    from benchmarks.bench_serve import start_server, time_range
    from repro.tracegen.big import big_trace

    out = {"events": events, "nprocs": NPROCS}
    with tempfile.TemporaryDirectory(prefix="bench_faults_") as tmp:
        shard_dir = os.path.join(tmp, "pack")
        t0 = time.time()
        big_trace(shard_dir, nprocs=NPROCS,
                  events_per_proc=max(events // NPROCS, 1000),
                  format="pack")
        out["generate_s"] = round(time.time() - t0, 1)
        shards = sorted(os.path.join(shard_dir, f)
                        for f in os.listdir(shard_dir))

        out["salvage"] = phase_salvage(shards, events)

        ts_min, ts_max = time_range(shards[0])
        window = (ts_min, ts_min + (ts_max - ts_min) * WINDOW_FRACTION)
        proc, port = start_server()
        try:
            out["resets"] = phase_resets(port, shards, window)
        finally:
            proc.kill()
            proc.wait(timeout=30)
    out["ok"] = out["salvage"]["ok"] and out["resets"]["ok"]
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--events", type=int, default=DEFAULT_EVENTS)
    ap.add_argument("--json", dest="json_path",
                    help="write the result dict to PATH as JSON")
    args = ap.parse_args(argv)
    sys.path.insert(0, os.path.join(REPO, "src"))
    res = bench(args.events)
    print(json.dumps(res, indent=2))
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(res, f, indent=2)
    return 0 if res["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
