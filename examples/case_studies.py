"""Paper §VII case studies on structure-preserving generated traces.

    PYTHONPATH=src python examples/case_studies.py --study load_imbalance
    PYTHONPATH=src python examples/case_studies.py --study all
"""

import argparse
import json
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.bench_case_studies import STUDIES  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--study", default="all", choices=list(STUDIES) + ["all"])
    args = ap.parse_args()
    names = list(STUDIES) if args.study == "all" else [args.study]
    for n in names:
        print(f"\n=== {n} ===")
        print(json.dumps(STUDIES[n](), indent=1))


if __name__ == "__main__":
    main()
