"""Analyze a compiled multi-pod program with Pipit (beyond-paper case study):
the dry-run's partitioned HLO becomes a modeled per-device timeline that
comm_matrix / comm_comp_breakdown / flat_profile dissect.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
        --shape train_4k --out experiments/dryrun --save-hlo
    PYTHONPATH=src python examples/analyze_hlo.py \
        experiments/dryrun/qwen1.5-0.5b__train_4k__pod16x16.hlo.gz
"""

import gzip
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core.trace import Trace  # noqa: E402


def main():
    if len(sys.argv) < 2:
        print(__doc__)
        return
    path = sys.argv[1]
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as f:
        hlo = f.read()
    t = Trace.from_hlo(hlo, n_procs=8)
    print(f"modeled timeline: {len(t)} events on {t.num_processes} devices\n")
    print("flat profile by op kind:")
    print(t.flat_profile().head(10))
    cm = t.comm_matrix()
    print(f"\ncomm matrix (ring traffic): dev0→dev1 = {cm[0,1]/1e9:.2f} GB")
    bd = t.comm_comp_breakdown()
    comp = float(np.asarray(bd['comp_only']).mean())
    comm = float(np.asarray(bd['comm_only']).mean())
    ov = float(np.asarray(bd['overlap']).mean())
    tot = comp + comm + ov
    print(f"\nmodeled step breakdown: compute {comp/tot:.1%}, "
          f"exposed comm {comm/tot:.1%}, overlapped {ov/tot:.1%}")
    print("(exposed comm is the hillclimb target — see EXPERIMENTS.md §Perf)")


if __name__ == "__main__":
    main()
