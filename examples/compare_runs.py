"""Comparing runs with TraceDiff (paper §IV-D: the analyses GUI tools
can't automate — cross-run diffs, regression hunting, scaling studies).

    PYTHONPATH=src python examples/compare_runs.py
"""

import sys

sys.path.insert(0, "src")

from repro import tracegen as tg  # noqa: E402
from repro.core import Filter, TraceSet  # noqa: E402

# ---------------------------------------------------------------------------
# 1. A before/after pair with a *known* injected regression: the tracegen
#    perturbation knob slows every computeRhs call by 40% in the "after"
#    run and leaves everything else bit-identical.
# ---------------------------------------------------------------------------
before, after = tg.regression_pair("tortuga", func="computeRhs",
                                   factor=1.4, nprocs=8, iters=4)
ts = TraceSet([before, after])

print("regression report (ranked by delta, worst first):")
print(ts.regression_report(top_n=6))

# ---------------------------------------------------------------------------
# 2. One lazy plan across both traces: the selection below is fused and
#    materialized once per member, then *cached* — both comparison ops
#    reuse the same prepared members.
# ---------------------------------------------------------------------------
q = ts.query().filter(Filter("Name", "not-in", ["MPI_Isend", "main()"]))
print("\nshared plan:")
print(q.explain())

print("\nname-aligned per-function deltas (absolute ns):")
print(q.diff_flat_profile().head(6))

print("\nwhere in the run the time went (per-bin delta, top column first):")
print(q.diff_time_profile(num_bins=8).head(8))

# ---------------------------------------------------------------------------
# 3. A scaling study is just a TraceSet of runs at different nprocs.
#    tortuga stops scaling past its knee — exactly the paper's Fig. 12
#    finding, recovered programmatically.
# ---------------------------------------------------------------------------
runs = [tg.tortuga(nprocs=n, iters=3) for n in (8, 16, 32, 64)]
print("\nstrong-scaling series (efficiency collapses past the knee):")
scal = TraceSet(runs).scaling_analysis(mode="strong")
print(scal[["Run", "num_processes", "duration", "speedup", "efficiency"]])

# ---------------------------------------------------------------------------
# 4. Which functions got *more imbalanced* between two runs.  (A uniform
#    slowdown keeps max/mean constant — skew needs per-process asymmetry,
#    here gol's extra work on process 0.)
# ---------------------------------------------------------------------------
balanced = tg.gol(nprocs=8, iters=4, imbalance=0.05)
skewed = tg.gol(nprocs=8, iters=4, imbalance=0.8)
balanced.label, skewed.label = "gol-balanced", "gol-skewed"
print("\nload-imbalance delta (skew got worse at the top):")
print(TraceSet([balanced, skewed]).diff_load_imbalance().head(4))
