"""End-to-end driver: train the paper-native ~100M LM while the framework's
tracer records the run, then analyze the training trace *with Pipit itself* —
the paper's loop closed on our own system.

    PYTHONPATH=src python examples/train_traced.py --steps 200
    (CPU container: ~100M params — use --smoke for a 1-minute demo)
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.configs import get_config, get_smoke_config  # noqa: E402
from repro.data import SyntheticLMStream  # noqa: E402
from repro.runtime import FaultInjector, Tracer, Trainer, TrainLoopConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--inject-fault", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = get_smoke_config("pipit-lm-100m") if args.smoke \
        else get_config("pipit-lm-100m")
    print(f"training {cfg.name}: ~{cfg.param_count()/1e6:.0f}M params, "
          f"{args.steps} steps, batch {args.batch}×{args.seq}")

    tracer = Tracer()
    loop = TrainLoopConfig(steps=args.steps, peak_lr=3e-3,
                           warmup_steps=max(args.steps // 10, 1),
                           ckpt_every=max(args.steps // 4, 1),
                           ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, loop, tracer=tracer)
    stream = SyntheticLMStream(cfg.vocab, args.batch, args.seq, seed=1)
    fault = FaultInjector([args.steps // 2]) if args.inject_fault else None
    out = trainer.run(stream, fault=fault)
    stream.close()

    losses = out["losses"]
    print(f"\nloss: {np.mean(losses[:5]):.4f} → {np.mean(losses[-5:]):.4f} "
          f"({out['steps']} steps, {out['restarts']} restarts, "
          f"{out['mean_step_time']:.3f}s/step)")

    # --- the paper's technique, applied to our own run ------------------
    t = tracer.to_trace("train_run")
    print("\nPipit flat profile of the training run:")
    print(t.flat_profile().head(8))
    print("\nPipit time profile (8 bins):")
    print(t.time_profile(num_bins=8).head(8))


if __name__ == "__main__":
    main()
