"""Quickstart (paper Fig. 1 + §IV-E): open a trace, chain a lazy query,
and extend the analysis API through the op registry.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, "src")

from repro.core import Filter, Trace, register_op, list_ops  # noqa: E402
from repro.core.constants import ENTER, ET, EXC, NAME  # noqa: E402

FIG1 = """Timestamp (s), Event Type, Name, Process
0, Enter, main(), 0
1, Enter, foo(), 0
3, Enter, MPI_Send, 0
5, Leave, MPI_Send, 0
8, Enter, baz(), 0
18, Leave, baz(), 0
25, Leave, foo(), 0
100, Leave, main(), 0
0, Enter, main(), 1
1, Enter, foo(), 1
3, Enter, MPI_Recv, 1
6, Leave, MPI_Recv, 1
8, Enter, baz(), 1
18, Leave, baz(), 1
25, Leave, foo(), 1
95, Leave, main(), 1
"""

# ---------------------------------------------------------------------------
# 1. Open a trace.  Trace.open sniffs the format (CSV / JSONL / Chrome /
#    OTF2-structured JSON / HLO text) via the reader registry — no need to
#    know which from_* constructor matches the file.
# ---------------------------------------------------------------------------
with tempfile.NamedTemporaryFile("w", suffix=".data", delete=False) as f:
    f.write(FIG1)
    path = f.name

foo_bar = Trace.open(path)          # format="auto" sniffs the CSV header
os.unlink(path)

print("events frame (paper Fig. 1):")
print(foo_bar.events[["Timestamp (ns)", "Event Type", "Name", "Process"]])

# ---------------------------------------------------------------------------
# 2. Eager one-liners still work — every Trace method is a one-step plan.
# ---------------------------------------------------------------------------
print("\nflat profile (paper §IV-B):")
print(foo_bar.flat_profile())

print("\ntime profile, 4 bins:")
print(foo_bar.time_profile(num_bins=4))

# ---------------------------------------------------------------------------
# 3. Chained lazy queries (paper §IV-E).  Nothing executes until a terminal
#    op: the three selections below fuse into ONE mask application, derived
#    structure is computed once and remapped through the selection, and
#    flat_profile's prerequisites are materialized exactly once.
# ---------------------------------------------------------------------------
query = (foo_bar.query()
         .slice_time(0, 30e9)                       # call-interval window
         .filter(Filter(NAME, "not-in", ["MPI_Send", "MPI_Recv"]))
         .restrict_processes([0, 1]))
print("\nquery plan (nothing has run yet):")
print(query.explain())

print("\nfused-plan flat profile:")
print(query.flat_profile())

# ---------------------------------------------------------------------------
# 4. Extending the API (the paper's §VII extensibility claim): register a
#    custom analysis with its prerequisites; it becomes a terminal op on
#    every query — and the engine materializes the prerequisites for you.
# ---------------------------------------------------------------------------


@register_op("busiest_function", needs_structure=True)
def busiest_function(trace, metric=EXC):
    """Name of the function with the largest total exclusive time."""
    ev = trace.events
    ent = ev.mask(ev.cat(ET).mask_eq(ENTER))
    prof = ent.groupby_agg(NAME, {metric: "sum"})
    vals = np.nan_to_num(np.asarray(prof[metric], np.float64))
    return str(prof[NAME][int(np.argmax(vals))])


print("\ncustom registered op, chained like a built-in:")
print("  busiest overall:", foo_bar.query().busiest_function())
print("  busiest under 30s, no MPI:",
      query.busiest_function())

print("\nregistered analysis ops:")
print(" ", ", ".join(list_ops()))

print("\ncalling context tree:")
for node in foo_bar.cct.nodes[1:]:
    print("  " * node.depth + node.name)

print("\nidle time per process:")
print(foo_bar.idle_time())
