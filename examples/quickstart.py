"""Quickstart (paper Fig. 1): read a CSV trace, inspect the events frame,
and run the first analysis ops.

    PYTHONPATH=src python examples/quickstart.py
"""

import io
import sys

sys.path.insert(0, "src")

from repro.core.trace import Trace  # noqa: E402

FIG1 = """Timestamp (s), Event Type, Name, Process
0, Enter, main(), 0
1, Enter, foo(), 0
3, Enter, MPI_Send, 0
5, Leave, MPI_Send, 0
8, Enter, baz(), 0
18, Leave, baz(), 0
25, Leave, foo(), 0
100, Leave, main(), 0
0, Enter, main(), 1
1, Enter, foo(), 1
3, Enter, MPI_Recv, 1
6, Leave, MPI_Recv, 1
8, Enter, baz(), 1
18, Leave, baz(), 1
25, Leave, foo(), 1
95, Leave, main(), 1
"""

foo_bar = Trace.from_csv(io.StringIO(FIG1))
print("events frame (paper Fig. 1):")
print(foo_bar.events[["Timestamp (ns)", "Event Type", "Name", "Process"]])

print("\nflat profile (paper §IV-B):")
print(foo_bar.flat_profile())

print("\ntime profile, 4 bins:")
print(foo_bar.time_profile(num_bins=4))

print("\ncalling context tree:")
for node in foo_bar.cct.nodes[1:]:
    print("  " * node.depth + node.name)

print("\nidle time per process:")
print(foo_bar.idle_time())
