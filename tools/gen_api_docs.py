#!/usr/bin/env python
"""Generate docs/api.md from the live op/reader registries.

The API page is *derived*, never hand-edited: every section is rendered
from what is actually registered in :mod:`repro.core.registry` (op name,
signature, declared prerequisites, scope, docstring; reader name,
extensions, sniffer, shard hint).  That makes drift impossible to hide —
``--check`` re-renders and compares against the committed file, and the
test suite runs it (tests/test_docs.py), so adding or changing a registered
op without regenerating the docs fails the verify flow.

Usage::

    PYTHONPATH=src python tools/gen_api_docs.py           # rewrite docs/api.md
    PYTHONPATH=src python tools/gen_api_docs.py --check   # exit 1 on drift
"""

from __future__ import annotations

import argparse
import inspect
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

HEADER = """\
# API reference — registered analysis ops and trace readers

<!-- GENERATED FILE — do not edit by hand.
     Regenerate with: PYTHONPATH=src python tools/gen_api_docs.py -->

This page is rendered directly from the live registries in
`repro.core.registry`, so it always matches the code: every op listed here
is callable as a terminal method on a lazy query (`trace.query().<op>()`
for single-trace ops, `TraceSet(...).query().<op>()` / `TraceSet.<op>()`
for set-scoped comparison ops), and every reader is resolvable through
`Trace.open(path, format="auto")`.

Ops marked *streaming: combinable* also run **out of core** — over a
`Trace.open(path, streaming=True)` handle they execute chunk by chunk with
mergeable partial aggregates and never materialize the trace (see
`docs/streaming.md`).  Ops additionally marked *(parallel)* declare a
cross-worker merge and fan out over a multi-core work-unit pool under
`Trace.open(..., streaming=True, processes=N)` / `executor="parallel"`.
Ops marked *streaming: —* need the whole trace and raise
`StreamingUnsupported` with the escape hatches spelled out.

Ops carrying a *backends* annotation accept a `backend=` keyword selecting
a registered compute backend for the op's core reduction — `numpy` is the
exact reference, `pallas` runs the reduction as a TPU Pallas kernel
(interpret mode on CPU) with results reproducible to f32 rounding and
digest-identical across the eager/streaming/parallel paths (see
`docs/kernels.md`).  Additional backends register with
`repro.core.register_backend(op, name)`; the same keyword works over the
trace-query service wire protocol.

Terminal-op results are memoized in the plan-result cache
(`repro.core.plancache`): streaming/scan executions cache by on-disk
content identity by default (`cache=False` opts out per call or per
handle), and in-memory traces opt in per call with `cache=True`
(content-hashed, so mutation always misses).  The cache is thread-safe,
reports `stats()` (hits/misses/evictions, per-tenant usage), and supports
per-tenant entry quotas (`configure(tenant_quota=N)`) — the trace-query
service (`docs/serving.md`) shares it across every client session and
every registered op here is callable remotely through that service.

Ops carrying a *detector* annotation are part of the automated
diagnostics suite (`docs/diagnostics.md`): each returns a ranked Findings
frame and participates in the combined `diagnose` terminal; the annotation
shows the detector's category and default severity threshold.

Register your own the same way the built-ins do:

```python
from repro.core import register_op

@register_op("my_analysis", needs_structure=True)
def my_analysis(trace, **kwargs):
    ...
```
"""


def _sig(fn) -> str:
    try:
        return str(inspect.signature(fn))
    except (TypeError, ValueError):  # pragma: no cover - C callables etc.
        return "(...)"


def _doc(fn) -> str:
    doc = inspect.getdoc(fn)
    return doc.rstrip() if doc else "*(no docstring)*"


def render() -> str:
    # importing trace/readers populates both registries (op modules + diff
    # are load-bearing imports of repro.core.trace)
    import repro.readers  # noqa: F401
    from repro.core import trace as _trace  # noqa: F401
    from repro.core import detectors as _detectors
    from repro.core import registry

    lines = [HEADER]

    for scope, title, blurb in (
        ("trace", "Single-trace analysis ops",
         "Terminal methods on `Trace` / `TraceQuery` (paper §IV). "
         "`needs structure` ops get enter/leave matching, parents and "
         "inclusive/exclusive metrics materialized first; `needs messages` "
         "ops get send/recv matching."),
        ("set", "Multi-trace comparison ops (TraceDiff)",
         "Terminal methods on `TraceSet` / `SetQuery` "
         "(`repro.core.diff`): the first argument is the *sequence* of "
         "member traces, prepared by one shared query plan."),
    ):
        lines.append(f"\n## {title}\n\n{blurb}\n")
        for name in registry.list_ops():
            spec = registry.get_op(name)
            if spec.scope != scope:
                continue
            prereqs = [p for p, on in (("structure", spec.needs_structure),
                                       ("messages", spec.needs_messages)) if on]
            if spec.streaming is None:
                streaming = "—"
            elif spec.parallel_safe:
                streaming = "combinable (parallel)"
            else:
                streaming = "combinable"
            lines.append(f"### `{name}`\n")
            lines.append(f"```python\n{name}{_sig(spec.fn)}\n```\n")
            det = _detectors.get_detector(name)
            detector = (f" · detector: {det.category} "
                        f"(threshold {det.threshold:g})" if det else "")
            bk = spec.backends
            backends = (" · backends: " + ", ".join(f"`{b}`" for b in bk)
                        if bk else "")
            lines.append(f"*needs: {', '.join(prereqs) if prereqs else 'nothing'}"
                         f" · scope: {spec.scope}"
                         f" · streaming: {streaming}{backends}{detector}*\n")
            lines.append(_doc(spec.fn) + "\n")

    lines.append("\n## Registered trace readers\n\n"
                 "Formats `Trace.open(path, format=\"auto\")` resolves; "
                 "content sniffers take precedence over file extensions, and "
                 "a `shard hint` lets the parallel driver skip per-rank "
                 "shards a process-restricted plan cannot need.\n")
    for name in registry.list_readers():
        spec = registry.get_reader(name)
        ext = ", ".join(f"`{e}`" for e in spec.extensions) or "*(none)*"
        sniffer = f"`{spec.sniff.__name__}`" if spec.sniff else "*(extension only)*"
        shard = f"`{spec.shard_procs.__name__}`" if spec.shard_procs else "—"
        units = (f"`{spec.plan_units.__name__}`" if spec.plan_units
                 else "—")
        lines.append(f"### `{name}`\n")
        lines.append(f"```python\n{name}.read{_sig(spec.read)}\n```\n")
        lines.append(f"*extensions: {ext} · sniffer: {sniffer} · "
                     f"shard hint: {shard} · unit planner: {units}*\n")
        lines.append(_doc(spec.read) + "\n")

    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if docs/api.md is out of date instead of "
                         "rewriting it")
    ap.add_argument("--out", default=os.path.join(REPO, "docs", "api.md"))
    args = ap.parse_args(argv)

    text = render()
    if args.check:
        try:
            with open(args.out) as f:
                on_disk = f.read()
        except OSError:
            on_disk = None
        if on_disk != text:
            print(f"{args.out} is out of date with the registry; "
                  f"regenerate with: PYTHONPATH=src python tools/gen_api_docs.py",
                  file=sys.stderr)
            return 1
        print(f"{args.out} is in sync with the registry")
        return 0
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
