#!/usr/bin/env python
"""Convert traces of any registered format to pipitpack (convert once,
analyze fast).

Each input (file, OTF2-style archive directory, or ``rank_*`` shard) is
converted independently to ``<stem>.pack`` — per-shard packs keep the
per-location stream layout the parallel driver exploits.  Conversion
streams chunk by chunk (bounded memory); ``--sidecar`` (default on)
additionally stores the precomputed structure sidecar so reopening skips
``derive_structure`` entirely.

Usage::

    PYTHONPATH=src python tools/pack.py TRACE [TRACE ...]
        [-o OUT]            # output file (single input) or directory
        [--format auto]     # source format (default: sniff)
        [--chunk-rows N]    # footer index granularity (default 250k)
        [--no-sidecar]      # skip the structure sidecar
        [--verify]          # reopen and compare a flat profile digest

Maintenance modes (inputs that are already packs)::

    PYTHONPATH=src python tools/pack.py --verify run.pack
        # integrity report: per-chunk CRC verdicts + sidecar checksum
    PYTHONPATH=src python tools/pack.py --repair bad.pack [-o fixed.pack]
        # salvage-open (footer loss and CRC-failing chunk groups are
        # tolerated) and rewrite a fresh, fully-checksummed pack
    PYTHONPATH=src python tools/pack.py --watermark rank_0.pack
        # committed-prefix watermark of a live (append-mode) shard:
        # rows/groups/bytes committed, ts range, finalized flag, and the
        # heartbeat record if the writing rank left one

``--verify`` on packs exits non-zero if any pack fails its CRCs;
``--repair`` exits non-zero only when a pack yields no rows at all.
"""

from __future__ import annotations

import argparse
import hashlib
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def _out_path(inp: str, out: str | None, many: bool) -> str:
    stem = os.path.basename(inp.rstrip(os.sep))
    for ext in (".jsonl", ".json", ".csv", ".otf2"):
        if stem.lower().endswith(ext):
            stem = stem[: -len(ext)]
            break
    if out is None:
        return os.path.join(os.path.dirname(inp) or ".", stem + ".pack")
    if many or os.path.isdir(out):
        os.makedirs(out, exist_ok=True)
        return os.path.join(out, stem + ".pack")
    return out


def _digest(handle) -> str:
    import numpy as np
    prof = handle.flat_profile()
    h = hashlib.sha256()
    h.update("\x00".join(map(str, prof["Name"])).encode())
    h.update(np.ascontiguousarray(
        np.asarray(prof["time.exc"], np.float64)).tobytes())
    return h.hexdigest()


def _digest_source(inp: str, fmt: str) -> str:
    """Digest of the source with pack storage quantization applied: packs
    store integer-ns timestamps (truncation, the repo-wide text-writer
    convention), so float-ns sources — e.g. HLO modeled timelines — must
    be compared post-quantization or the digest would mismatch by design."""
    import numpy as np
    from repro.core.constants import TS
    from repro.core.trace import Trace
    t = Trace.open(inp, format=fmt, streaming=True,
                   cache=False).materialize()
    ev = t.events
    ev[TS] = np.asarray(ev[TS], np.int64)
    return _digest(Trace(ev))


def _is_pack(path: str) -> bool:
    try:
        with open(path, "rb") as f:
            return f.read(11) == b"#pipitpack "
    except OSError:
        return False


def _verify_mode(inputs: list) -> int:
    """Integrity-report mode: every input is already a pack."""
    from repro.readers.pack import verify_pack
    failures = 0
    for inp in inputs:
        try:
            rep = verify_pack(inp)
        except (OSError, ValueError) as e:
            print(f"{inp}: UNREADABLE ({e}) — try --repair")
            failures += 1
            continue
        bad = rep["chunks_bad"]
        side = {None: "n/a", True: "ok", False: "CORRUPT"}[rep["sidecar_ok"]]
        verdict = "OK" if rep["ok"] else "DAMAGED"
        print(f"{inp}: {verdict}  v{rep['version']}, {rep['rows']} rows, "
              f"{rep['chunks_total']} chunk group(s), {len(bad)} bad, "
              f"sidecar {side}")
        for b in bad:
            print(f"  bad group #{b['index']}: rows "
                  f"[{b['rows'][0]}, {b['rows'][1]}) at byte {b['offset']}")
        if rep.get("note"):
            print(f"  note: {rep['note']}")
        failures += 0 if rep["ok"] else 1
    return 1 if failures else 0


def _repair_mode(inputs: list, out: str | None) -> int:
    from repro.readers.pack import repair_pack
    many = len(inputs) > 1
    failures = 0
    for inp in inputs:
        if out is None:
            dst = (inp[:-5] if inp.endswith(".pack") else inp) \
                + ".repaired.pack"
        elif many or os.path.isdir(out):
            os.makedirs(out, exist_ok=True)
            dst = os.path.join(out, os.path.basename(inp))
        else:
            dst = out
        rep = repair_pack(inp, dst)
        print(f"{inp} -> {dst}  ({rep['rows_recovered']} rows recovered, "
              f"{rep['chunks_quarantined']} chunk group(s) quarantined"
              f"{', footer rebuilt' if rep['footer_rebuilt'] else ''})")
        if rep["rows_recovered"] == 0:
            print("  NOTHING SALVAGEABLE")
            failures += 1
    return 1 if failures else 0


def _watermark_mode(inputs: list) -> int:
    """Committed-prefix report for live append-mode shards (works on
    finalized packs too — there the watermark is just the whole file)."""
    import json

    from repro.readers.pack import committed_prefix
    from repro.runtime.tracer import read_heartbeat
    failures = 0
    for inp in inputs:
        try:
            snap = committed_prefix(inp)
        except (OSError, ValueError) as e:
            print(f"{inp}: UNREADABLE ({e})")
            failures += 1
            continue
        out = dict(snap["watermark"], path=inp)
        hb = read_heartbeat(inp)
        if hb is not None:
            age = time.time() - hb["wall"] if hb.get("wall") else None
            out["heartbeat"] = dict(
                hb, age_s=round(age, 3) if age is not None else None)
        print(json.dumps(out))
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("inputs", nargs="+", help="trace files / archives")
    ap.add_argument("-o", "--out", help="output .pack file (single input) "
                    "or directory (several)")
    ap.add_argument("--format", default="auto",
                    help="source format (default: sniff per input)")
    ap.add_argument("--chunk-rows", type=int, default=None,
                    help="rows per footer-index chunk (default 250000)")
    ap.add_argument("--no-sidecar", action="store_true",
                    help="do not store the structure sidecar")
    ap.add_argument("--verify", action="store_true",
                    help="converting: reopen each pack and check the "
                    "flat-profile digest against the source; on inputs "
                    "that are already packs: full CRC integrity report")
    ap.add_argument("--repair", action="store_true",
                    help="salvage a damaged pack and rewrite it as a "
                    "fresh, fully-checksummed pack (default output: "
                    "<stem>.repaired.pack)")
    ap.add_argument("--watermark", action="store_true",
                    help="print each shard's committed-prefix watermark "
                    "(+ heartbeat, if any) as one JSON line — for "
                    "inspecting live append-mode shards")
    args = ap.parse_args(argv)

    if args.watermark:
        return _watermark_mode(args.inputs)
    if args.repair:
        return _repair_mode(args.inputs, args.out)
    if args.verify and all(_is_pack(i) for i in args.inputs):
        return _verify_mode(args.inputs)

    from repro.core.trace import Trace

    many = len(args.inputs) > 1
    failures = 0
    for inp in args.inputs:
        dst = _out_path(inp, args.out, many)
        t0 = time.time()
        src = Trace.open(inp, format=args.format, streaming=True,
                         cache=False)
        src.save_pack(dst, chunk_rows=args.chunk_rows,
                      sidecar=not args.no_sidecar)
        dt = time.time() - t0
        src_b = sum(
            os.path.getsize(os.path.join(r, f))
            for r, _d, fs in os.walk(inp) for f in fs
        ) if os.path.isdir(inp) else os.path.getsize(inp)
        print(f"{inp} -> {dst}  ({src_b / 1e6:.1f} MB -> "
              f"{os.path.getsize(dst) / 1e6:.1f} MB, {dt:.1f}s)")
        if args.verify:
            a = _digest_source(inp, args.format)
            b = _digest(Trace.open(dst, streaming=True, cache=False))
            ok = a == b
            print(f"  verify: {'OK' if ok else 'DIGEST MISMATCH'}")
            failures += 0 if ok else 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
