#!/usr/bin/env python
"""CI crash-consistency smoke: SIGKILL a pack writer mid-write, repair,
serve, and require the served digest to match a direct library read.

Closed loop, all gates hard:

1. spawn a ``tracegen.big_trace`` pack write (non-atomic ``PackWriter``)
   and SIGKILL it once the destination has real chunk groups on disk;
2. ``tools/pack.py --repair`` must salvage the torn pack (non-empty,
   verify-clean output);
3. the recovered rows must be a bit-exact prefix of the same generator's
   full output (nothing invented, nothing reordered);
4. a trace-query service over the repaired pack must return a
   ``flat_profile`` digest identical to a direct ``Trace.open`` — served
   recovery equals library recovery.

It also runs a **live-ingest smoke** (``--skip-live`` to omit): an
8-rank live writer fleet (``Tracer`` with append-mode sinks +
heartbeats) is polled twice through :class:`LiveTraceSet` asserting
per-rank watermark monotonicity, two ranks are SIGKILLed mid-commit, and
after ``dead_timeout`` the degraded query must cover exactly the six
survivors (dead ranks named in the coverage report) with eager ==
streaming == parallel digests over the committed prefix.

It also emits a **fault matrix** artifact (``--matrix-json``): every
registered text/pack reader x {truncate 25/75/99%, bit-flip, garbage
tail} x {strict, lenient} with the observed outcome, so CI archives a
machine-readable robustness census per commit.

Usage::

    PYTHONPATH=src python tools/crash_smoke.py [--events N]
        [--matrix-json fault_matrix.json] [--skip-live]
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

WRITER = """
import sys
sys.path.insert(0, {src!r})
from repro.tracegen.big import big_trace
print("ready", flush=True)
big_trace({out!r}, nprocs=1, events_per_proc={events}, format="pack")
print("done", flush=True)
"""


def crash_consistency(events: int) -> dict:
    from repro.core.trace import Trace
    from repro.readers.pack import verify_pack

    out = {}
    with tempfile.TemporaryDirectory(prefix="crash_smoke_") as tmp:
        shard_dir = os.path.join(tmp, "torn")
        victim = os.path.join(shard_dir, "rank_0.pack")
        proc = subprocess.Popen(
            [sys.executable, "-c",
             WRITER.format(src=os.path.join(REPO, "src"), out=shard_dir,
                           events=events)],
            stdout=subprocess.PIPE, text=True)
        assert proc.stdout.readline().strip() == "ready"
        # wait for at least one finalized chunk group (250k rows x ~33
        # bytes/row ~= 8 MB), then kill mid-write of a later group
        deadline = time.time() + 120
        while time.time() < deadline:
            if (os.path.exists(victim)
                    and os.path.getsize(victim) > 9_000_000):
                break
            time.sleep(0.002)
        else:
            raise RuntimeError("writer never produced bytes to tear")
        proc.send_signal(signal.SIGKILL)
        proc.wait()
        out["torn_bytes"] = os.path.getsize(victim)

        repaired = os.path.join(tmp, "repaired.pack")
        r = subprocess.run(
            [sys.executable, os.path.join(REPO, "tools", "pack.py"),
             "--repair", victim, "-o", repaired],
            capture_output=True, text=True)
        out["repair_rc"] = r.returncode
        out["repair_log"] = r.stdout.strip()
        if r.returncode != 0:
            raise SystemExit(f"repair failed:\n{r.stdout}{r.stderr}")

        rep = verify_pack(repaired)
        out["repaired_rows"] = rep["rows"]
        if not (rep["ok"] and rep["rows"] > 0):
            raise SystemExit(f"repaired pack not verify-clean: {rep}")

        # recovered rows must be a bit-exact prefix of the full generation
        import numpy as np
        from repro.core.constants import TS
        full_dir = os.path.join(tmp, "full")
        from repro.tracegen.big import big_trace
        big_trace(full_dir, nprocs=1, events_per_proc=events, format="pack")
        got = np.asarray(Trace.open(repaired).events[TS], np.int64)
        want = np.asarray(
            Trace.open(os.path.join(full_dir, "rank_0.pack")).events[TS],
            np.int64)[:len(got)]
        if not np.array_equal(got, want):
            raise SystemExit("recovered rows are not a prefix of the "
                             "generator's output")
        out["prefix_exact"] = True

        # served digest == library digest over the repaired pack
        sys.path.insert(0, REPO)
        from benchmarks.bench_serve import start_server
        from repro.serving.client import ServiceClient
        from repro.serving.protocol import result_digest
        lib_digest = result_digest(
            Trace.open(repaired).query().run("flat_profile", cache=False))
        srv, port = start_server()
        try:
            c = ServiceClient("127.0.0.1", port, tenant="smoke")
            served = c.open(repaired).query().run("flat_profile",
                                                  cache=False)
            out["served_digest_equal"] = \
                result_digest(served) == lib_digest
            c.close()
        finally:
            srv.kill()
            srv.wait(timeout=30)
        if not out["served_digest_equal"]:
            raise SystemExit("served digest != library digest")
    return out


LIVE_WRITER = """
import sys, time
sys.path.insert(0, {src!r})
from repro.runtime.tracer import Tracer
tr = Tracer(process={rank}, sink={sink!r}, flush_every=2000,
            heartbeat_interval=0.2, fsync=False)
print("ready", flush=True)
i = 0
while True:
    with tr.span("fn%d" % (i % 11), proc={rank}):
        tr.instant("tick", proc={rank})
    i += 1
    if i % 2000 == 0:
        time.sleep(0.01)   # pace the loop so the fleet outlives the polls
"""

NRANKS = 8
KILL_RANKS = (2, 5)


def live_ingest() -> dict:
    """8-rank live fleet: watermark monotonicity under growth, SIGKILL
    two ranks, survivor-only degraded queries with digest agreement."""
    from repro.core.liveset import LiveTraceSet
    from repro.core.streaming import LiveTrace
    from repro.readers.pack import committed_prefix
    from repro.serving.protocol import result_digest

    out = {}
    with tempfile.TemporaryDirectory(prefix="live_smoke_") as tmp:
        sinks = [os.path.join(tmp, f"rank_{r}.pack")
                 for r in range(NRANKS)]
        procs = [subprocess.Popen(
            [sys.executable, "-c",
             LIVE_WRITER.format(src=os.path.join(REPO, "src"),
                                rank=r, sink=sinks[r])],
            stdout=subprocess.PIPE, text=True) for r in range(NRANKS)]
        try:
            for p in procs:
                assert p.stdout.readline().strip() == "ready"
            deadline = time.time() + 120
            while time.time() < deadline:
                if all(committed_prefix(s)["rows"] > 0 for s in sinks):
                    break
                time.sleep(0.01)
            else:
                raise RuntimeError("fleet never committed rows")

            ls = LiveTraceSet(tmp, lag_timeout=1.5, dead_timeout=4.0)
            cov = ls.coverage
            if cov.included != list(range(NRANKS)):
                raise SystemExit(f"fleet not fully live: {cov.as_dict()}")
            wm1 = {r: cov.per_rank[r]["rows"] for r in cov.included}

            time.sleep(0.6)
            cov = ls.refresh()
            wm2 = {r: cov.per_rank[r]["rows"] for r in cov.included}
            if any(wm2[r] < wm1[r] for r in wm1):
                raise SystemExit(f"watermark went backwards: {wm1} {wm2}")
            if sum(wm2.values()) <= sum(wm1.values()):
                raise SystemExit("fleet-wide watermark did not advance "
                                 f"between polls: {wm1} {wm2}")
            out["watermarks_monotone"] = True
            out["rows_poll1"] = sum(wm1.values())
            out["rows_poll2"] = sum(wm2.values())

            for r in KILL_RANKS:
                procs[r].send_signal(signal.SIGKILL)
                procs[r].wait()
            time.sleep(4.5)   # past dead_timeout; survivors keep writing

            cov = ls.refresh()
            survivors = [r for r in range(NRANKS) if r not in KILL_RANKS]
            if cov.included != survivors or cov.missing != list(KILL_RANKS):
                raise SystemExit(
                    f"wrong degraded coverage: {cov.as_dict()}")
            out["missing_ranks"] = cov.missing
            out["survivor_rows"] = ls.watermark.rows
            out["staleness_spread"] = cov.staleness_spread
            # dead ranks' committed prefixes still reported, durable
            if any(cov.per_rank[r]["rows"] <= 0 for r in KILL_RANKS):
                raise SystemExit("dead ranks lost their committed prefix")
        finally:
            for p in procs:
                if p.poll() is None:
                    p.kill()
            for p in procs:
                p.wait()

        # fleet fully stopped: the committed prefixes are frozen, so
        # eager == streaming == parallel must agree digest-for-digest
        spaths = [sinks[r] for r in range(NRANKS) if r not in KILL_RANKS]
        serial = LiveTrace(spaths, cache=False)
        d_stream = result_digest(
            serial.query().run("flat_profile", cache=False))
        d_eager = result_digest(
            serial.materialize().query().run("flat_profile", cache=False))
        d_par = result_digest(
            LiveTrace(spaths, processes=2, executor="parallel",
                      cache=False).query().run("flat_profile", cache=False))
        out["digests_agree"] = (d_stream == d_eager == d_par)
        if not out["digests_agree"]:
            raise SystemExit(
                f"digest disagreement on committed prefix: "
                f"stream={d_stream} eager={d_eager} par={d_par}")
    return out


def fault_matrix() -> list:
    """Outcome census: reader x corruption x policy on small goldens."""
    from repro import tracegen
    from repro.core.errors import TraceReadError
    from repro.core.trace import Trace
    from repro.readers.chrome import write_chrome
    from repro.readers.csvreader import write_csv
    from repro.readers.jsonl import write_jsonl
    from repro.readers.otf2j import write_otf2_json
    from repro.readers.pack import write_pack
    from repro.testing.faults import bit_flip, garbage_append, truncate_at

    golden = tracegen.gol(nprocs=3, iters=4, seed=7)
    writers = {"jsonl": ("g.jsonl", write_jsonl),
               "csv": ("g.csv", write_csv),
               "chrome": ("g.json", write_chrome),
               "otf2j": ("g.otf2.json", write_otf2_json),
               "pack": ("g.pack",
                        lambda t, p: write_pack(t, p, chunk_rows=20))}
    hurts = {"trunc25": lambda s, d: truncate_at(s, d, frac=0.25),
             "trunc75": lambda s, d: truncate_at(s, d, frac=0.75),
             "trunc99": lambda s, d: truncate_at(s, d, frac=0.99),
             "bitflip": lambda s, d: bit_flip(s, d, frac=0.5, count=4,
                                              seed=13),
             "garbage": lambda s, d: garbage_append(s, d, nbytes=97,
                                                    seed=13)}
    rows = []
    with tempfile.TemporaryDirectory(prefix="fault_matrix_") as tmp:
        for fmt, (name, writer) in writers.items():
            src = os.path.join(tmp, name)
            writer(golden, src)
            lenient = "salvage" if fmt == "pack" else "skip"
            for hurt, injure in hurts.items():
                dst = os.path.join(tmp, f"{hurt}-{name}")
                injure(src, dst)
                for policy in ("strict", lenient):
                    row = {"format": fmt, "corruption": hurt,
                           "policy": policy}
                    try:
                        t = Trace.open(dst, format=fmt, on_error=policy)
                        rpt = t.ingest_report()
                        row.update(outcome="opened",
                                   rows=len(t.events),
                                   clean=rpt.clean,
                                   skipped=rpt.total_skipped())
                    except (TraceReadError, ValueError) as e:
                        row.update(outcome="raised",
                                   error=str(e)[:200],
                                   names_file=os.path.basename(dst)
                                   in str(e))
                    rows.append(row)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--events", type=int, default=2_000_000,
                    help="events in the torn shard's generator")
    ap.add_argument("--matrix-json",
                    help="write the reader x corruption x policy outcome "
                    "matrix to PATH")
    ap.add_argument("--skip-live", action="store_true",
                    help="skip the live-ingest rank-failure smoke")
    args = ap.parse_args(argv)

    result = {"crash_consistency": crash_consistency(args.events)}
    if not args.skip_live:
        result["live_ingest"] = live_ingest()
    print(json.dumps(result, indent=2))

    if args.matrix_json:
        rows = fault_matrix()
        with open(args.matrix_json, "w") as f:
            json.dump(rows, f, indent=1)
        raised_unnamed = [r for r in rows if r["outcome"] == "raised"
                          and not r["names_file"]]
        print(f"fault matrix: {len(rows)} cells -> {args.matrix_json}")
        if raised_unnamed:
            print("FAIL: errors not naming the damaged file:",
                  json.dumps(raised_unnamed, indent=1))
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
