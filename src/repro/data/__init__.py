from .synthetic import SyntheticLMStream

__all__ = ["SyntheticLMStream"]
