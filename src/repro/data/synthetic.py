"""Deterministic synthetic LM data pipeline.

Token streams come from a stateless hash of (seed, step, position) so any
host can materialize its own shard without coordination — the property a
1000-node data pipeline needs for restart/elastic reshard: batch ``i`` is
identical no matter which host produces it or how many hosts exist.

A learnable-but-nontrivial distribution: a degree-2 Markov-ish mixture where
token t depends on (t-1, t-2) hashes, so a ~100M model's loss visibly drops
within a few hundred steps (used by examples/train_traced.py).
"""

from __future__ import annotations

import threading
import queue as queue_mod
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["SyntheticLMStream"]


def _hash2(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    x = (a.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
         + b.astype(np.uint64) * np.uint64(0xBF58476D1CE4E5B9))
    x ^= x >> np.uint64(31)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(29)
    return x


class SyntheticLMStream:
    """Iterator of {tokens, labels} int32 [batch, seq] with double-buffered
    background prefetch."""

    def __init__(self, vocab: int, batch: int, seq_len: int, seed: int = 0,
                 structured: bool = True, prefetch: int = 2):
        self.vocab = int(vocab)
        self.batch = int(batch)
        self.seq = int(seq_len)
        self.seed = seed
        self.structured = structured
        self._step = 0
        self._q: queue_mod.Queue = queue_mod.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    # -- deterministic batch materialization --------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        B, S, V = self.batch, self.seq + 1, self.vocab
        rows = (np.uint64(self.seed) * np.uint64(1_000_003)
                + np.arange(step * B, (step + 1) * B, dtype=np.uint64))
        pos = np.arange(S, dtype=np.uint64)
        h = _hash2(rows[:, None], pos[None, :])
        toks = (h % np.uint64(V)).astype(np.int64)
        if self.structured:
            # overwrite 75% of positions with a deterministic function of the
            # two previous tokens — learnable structure
            choose = (h >> np.uint64(32)) % np.uint64(4)
            for t in range(2, S):
                det = (toks[:, t - 1] * 31 + toks[:, t - 2] * 7) % V
                toks[:, t] = np.where(choose[:, t] > 0, det, toks[:, t])
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    # -- iterator protocol ----------------------------------------------------
    def _producer(self):
        step = 0
        while not self._stop.is_set():
            b = self.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.2)
                    break
                except queue_mod.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, b = self._q.get()
        self._step = step
        return b

    def seek(self, step: int) -> None:
        """Restart-safe: drain and refill from ``step`` (checkpoint restore)."""
        self.close()
        self.__init__(self.vocab, self.batch, self.seq, seed=self.seed,
                      structured=self.structured)
        # skip forward deterministically
        while self._step + 1 < step:
            self.__next__()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue_mod.Empty:
            pass
        self._thread.join(timeout=1.0)
