"""Mixture-of-Experts FFN with static-capacity gather/scatter dispatch.

Switch-Transformer-style routing adapted for TPU/pjit:

1. router logits → top-k experts + gate probs per token,
2. tokens sorted by expert id; rank-within-expert computed vectorially,
3. assignments over ``capacity`` are dropped (capacity_factor configurable),
4. an index table gathers tokens into ``[G, E, C, d]``,
5. batched expert matmuls (``E`` shardable along the ``model``/EP axis),
6. weighted scatter-add back to token order.

**Grouped dispatch** (``groups=G > 1``) is the scale-out path: tokens are
split into G groups aligned with the data-parallel shards, and capacity,
sorting, and gather/scatter all happen *within* a group.  Dispatch then never
crosses the data axis — measured on qwen2-moe train_4k this removed ~97% of
the per-device collective traffic (EXPERIMENTS.md §Perf iteration 2).

All shapes static → compiles under pjit; FLOPs counted by ``cost_analysis``
are the actual routed matmuls, so the roofline's MODEL_FLOPS/HLO_FLOPs ratio
directly exposes capacity waste.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain

__all__ = ["moe_ffn", "route_topk"]


def route_topk(router_logits: jax.Array, topk: int
               ) -> Tuple[jax.Array, jax.Array]:
    """[..., E] logits → ([..., k] expert ids, [..., k] gates)."""
    gates, idx = jax.lax.top_k(router_logits, topk)
    gates = jax.nn.softmax(gates.astype(jnp.float32), axis=-1)
    return idx.astype(jnp.int32), gates


def moe_ffn(x: jax.Array, w_router: jax.Array, w_gate: jax.Array,
            w_up: jax.Array, w_down: jax.Array, *, topk: int,
            capacity_factor: float = 1.25, dropless: bool = False,
            groups: int = 1) -> jax.Array:
    """x [T, d]; router [d, E]; w_gate/w_up [E, d, f]; w_down [E, f, d].

    ``dropless=True`` sets capacity C=Tg (a token hits an expert at most
    once, so nothing can overflow) — required at decode time where T is tiny.
    ``groups`` splits tokens into independently-dispatched groups (align with
    the data-parallel shard count so dispatch never crosses devices).
    """
    T, d = x.shape
    E = w_gate.shape[0]
    G = groups if T % groups == 0 else 1
    Tg = T // G
    if dropless:
        C = Tg
    else:
        C = max(int(Tg * topk / E * capacity_factor), 1)
        C = -(-C // 8) * 8                       # lane-align capacity
        C = min(C, Tg)

    xg = constrain(x.reshape(G, Tg, d), "act_batch", None, None)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    idx, gates = route_topk(logits, topk)                 # [G,Tg,k]

    K = Tg * topk
    flat_e = idx.reshape(G, K)
    flat_g = gates.reshape(G, K)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), topk)[None], (G, K))

    order = jnp.argsort(flat_e, axis=1)                   # stable per group
    e_sorted = jnp.take_along_axis(flat_e, order, axis=1)
    # rank within (group, expert) via global bincount with group offsets
    ge = (jnp.arange(G, dtype=jnp.int32)[:, None] * E + e_sorted).reshape(-1)
    counts = jnp.zeros(G * E, jnp.int32).at[ge].add(1).reshape(G, E)
    starts = jnp.concatenate(
        [jnp.zeros((G, 1), jnp.int32),
         jnp.cumsum(counts, axis=1)[:, :-1].astype(jnp.int32)], axis=1)
    rank = (jnp.arange(K, dtype=jnp.int32)[None]
            - jnp.take_along_axis(starts, e_sorted, axis=1))
    keep = rank < C

    slot = jnp.where(keep, e_sorted * C + rank, E * C)    # overflow slot
    tok_sorted = jnp.take_along_axis(flat_tok, order, axis=1)
    gate_sorted = jnp.take_along_axis(flat_g, order, axis=1)
    gi = jnp.arange(G, dtype=jnp.int32)[:, None]
    tok_tab = jnp.full((G, E * C + 1), Tg, jnp.int32).at[gi, slot].set(tok_sorted)
    gate_tab = jnp.zeros((G, E * C + 1), jnp.float32).at[gi, slot].set(gate_sorted)
    tok_tab, gate_tab = tok_tab[:, :-1], gate_tab[:, :-1]

    xp = constrain(jnp.concatenate([xg, jnp.zeros((G, 1, d), x.dtype)],
                                   axis=1), "act_batch", None, None)
    # vmapped gather: batched-index take_along_axis makes GSPMD all-gather
    # the [G,Tg,d] tokens; the vmap form keeps the gather group-local
    xe = jax.vmap(lambda xpr, tok: xpr[tok])(xp, tok_tab)     # [G,E*C,d]
    xe = constrain(xe.reshape(G, E, C, d), "act_batch", "act_exp", None, None)

    h = constrain(jnp.einsum("gecd,edf->gecf", xe, w_gate),
                  "act_batch", "act_exp", None, "act_ff")
    u = constrain(jnp.einsum("gecd,edf->gecf", xe, w_up),
                  "act_batch", "act_exp", None, "act_ff")
    y = constrain(jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * u, w_down),
                  "act_batch", "act_exp", None, None)

    # combine in the activation dtype: the gate-weighted sum has ≤ topk
    # terms, and the cross-model all-reduce of the combined tokens is the
    # biggest remaining collective — bf16 halves it (f32 in f32 tests).
    cdt = x.dtype
    yw = constrain(
        (y.reshape(G, E * C, d).astype(jnp.float32)
         * gate_tab[..., None]).astype(cdt),
        "act_batch", None, None)
    # combine via a *vmapped* scatter-add: explicit [gi, tok] batch indices
    # defeat GSPMD's scatter partitioner (it replicates the [G,Tg,d] target —
    # 3×17 GB of per-layer collectives on qwen3-moe, §Perf iterations 3-4);
    # the vmap form marks G as a scatter batch dim and the combine stays
    # local up to one model-axis all-reduce of the E-sharded contributions.
    zeros = constrain(jnp.zeros((G, Tg + 1, d), cdt),
                      "act_batch", None, None)
    out = jax.vmap(lambda z, t, yv: z.at[t].add(yv))(zeros, tok_tab, yw)
    out = constrain(out[:, :Tg], "act_batch", None, None)
    return out.reshape(T, d).astype(x.dtype)
