"""Shared neural-net layers and the parameter-definition machinery.

Parameters are declared once as ``ParamDef`` pytrees carrying (shape, logical
axes, init); the same tree produces concrete arrays (``init_tree``), shape
stand-ins for the dry-run (``abstract_tree``), and ``PartitionSpec`` trees
(``spec_tree`` via the sharding rules in :mod:`repro.distributed.sharding`).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamDef", "init_tree", "abstract_tree", "map_defs", "rms_norm",
           "rope", "apply_rope", "gelu", "swiglu_act", "softmax_xent"]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]       # logical axis name per dim
    init: str = "normal"                  # normal | zeros | ones
    scale: float = 1.0                    # stddev multiplier (normal)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def map_defs(fn: Callable[[ParamDef], Any], tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_def)


def init_tree(tree, key: jax.Array, dtype=jnp.bfloat16):
    """Materialize a ParamDef tree into arrays (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dtype))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale / np.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, d.shape, jnp.float32) * std
                        ).astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_tree(tree, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins (for .lower() without allocation)."""
    return map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), tree)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * (1.0 + gamma.astype(dt))


def rope(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """Rotary embedding tables for integer positions [..., S] -> cos,sin [..., S, hd/2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, S, H, hd]; cos/sin: [B, S, hd/2] (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype) if cos.ndim == 3 else cos
    s = sin[..., None, :].astype(x.dtype) if sin.ndim == 3 else sin
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


def swiglu_act(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def softmax_xent(logits: jax.Array, labels: jax.Array, vocab: int) -> jax.Array:
    """Mean next-token cross-entropy; labels >= vocab (padding ids) masked out.

    logits [B,S,V] (V possibly padded beyond vocab), labels [B,S] int32.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    mask = (labels >= 0) & (labels < vocab)
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
