"""Model zoo: a unified functional LM covering dense / MoE / SSM / hybrid /
enc-dec / VLM families, plus ``input_specs`` stand-ins for the dry-run."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .config import SHAPES, ModelConfig, ShapeConfig
from .encdec import EncDecLM
from .lm import LM

__all__ = ["LM", "EncDecLM", "build_model", "input_specs", "ModelConfig",
           "ShapeConfig", "SHAPES"]


def build_model(cfg: ModelConfig) -> LM:
    return EncDecLM(cfg) if cfg.family == "encdec" else LM(cfg)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a given cell.

    * train  → {tokens, labels} (+frames/img_embeds by family)
    * prefill→ {tokens} (+frames/img_embeds)
    * decode → {token, pos} (+cache built separately via model.init_cache)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
               "labels": jax.ShapeDtypeStruct((B, S), i32)}
    elif shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:  # decode: one new token against a cache of length S
        out = {"token": jax.ShapeDtypeStruct((B, 1), i32),
               "pos": jax.ShapeDtypeStruct((), i32)}
    if cfg.family == "encdec" and shape.kind in ("train", "prefill"):
        out["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_frames, cfg.d_model),
                                             dtype)
    if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
        out["img_embeds"] = jax.ShapeDtypeStruct((B, cfg.img_tokens,
                                                  cfg.d_model), dtype)
    return out
