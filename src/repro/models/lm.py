"""Unified decoder-only LM covering the dense / MoE / SSM / hybrid / VLM
families.  Layers are stacked and scanned (``lax.scan``) in *periods*: most
archs scan ``n_layers`` identical layers (period 1); gemma3 scans groups of
(5 local + 1 global) so the 5:1 attention pattern stays static inside the
scan body — no ``lax.cond``, exact FLOP accounting, compact HLO.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import LayerSpec, cache_defs, layer_apply, layer_defs
from ..distributed.sharding import constrain
from .config import ModelConfig
from .layers import (ParamDef, abstract_tree, init_tree, map_defs, rms_norm,
                     softmax_xent)

__all__ = ["LM", "plan_layers"]

_REMAT_POLICIES = {
    "nothing_saveable": jax.checkpoint_policies.nothing_saveable,
    "dots_saveable": jax.checkpoint_policies.dots_saveable,
    "dots_with_no_batch_dims_saveable":
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    "everything_saveable": jax.checkpoint_policies.everything_saveable,
}


def plan_layers(cfg: ModelConfig) -> Tuple[Tuple[LayerSpec, ...], int,
                                           Tuple[LayerSpec, ...]]:
    """(pattern within a period, n_periods, tail specs)."""
    if cfg.family == "ssm":
        base = LayerSpec(mixer="ssm")
    elif cfg.family == "hybrid":
        base = LayerSpec(mixer="hybrid", window=cfg.window, moe=False)
    elif cfg.family == "moe":
        base = LayerSpec(mixer="attn", moe=True)
    else:                      # dense | vlm
        base = LayerSpec(mixer="attn", window=cfg.window)

    if cfg.global_every:       # gemma3-style local:global interleave
        local = LayerSpec(mixer="attn", window=cfg.window, rope_theta=1e4)
        glob = LayerSpec(mixer="attn", window=None, rope_theta=cfg.rope_theta)
        pattern = tuple([local] * (cfg.global_every - 1) + [glob])
        n_periods = cfg.n_layers // len(pattern)
        tail = tuple([local] * (cfg.n_layers - n_periods * len(pattern)))
        return pattern, n_periods, tail
    return (base,), cfg.n_layers, ()


def _stack_defs(defs: Dict[str, ParamDef], *lead: int) -> Dict[str, ParamDef]:
    lead_axes = tuple(["layers"] + ["layers_inner"] * (len(lead) - 1))
    return {k: ParamDef(tuple(lead) + d.shape, lead_axes + d.axes, d.init,
                        d.scale)
            for k, d in defs.items()}


class LM:
    """Functional model: all methods are pure and jit/pjit-friendly."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pattern, self.n_periods, self.tail = plan_layers(cfg)
        self.period = len(self.pattern)

    # -- parameters ---------------------------------------------------------
    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        d, V = cfg.d_model, cfg.padded_vocab
        defs: Dict[str, Any] = {
            "embed": ParamDef((V, d), ("vocab", "embed"),
                              scale=float(np.sqrt(V / d))),
            "final_ln": ParamDef((d,), ("embed",), "zeros"),
        }
        layer = layer_defs(cfg, self.pattern[0])
        for s in self.pattern[1:]:
            assert set(layer_defs(cfg, s)) == set(layer), "period must be homogeneous"
        defs["blocks"] = _stack_defs(layer, self.n_periods, self.period) \
            if self.period > 1 else _stack_defs(layer, self.n_periods)
        if self.tail:
            defs["tail"] = _stack_defs(layer_defs(cfg, self.tail[0]),
                                       len(self.tail))
        if not cfg.tie_embeddings:
            defs["unembed"] = ParamDef((d, V), ("embed", "vocab"))
        if cfg.meta_tokens:
            defs["meta"] = ParamDef((cfg.meta_tokens, d), (None, "embed"),
                                    scale=float(np.sqrt(d)))
        return defs

    def init(self, key: jax.Array, dtype=jnp.bfloat16):
        return init_tree(self.param_defs(), key, dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        return abstract_tree(self.param_defs(), dtype)

    # -- cache --------------------------------------------------------------
    def cache_defs(self, batch: int, cache_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        per = {}
        for i, s in enumerate(self.pattern):
            cd = cache_defs(cfg, s, batch, cache_len)
            for k, v in cd.items():
                per.setdefault(k, []).append((i, v))
        # all pattern positions must produce the same cache keys & shapes per
        # kind; stack [n_periods, period, ...] grouped by (key, shape)
        out: Dict[str, Any] = {}
        blocks: Dict[str, ParamDef] = {}
        for k, items in per.items():
            shapes = {v.shape for _, v in items}
            assert len(shapes) == 1 or self.period == len(self.pattern), k
        # group identical-shape keys; for gemma3 local/global have different
        # cache lengths → separate entries per pattern position group
        groups: Dict[Tuple[str, Tuple[int, ...]], List[int]] = {}
        for k, items in per.items():
            for i, v in items:
                groups.setdefault((k, v.shape), []).append(i)
        for (k, shape), idxs in groups.items():
            proto = dict(per[k])[idxs[0]]
            name = f"{k}@{'-'.join(map(str, idxs))}"
            blocks[name] = ParamDef((self.n_periods, len(idxs)) + proto.shape,
                                    ("layers", "layers_inner") + proto.axes,
                                    "zeros")
        out["blocks"] = blocks
        if self.tail:
            tl = {}
            for k, v in cache_defs(cfg, self.tail[0], batch, cache_len).items():
                tl[k] = ParamDef((len(self.tail),) + v.shape,
                                 ("layers",) + v.axes, "zeros")
            out["tail"] = tl
        return out

    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16,
                   abstract: bool = False):
        """Zeroed (or ShapeDtypeStruct) cache.  SSD states are f32 (they
        accumulate); KV/conv caches use the activation dtype."""
        defs = self.cache_defs(batch, cache_len)

        def mk(path, d):
            name = str(path[-1].key) if path else ""
            dt = jnp.float32 if name.startswith("ssm_h") else dtype
            if abstract:
                return jax.ShapeDtypeStruct(d.shape, dt)
            return jnp.zeros(d.shape, dt)

        return jax.tree_util.tree_map_with_path(
            mk, defs, is_leaf=lambda x: isinstance(x, ParamDef))

    # -- cache <-> per-layer views -----------------------------------------
    @staticmethod
    def _cache_slice(cblk: Dict[str, jax.Array], i: int) -> Dict[str, jax.Array]:
        """Per-pattern-position cache view from grouped '@' keys."""
        out = {}
        for name, arr in cblk.items():
            k, idxs = name.split("@")
            idxs = [int(j) for j in idxs.split("-")]
            if i in idxs:
                out[k] = arr[idxs.index(i)]
        return out

    @staticmethod
    def _cache_unslice(names, per_pos: List[Dict[str, jax.Array]]):
        """Inverse of _cache_slice: re-stack per-position dicts."""
        out = {}
        for name in names:
            k, idxs = name.split("@")
            idxs = [int(j) for j in idxs.split("-")]
            out[name] = jnp.stack([per_pos[i][k] for i in idxs], axis=0)
        return out

    # -- forward ------------------------------------------------------------
    def _prefix_embeds(self, params, batch: int) -> Optional[jax.Array]:
        if self.cfg.meta_tokens:
            return jnp.broadcast_to(params["meta"][None],
                                    (batch,) + params["meta"].shape)
        return None

    def _embed_tokens(self, params, tokens, img_embeds=None):
        x = params["embed"][tokens]
        pre = []
        if img_embeds is not None:
            pre.append(img_embeds.astype(x.dtype))
        mt = self._prefix_embeds(params, tokens.shape[0])
        if mt is not None:
            pre.append(mt)
        prefix_len = sum(p.shape[1] for p in pre)
        if pre:
            x = jnp.concatenate(pre + [x], axis=1)
        return constrain(x, "act_batch", "act_seq", "act_embed"), prefix_len

    def _run_blocks(self, params, x, mode: str, pos, cache=None,
                    cache_len: int = 0, enc_out=None):
        cfg = self.cfg
        collect = mode == "prefill"
        cblk = cache["blocks"] if (cache is not None and mode == "decode") else None

        def body(xc, inp):
            blk = inp[0] if isinstance(inp, tuple) else inp
            cin = inp[1] if isinstance(inp, tuple) else None
            per_pos = []
            for i, spec in enumerate(self.pattern):
                p_i = jax.tree_util.tree_map(lambda a: a[i], blk) \
                    if self.period > 1 else blk
                c_i = self._cache_slice(cin, i) if cin is not None else None
                xc, nc = layer_apply(p_i, xc, cfg, spec, mode=mode, pos=pos,
                                     cache=c_i, enc_out=enc_out,
                                     cache_len=cache_len)
                xc = constrain(xc, "act_batch", "act_seq", "act_embed")
                per_pos.append(nc)
            ys = None
            if collect or mode == "decode":
                names = cin.keys() if cin is not None else None
                if names is None:
                    # build grouped names from produced caches
                    names = self._group_names(per_pos)
                ys = self._cache_unslice(list(names), per_pos)
            return xc, ys

        if mode == "train" and cfg.remat != "none":
            body = jax.checkpoint(
                body, policy=_REMAT_POLICIES.get(cfg.remat), prevent_cse=False)

        xs = params["blocks"] if cblk is None else (params["blocks"], cblk)
        layer_unroll = min(max(cfg.cost_probe, 1), self.n_periods)
        x, new_cblk = jax.lax.scan(body, x, xs, unroll=layer_unroll)

        new_tail = {}
        if self.tail:
            tcache = cache["tail"] if (cache is not None and mode == "decode") \
                else None
            per_pos = []
            for t, spec in enumerate(self.tail):
                p_t = jax.tree_util.tree_map(lambda a: a[t], params["tail"])
                c_t = jax.tree_util.tree_map(lambda a: a[t], tcache) \
                    if tcache is not None else None
                x, nc = layer_apply(p_t, x, cfg, spec, mode=mode, pos=pos,
                                    cache=c_t, enc_out=enc_out,
                                    cache_len=cache_len)
                per_pos.append(nc)
            if per_pos and per_pos[0]:
                new_tail = {k: jnp.stack([pp[k] for pp in per_pos])
                            for k in per_pos[0]}
        new_cache = None
        if collect or mode == "decode":
            new_cache = {"blocks": new_cblk}
            if self.tail:
                new_cache["tail"] = new_tail
        return x, new_cache

    def _group_names(self, per_pos: List[Dict[str, jax.Array]]) -> List[str]:
        groups: Dict[Tuple[str, Tuple[int, ...]], List[int]] = {}
        for i, d in enumerate(per_pos):
            for k, v in d.items():
                groups.setdefault((k, tuple(v.shape)), []).append(i)
        return [f"{k}@{'-'.join(map(str, idxs))}" for (k, _), idxs in groups.items()]

    def _logits(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        return constrain(x @ w, "act_batch", "act_seq", "act_vocab")

    # -- public entry points -------------------------------------------------
    def forward(self, params, tokens, img_embeds=None):
        x, prefix = self._embed_tokens(params, tokens, img_embeds)
        x, _ = self._run_blocks(params, x, "train", 0)
        return self._logits(params, x), prefix

    def loss(self, params, batch) -> jax.Array:
        logits, prefix = self.forward(params, batch["tokens"],
                                      batch.get("img_embeds"))
        if prefix:
            logits = logits[:, prefix:]
        return softmax_xent(logits, batch["labels"], self.cfg.vocab)

    def prefill(self, params, tokens, cache_len: int, img_embeds=None):
        """Returns (cache, last-token logits, next_pos)."""
        x, prefix = self._embed_tokens(params, tokens, img_embeds)
        S_total = x.shape[1]
        x, cache = self._run_blocks(params, x, "prefill", 0,
                                    cache_len=cache_len)
        logits = self._logits(params, x[:, -1:])
        return cache, logits[:, 0], S_total

    def decode_step(self, params, cache, token, pos, cache_len: int):
        """token [B,1] int32; pos: scalar (tokens so far incl. prefix).
        Returns (logits [B,V], new_cache)."""
        x = params["embed"][token]
        x, new_cache = self._run_blocks(params, x, "decode", pos, cache=cache,
                                        cache_len=cache_len)
        return self._logits(params, x)[:, 0], new_cache
