"""Encoder-decoder LM (Whisper-style).  The audio conv frontend is a stub per
the assignment: ``input_specs()`` supplies precomputed frame embeddings
``[B, enc_frames, d_model]``; a learned linear projection stands in for the
conv stack.  Encoder uses sinusoidal positions + bidirectional attention;
decoder is a causal LM with cross-attention whose K/V are cached at prefill.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import LayerSpec, cache_defs, layer_apply, layer_defs
from .config import ModelConfig
from .layers import ParamDef, abstract_tree, init_tree, rms_norm, softmax_xent
from .lm import LM, _REMAT_POLICIES, _stack_defs

__all__ = ["EncDecLM", "sinusoidal_positions"]


def sinusoidal_positions(S: int, d: int) -> np.ndarray:
    pos = np.arange(S)[:, None]
    dim = np.arange(d // 2)[None]
    ang = pos / (10000.0 ** (dim / (d // 2)))
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


class EncDecLM(LM):
    """Whisper-shaped model; reuses the LM scan machinery for the decoder."""

    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        # decoder layers: causal self-attn + cross-attn + FFN
        self.pattern = (LayerSpec(mixer="attn", cross=True),)
        self.period = 1
        self.n_periods = cfg.n_layers
        self.tail = ()
        self.enc_spec = LayerSpec(mixer="attn", causal=False)

    def param_defs(self) -> Dict[str, Any]:
        defs = super().param_defs()
        cfg = self.cfg
        d = cfg.d_model
        defs["blocks"] = _stack_defs(layer_defs(cfg, self.pattern[0]),
                                     self.n_periods)
        defs["frontend"] = ParamDef((d, d), ("embed", "embed2"))
        defs["enc_blocks"] = _stack_defs(layer_defs(cfg, self.enc_spec),
                                         cfg.enc_layers)
        defs["enc_ln"] = ParamDef((d,), ("embed",), "zeros")
        return defs

    # -- encoder -------------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = frames @ params["frontend"]
        pos = jnp.asarray(sinusoidal_positions(frames.shape[1], cfg.d_model))
        x = x + pos[None].astype(x.dtype)

        def body(xc, blk):
            xc, _ = layer_apply(blk, xc, cfg, self.enc_spec, mode="train")
            return xc, None

        if cfg.remat != "none":
            body = jax.checkpoint(body, policy=_REMAT_POLICIES.get(cfg.remat),
                                  prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"],
                            unroll=min(max(cfg.cost_probe, 1),
                                       cfg.enc_layers))
        return rms_norm(x, params["enc_ln"], cfg.norm_eps)

    # -- public entry points --------------------------------------------------
    def forward(self, params, tokens, img_embeds=None, frames=None):
        assert frames is not None, "encoder-decoder needs frames"
        enc_out = self.encode(params, frames)
        x, prefix = self._embed_tokens(params, tokens)
        x, _ = self._run_blocks(params, x, "train", 0, enc_out=enc_out)
        return self._logits(params, x), prefix

    def loss(self, params, batch) -> jax.Array:
        logits, prefix = self.forward(params, batch["tokens"],
                                      frames=batch["frames"])
        return softmax_xent(logits, batch["labels"], self.cfg.vocab)

    def prefill(self, params, tokens, cache_len: int, img_embeds=None,
                frames=None):
        enc_out = self.encode(params, frames)
        x, _ = self._embed_tokens(params, tokens)
        x, cache = self._run_blocks(params, x, "prefill", 0,
                                    cache_len=cache_len, enc_out=enc_out)
        logits = self._logits(params, x[:, -1:])
        return cache, logits[:, 0], tokens.shape[1]
