"""Mamba-2 SSD (state-space duality) core: chunked parallel form for
training/prefill, O(1)-state recurrent form for decode.

Math (per head h, head dim P, state N):
    h_t = exp(A·dt_t) · h_{t-1} + dt_t · B_t ⊗ x_t        (state update)
    y_t = C_t · h_t + D · x_t                             (readout)

The chunked algorithm splits the sequence into chunks of ``Q`` tokens; within
a chunk the quadratic "attention-like" form runs on the MXU, states are passed
between chunks by a ``lax.scan``.  This is the TPU-native adaptation of the
paper's SSD blocked algorithm (arXiv:2405.21060).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain

__all__ = ["ssd_chunked", "ssd_reference", "ssd_step", "causal_conv1d",
           "conv1d_step"]


def ssd_reference(x, dt, A, Bm, Cm, h0=None):
    """Sequential oracle.  x [B,S,H,P]; dt [B,S,H]; A [H]; Bm/Cm [B,S,N]."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    h = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # [B,H,P],[B,H],[B,N],[B,N]
        decay = jnp.exp(A[None] * dtt)              # [B,H]
        h = h * decay[..., None, None] + (
            dtt[..., None, None] * xt[..., None] * bt[:, None, None, :])
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cm.astype(jnp.float32), 1, 0))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h  # y [B,S,H,P]


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int = 256, h0=None,
                unroll: int = 1):
    """Chunked SSD.  Same signature/returns as :func:`ssd_reference`."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    f32 = jnp.float32
    xc = constrain(jnp.moveaxis(x.reshape(B, nc, Q, H, P), 1, 0).astype(f32),
                   None, "act_batch", None, "act_ssm_heads", None)
    dtc = constrain(jnp.moveaxis(dt.reshape(B, nc, Q, H), 1, 0).astype(f32),
                    None, "act_batch", None, "act_ssm_heads")
    bc = constrain(jnp.moveaxis(Bm.reshape(B, nc, Q, N), 1, 0).astype(f32),
                   None, "act_batch", None, None)
    cc = constrain(jnp.moveaxis(Cm.reshape(B, nc, Q, N), 1, 0).astype(f32),
                   None, "act_batch", None, None)
    h = jnp.zeros((B, H, P, N), f32) if h0 is None else h0.astype(f32)
    h = constrain(h, "act_batch", "act_ssm_heads", None, None)

    def body(h, inp):
        xq, dtq, bq, cq = inp           # [B,Q,H,P],[B,Q,H],[B,Q,N],[B,Q,N]
        a = A[None, None] * dtq          # [B,Q,H] log-decay per step
        cum = jnp.cumsum(a, axis=1)      # inclusive cumsum
        # --- intra-chunk (quadratic, MXU-friendly) -----------------------
        g = jnp.einsum("bsn,btn->bst", cq, bq)                  # [B,Q,Q]
        ldiff = cum[:, :, None, :] - cum[:, None, :, :]          # [B,s,t,H]
        mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
        L = jnp.where(mask[None, :, :, None], jnp.exp(ldiff), 0.0)
        m = g[..., None] * L * dtq[:, None, :, :]                # [B,s,t,H]
        y = jnp.einsum("bsth,bthp->bshp", m, xq)                 # [B,Q,H,P]
        # --- inter-chunk: contribution of the carried state --------------
        y += jnp.einsum("bsn,bhpn,bsh->bshp", cq, h, jnp.exp(cum))
        # --- state passing -------------------------------------------------
        tot = cum[:, -1:, :]                                     # [B,1,H]
        w = dtq * jnp.exp(tot - cum)                             # [B,Q,H]
        h_in = jnp.einsum("btn,bthp,bth->bhpn", bq, xq, w)
        h = h * jnp.exp(tot[:, 0])[:, :, None, None] + h_in
        h = constrain(h, "act_batch", "act_ssm_heads", None, None)
        y = constrain(y, "act_batch", None, "act_ssm_heads", None)
        return h, y

    h, yc = jax.lax.scan(body, h, (xc, dtc, bc, cc),
                         unroll=min(unroll, nc) if unroll > 1 else 1)
    y = jnp.moveaxis(yc, 0, 1).reshape(B, nc * Q, H, P)
    return y[:, :S].astype(x.dtype), h


def ssd_step(h, xt, dtt, A, bt, ct):
    """One decode step.  h [B,H,P,N]; xt [B,H,P]; dtt [B,H]; bt/ct [B,N]."""
    decay = jnp.exp(A[None] * dtt)
    h = h * decay[..., None, None] + (
        dtt[..., None, None] * xt.astype(jnp.float32)[..., None]
        * bt.astype(jnp.float32)[:, None, None, :])
    y = jnp.einsum("bhpn,bn->bhp", h, ct.astype(jnp.float32))
    return h, y.astype(xt.dtype)


def causal_conv1d(x, w, b):
    """Depthwise causal conv.  x [B,S,Cch]; w [W,Cch]; b [Cch]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    S = x.shape[1]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):   # W is 4 — tiny static unroll
        y = y + xp[:, i:i + S].astype(jnp.float32) * w[i][None, None]
    return jax.nn.silu(y + b[None, None]).astype(x.dtype)


def conv1d_step(conv_state, xt, w, b):
    """Decode-time conv.  conv_state [B,W-1,C]; xt [B,C] → (new_state, yt)."""
    W = w.shape[0]
    window = jnp.concatenate([conv_state, xt[:, None]], axis=1)  # [B,W,C]
    y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32)) + b[None]
    return window[:, 1:], jax.nn.silu(y).astype(xt.dtype)
