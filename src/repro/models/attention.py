"""Attention cores: chunked (flash-style) training/prefill attention, banded
local-window attention, and single-token decode attention.

All paths are pure ``jnp`` + ``lax`` (shardable under pjit); the Pallas TPU
kernel in :mod:`repro.kernels.flash_attention` implements the same math for
the MXU and is validated against :func:`reference_attention` in interpret
mode.  The chunked scan keeps peak memory at O(S·chunk) instead of O(S²),
which is what lets the 32k-token cells compile inside a v5e HBM budget.

GQA layout: q ``[B,S,H,D]``, k/v ``[B,S,KVH,D]`` with ``H = KVH*G``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain

__all__ = ["reference_attention", "chunked_attention", "local_attention",
           "decode_attention"]

_NEG = -1e30


def _mask(qpos, kpos, causal: bool, window, prefix_len: int = 0):
    """[Sq,Sk] boolean allowed-mask from absolute positions.  ``prefix_len``
    keeps the first N keys always attendable (Hymba meta tokens)."""
    d = qpos[:, None] - kpos[None, :]
    m = jnp.ones(d.shape, bool)
    if causal:
        m &= d >= 0
    if window is not None:
        m &= d < window
    if prefix_len:
        m |= ((kpos < prefix_len)[None, :] & (d >= 0))
    return m


def reference_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                        scale=None):
    """O(S²) oracle used by tests and tiny shapes."""
    B, Sq, H, D = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = scale or D ** -0.5
    qq = q.reshape(B, Sq, KVH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qq.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    s = jnp.where(_mask(qpos, kpos, causal, window)[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def chunked_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                      chunk: int = 1024, scale=None, prefix_len: int = 0,
                      unroll: int = 1):
    """Online-softmax attention scanning over KV chunks (flash-style).

    ``window`` may be a traced scalar (the gemma3 local/global switch); block
    skipping is impossible then, but masking stays correct.
    """
    B, Sq, H, D = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = scale or D ** -0.5
    chunk = min(chunk, Sk)
    nk = -(-Sk // chunk)
    pad = nk * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = constrain(jnp.moveaxis(k.reshape(B, nk, chunk, KVH, D), 1, 0),
                   None, "act_batch", None, "act_kv", None)
    vc = constrain(jnp.moveaxis(v.reshape(B, nk, chunk, KVH, D), 1, 0),
                   None, "act_batch", None, "act_kv", None)
    qq = constrain((q.reshape(B, Sq, KVH, G, D) * scale).astype(q.dtype),
                   "act_batch", "act_seq", "act_kv_group", "act_q_group", None)
    qpos = q_offset + jnp.arange(Sq)

    def body(carry, inp):
        m, l, acc = carry
        kci, vci, ki = inp
        s = jnp.einsum("bqhgd,bchd->bhgqc", qq.astype(jnp.float32),
                       kci.astype(jnp.float32))
        kpos = ki * chunk + jnp.arange(chunk)
        allow = _mask(qpos, kpos, causal, window, prefix_len) & (kpos < Sk)[None, :]
        s = jnp.where(allow[None, None, None], s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqc,bchd->bhgqd", p, vci.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        m_new = constrain(m_new, "act_batch", "act_kv_group", "act_q_group",
                          "act_seq")
        l_new = constrain(l_new, "act_batch", "act_kv_group", "act_q_group",
                          "act_seq")
        acc_new = constrain(acc_new, "act_batch", "act_kv_group",
                            "act_q_group", "act_seq", None)
        return (m_new, l_new, acc_new), None

    m0 = constrain(jnp.full((B, KVH, G, Sq), _NEG, jnp.float32),
                   "act_batch", "act_kv_group", "act_q_group", "act_seq")
    l0 = constrain(jnp.zeros((B, KVH, G, Sq), jnp.float32),
                   "act_batch", "act_kv_group", "act_q_group", "act_seq")
    a0 = constrain(jnp.zeros((B, KVH, G, Sq, D), jnp.float32),
                   "act_batch", "act_kv_group", "act_q_group", "act_seq", None)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nk)),
                                  unroll=min(unroll, nk) if unroll > 1 else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, D).astype(q.dtype)


def local_attention(q, k, v, *, window: int, chunk: int = 512, scale=None,
                    unroll: int = 1):
    """Banded sliding-window attention: each query chunk attends only to the
    KV band ``[qstart - window, qend)`` — O(S·(window+chunk)) compute instead
    of O(S²).  ``window`` must be static here.  Causal by construction;
    sequences start at position 0.
    """
    B, Sq, H, D = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = scale or D ** -0.5
    chunk = min(chunk, Sq)
    nq = -(-Sq // chunk)
    qpad = nq * chunk - Sq
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    band = window + chunk                      # kv span a query chunk can see
    band = -(-band // chunk) * chunk           # round up to chunk multiple
    # pad KV left by `band` and right up to nq*chunk so every slice is in
    # range (dynamic_slice clamps out-of-range starts, silently shifting the
    # window — the explicit pad prevents that)
    assert Sq == Sk, "local_attention is self-attention"
    k = jnp.pad(k, ((0, 0), (band, nq * chunk - Sk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (band, nq * chunk - Sk), (0, 0), (0, 0)))
    qb = constrain(jnp.moveaxis(q.reshape(B, nq, chunk, H, D), 1, 0),
                   None, "act_batch", None, "act_heads", None)
    k = constrain(k, "act_batch", None, "act_kv", None)
    v = constrain(v, "act_batch", None, "act_kv", None)

    def body(_, inp):
        qi, i = inp
        qstart = i * chunk
        # band start in padded-kv coordinates: (qstart + chunk - band) + band
        kstart = qstart + chunk
        kci = jax.lax.dynamic_slice_in_dim(k, kstart, band, axis=1)
        vci = jax.lax.dynamic_slice_in_dim(v, kstart, band, axis=1)
        qq = qi.reshape(B, chunk, KVH, G, D) * scale
        s = jnp.einsum("bqhgd,bchd->bhgqc", qq.astype(jnp.float32),
                       kci.astype(jnp.float32))
        qpos = qstart + jnp.arange(chunk)
        kpos = qstart + chunk - band + jnp.arange(band)
        allow = _mask(qpos, kpos, True, window)
        allow &= ((kpos >= 0) & (kpos < Sk))[None, :]
        s = jnp.where(allow[None, None, None], s, _NEG)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqc,bchd->bqhgd", p, vci.astype(jnp.float32))
        return None, constrain(o.reshape(B, chunk, H, D),
                               "act_batch", None, "act_heads", None)

    _, ob = jax.lax.scan(body, None, (qb, jnp.arange(nq)),
                         unroll=min(unroll, nq) if unroll > 1 else 1)
    out = jnp.moveaxis(ob, 0, 1).reshape(B, nq * chunk, H, D)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(q, k, v, *, kv_len, window=None, scale=None):
    """Single-token attention against a full cache.

    q ``[B,1,H,D]``; k/v ``[B,Smax,KVH,D]`` where positions ``>= kv_len`` are
    unwritten.  Direct (unchunked) einsum: the score tensor is only
    ``[B,H,Smax]`` and XLA handles a sequence-sharded cache with a distributed
    softmax (partial max/sum + all-reduce).
    """
    B, _, H, D = q.shape
    Smax, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = scale or D ** -0.5
    qq = q.reshape(B, KVH, G, D) * scale
    s = jnp.einsum("bhgd,bshd->bhgs", qq.astype(jnp.float32),
                   k.astype(jnp.float32))
    kpos = jnp.arange(Smax)
    qpos = kv_len  # the new token's position
    allow = kpos < kv_len + 1
    allow &= kpos <= qpos
    if window is not None:
        allow &= kpos > qpos - window
    s = jnp.where(allow[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)
