"""Per-layer building blocks shared by every architecture family.

A *layer spec* (``LayerSpec``) describes one transformer layer: which mixer it
uses (attention / SSD / both-in-parallel), its attention window, and whether
the FFN is dense or MoE.  ``layer_defs`` emits the ParamDef tree for one such
layer; ``layer_apply`` runs it in ``train``/``prefill``/``decode`` mode.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from . import ssm as ssm_lib
from ..distributed.sharding import constrain
from .config import ModelConfig
from .layers import ParamDef, apply_rope, gelu, rms_norm, rope, swiglu_act
from .moe import moe_ffn

__all__ = ["LayerSpec", "layer_defs", "layer_apply", "cache_defs"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str                    # attn | ssm | hybrid
    window: Optional[int] = None  # sliding window (None = full)
    moe: bool = False
    cross: bool = False           # enc-dec decoder cross-attention
    causal: bool = True
    rope_theta: Optional[float] = None


# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------

def _attn_defs(cfg: ModelConfig, prefix: str = "") -> Dict[str, ParamDef]:
    d, H, KVH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    out = {
        prefix + "ln": ParamDef((d,), ("embed",), "zeros"),
        prefix + "wq": ParamDef((d, H * hd), ("embed", "heads")),
        prefix + "wk": ParamDef((d, KVH * hd), ("embed", "kv")),
        prefix + "wv": ParamDef((d, KVH * hd), ("embed", "kv")),
        prefix + "wo": ParamDef((H * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        out[prefix + "bq"] = ParamDef((H * hd,), ("heads",), "zeros")
        out[prefix + "bk"] = ParamDef((KVH * hd,), ("kv",), "zeros")
        out[prefix + "bv"] = ParamDef((KVH * hd,), ("kv",), "zeros")
    return out


def _ssm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    nh = d_in // cfg.ssm_headdim
    N = cfg.ssm_state
    conv_ch = d_in + 2 * N
    return {
        "sln": ParamDef((d,), ("embed",), "zeros"),
        "w_zx": ParamDef((d, 2 * d_in), ("embed", "ssm_in")),
        "w_bc": ParamDef((d, 2 * N), ("embed", None)),
        "w_dt": ParamDef((d, nh), ("embed", None)),
        "conv_w": ParamDef((cfg.conv_width, conv_ch), (None, "ssm_in")),
        "conv_b": ParamDef((conv_ch,), ("ssm_in",), "zeros"),
        "A_log": ParamDef((nh,), (None,), "zeros"),
        "Dskip": ParamDef((nh,), (None,), "ones"),
        "dt_bias": ParamDef((nh,), (None,), "zeros"),
        "w_so": ParamDef((d_in, d), ("ssm_in", "embed")),
    }


def _ffn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    out = {"fln": ParamDef((d,), ("embed",), "zeros")}
    if cfg.act == "swiglu":
        out["w_gate"] = ParamDef((d, f), ("embed", "mlp"))
        out["w_up"] = ParamDef((d, f), ("embed", "mlp"))
    else:
        out["w_up"] = ParamDef((d, f), ("embed", "mlp"))
    out["w_down"] = ParamDef((f, d), ("mlp", "embed"))
    return out


def _moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    out = {
        "fln": ParamDef((d,), ("embed",), "zeros"),
        "router": ParamDef((d, E), ("embed", None)),
        "we_gate": ParamDef((E, d, f), ("experts", "embed", "expert_mlp")),
        "we_up": ParamDef((E, d, f), ("experts", "embed", "expert_mlp")),
        "we_down": ParamDef((E, f, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        out["ws_gate"] = ParamDef((d, fs), ("embed", "mlp"))
        out["ws_up"] = ParamDef((d, fs), ("embed", "mlp"))
        out["ws_down"] = ParamDef((fs, d), ("mlp", "embed"))
        out["ws_sig"] = ParamDef((d, 1), ("embed", None), "zeros")
    return out


def layer_defs(cfg: ModelConfig, spec: LayerSpec) -> Dict[str, ParamDef]:
    out: Dict[str, ParamDef] = {}
    if spec.mixer in ("attn", "hybrid"):
        out.update(_attn_defs(cfg))
    if spec.mixer in ("ssm", "hybrid"):
        out.update(_ssm_defs(cfg))
    if spec.cross:
        out.update(_attn_defs(cfg, prefix="x_"))
    if spec.mixer != "ssm":                       # pure-SSM blocks have no FFN
        out.update(_moe_defs(cfg) if spec.moe else _ffn_defs(cfg))
    return out


def cache_defs(cfg: ModelConfig, spec: LayerSpec, batch: int, cache_len: int,
               ring: bool = True) -> Dict[str, ParamDef]:
    """KV/state cache ParamDefs for one layer at serve time.

    Local-window layers get a ring buffer of ``window`` slots (bounded cache —
    what makes long_500k feasible on gemma3/hymba); full-attention layers get
    ``cache_len`` slots.
    """
    out: Dict[str, ParamDef] = {}
    KVH, hd = cfg.n_kv_heads, cfg.hd
    if spec.mixer in ("attn", "hybrid"):
        S = min(spec.window, cache_len) if (spec.window and ring) else cache_len
        out["k_cache"] = ParamDef((batch, S, KVH, hd), ("batch", "kv_seq", "kv", None), "zeros")
        out["v_cache"] = ParamDef((batch, S, KVH, hd), ("batch", "kv_seq", "kv", None), "zeros")
        if cfg.meta_tokens:
            out["k_meta"] = ParamDef((batch, cfg.meta_tokens, KVH, hd),
                                     ("batch", None, "kv", None), "zeros")
            out["v_meta"] = ParamDef((batch, cfg.meta_tokens, KVH, hd),
                                     ("batch", None, "kv", None), "zeros")
    if spec.mixer in ("ssm", "hybrid"):
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_headdim
        out["ssm_h"] = ParamDef((batch, nh, cfg.ssm_headdim, cfg.ssm_state),
                                ("batch", None, None, None), "zeros")
        out["conv_state"] = ParamDef((batch, cfg.conv_width - 1,
                                      d_in + 2 * cfg.ssm_state),
                                     ("batch", None, "ssm_in"), "zeros")
    if spec.cross:
        out["x_k_cache"] = ParamDef((batch, cfg.enc_frames, KVH, hd),
                                    ("batch", None, "kv", None), "zeros")
        out["x_v_cache"] = ParamDef((batch, cfg.enc_frames, KVH, hd),
                                    ("batch", None, "kv", None), "zeros")
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _attn_apply(p, x, cfg: ModelConfig, spec: LayerSpec, mode: str,
                pos, cache: Optional[dict], prefix: str = "",
                cross_src: Optional[jax.Array] = None, cache_len: int = 0):
    """Returns (out, new_cache_entries).

    ``cache_len`` is the serve-time cache budget (static); local-window layers
    allocate ``min(window, cache_len)`` ring slots.
    """
    B, S, d = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xn = rms_norm(x, p[prefix + "ln"], cfg.norm_eps)
    q = xn @ p[prefix + "wq"]
    if prefix + "bq" in p:
        q = q + p[prefix + "bq"]
    q = q.reshape(B, S, H, hd)
    new_cache = {}
    theta = spec.rope_theta or cfg.rope_theta

    inner_unroll = 1_000_000 if cfg.cost_probe else 1
    if cross_src is not None or (prefix and mode == "decode"):
        # cross-attention: K/V from encoder output (cached after prefill)
        if mode == "decode":
            k = cache[prefix + "k_cache"]
            v = cache[prefix + "v_cache"]
            new_cache[prefix + "k_cache"] = k   # pass-through (static enc KV)
            new_cache[prefix + "v_cache"] = v
        else:
            k = (cross_src @ p[prefix + "wk"]).reshape(B, -1, KVH, hd)
            v = (cross_src @ p[prefix + "wv"]).reshape(B, -1, KVH, hd)
            if mode == "prefill":
                new_cache[prefix + "k_cache"] = k
                new_cache[prefix + "v_cache"] = v
        out = attn_lib.chunked_attention(q, k, v, causal=False,
                                         unroll=inner_unroll)
        y = out.reshape(B, S, H * hd) @ p[prefix + "wo"]
        return y, new_cache

    k = (xn @ p[prefix + "wk"])
    v = (xn @ p[prefix + "wv"])
    if prefix + "bk" in p:
        k = k + p[prefix + "bk"]
        v = v + p[prefix + "bv"]
    k = constrain(k.reshape(B, S, KVH, hd), "act_batch", "act_seq", "act_kv",
                  None)
    v = constrain(v.reshape(B, S, KVH, hd), "act_batch", "act_seq", "act_kv",
                  None)
    if spec.causal:                                   # rope on causal LM layers
        positions = pos + jnp.arange(S)
        cos, sin = rope(positions[None], hd, theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if cfg.attn_broadcast_kv and mode != "decode" and KVH < H:
        k = jnp.repeat(k, H // KVH, axis=2)
        v = jnp.repeat(v, H // KVH, axis=2)

    if mode == "decode":
        Sc = cache[prefix + "k_cache"].shape[1]
        slot = pos % Sc
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache[prefix + "k_cache"], k.astype(cache[prefix + "k_cache"].dtype),
            slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache[prefix + "v_cache"], v.astype(cache[prefix + "v_cache"].dtype),
            slot, axis=1)
        new_cache[prefix + "k_cache"] = kc
        new_cache[prefix + "v_cache"] = vc
        if cfg.meta_tokens and prefix + "k_meta" in cache:
            new_cache[prefix + "k_meta"] = cache[prefix + "k_meta"]
            new_cache[prefix + "v_meta"] = cache[prefix + "v_meta"]
            out = _merge_meta(q, cache[prefix + "k_meta"],
                              cache[prefix + "v_meta"], kc, vc, pos, Sc)
        elif spec.window and Sc <= spec.window:       # ring buffer: bounded
            out = _ring_decode(q, kc, vc, jnp.minimum(pos + 1, Sc))
        else:
            out = attn_lib.decode_attention(q, kc, vc, kv_len=pos,
                                            window=spec.window)
    else:
        if mode == "prefill":
            Sc = min(spec.window, cache_len) if spec.window else cache_len
            new_cache[prefix + "k_cache"] = _ring_layout(k, S, Sc)
            new_cache[prefix + "v_cache"] = _ring_layout(v, S, Sc)
            if cfg.meta_tokens:
                new_cache[prefix + "k_meta"] = k[:, :cfg.meta_tokens]
                new_cache[prefix + "v_meta"] = v[:, :cfg.meta_tokens]
        if spec.window and not cfg.meta_tokens:
            out = attn_lib.local_attention(q, k, v, window=spec.window,
                                           unroll=inner_unroll)
        else:
            out = attn_lib.chunked_attention(
                q, k, v, causal=spec.causal, window=spec.window,
                prefix_len=cfg.meta_tokens, unroll=inner_unroll)
    y = constrain(out.reshape(B, S, H * hd) @ p[prefix + "wo"],
                  "act_batch", "act_seq", "act_embed")
    return y, new_cache


def _ring_layout(k: jax.Array, S: int, Sc: int) -> jax.Array:
    """Place prefill K/V of length S into an Sc-slot cache so that position p
    sits at slot ``p % Sc`` (ring invariant the decode step maintains)."""
    if S >= Sc:
        return jnp.roll(k[:, -Sc:], shift=S % Sc, axis=1)
    pad = [(0, 0)] * k.ndim
    pad[1] = (0, Sc - S)
    return jnp.pad(k, pad)


def _ring_decode(q, kc, vc, kv_len):
    """Attention over a fully-valid ring buffer (first kv_len slots valid)."""
    B, _, H, D = q.shape
    Sc, KVH = kc.shape[1], kc.shape[2]
    G = H // KVH
    qq = q.reshape(B, KVH, G, D) * (D ** -0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qq.astype(jnp.float32),
                   kc.astype(jnp.float32))
    valid = jnp.arange(Sc) < kv_len
    s = jnp.where(valid[None, None, None], s, -1e30)
    pmax = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", pmax, vc.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


def _merge_meta(q, k_meta, v_meta, kc, vc, pos, Sc):
    """Recompute decode attention over [meta ∪ ring] exactly (meta tokens are
    always attendable in Hymba).  Concatenate and mask."""
    B, _, H, D = q.shape
    KVH = kc.shape[2]
    G = H // KVH
    kk = jnp.concatenate([k_meta, kc], axis=1)
    vv = jnp.concatenate([v_meta, vc], axis=1)
    M = k_meta.shape[1]
    qq = q.reshape(B, KVH, G, D) * (D ** -0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qq.astype(jnp.float32),
                   kk.astype(jnp.float32))
    ring_valid = jnp.arange(Sc) < jnp.minimum(pos + 1, Sc)
    valid = jnp.concatenate([jnp.ones(M, bool), ring_valid])
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, vv.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


def _ssm_apply(p, x, cfg: ModelConfig, mode: str, cache: Optional[dict]):
    B, S, d = x.shape
    d_in = cfg.ssm_expand * d
    nh = d_in // cfg.ssm_headdim
    N = cfg.ssm_state
    xn = rms_norm(x, p["sln"], cfg.norm_eps)
    zx = xn @ p["w_zx"]
    z, xin = zx[..., :d_in], zx[..., d_in:]
    bc = xn @ p["w_bc"]
    dt = jax.nn.softplus((xn @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xbc = jnp.concatenate([xin, bc], axis=-1)
    new_cache = {}
    if mode == "decode":
        conv_state, yt = ssm_lib.conv1d_step(cache["conv_state"], xbc[:, 0],
                                             p["conv_w"], p["conv_b"])
        new_cache["conv_state"] = conv_state
        xs, Bm, Cm = yt[..., :d_in], yt[..., d_in:d_in + N], yt[..., d_in + N:]
        h, y = ssm_lib.ssd_step(cache["ssm_h"], xs.reshape(B, nh, cfg.ssm_headdim),
                                dt[:, 0], A, Bm, Cm)
        new_cache["ssm_h"] = h
        y = y.reshape(B, 1, d_in)
    else:
        yconv = ssm_lib.causal_conv1d(xbc, p["conv_w"], p["conv_b"])
        xs = yconv[..., :d_in].reshape(B, S, nh, cfg.ssm_headdim)
        Bm = yconv[..., d_in:d_in + N]
        Cm = yconv[..., d_in + N:]
        y, h = ssm_lib.ssd_chunked(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk,
                                   unroll=1_000_000 if cfg.cost_probe else 1)
        if mode == "prefill":
            new_cache["ssm_h"] = h
            new_cache["conv_state"] = xbc[:, -(cfg.conv_width - 1):]
        y = y.reshape(B, S, d_in)
    y = y + (xs.reshape(B, -1, nh, cfg.ssm_headdim)
             * p["Dskip"].astype(x.dtype)[None, None, :, None]).reshape(y.shape)
    y = y * jax.nn.silu(z)
    return y @ p["w_so"], new_cache


def _ffn_apply(p, x, cfg: ModelConfig, spec: LayerSpec, mode: str = "train"):
    xn = rms_norm(x, p["fln"], cfg.norm_eps)
    if spec.moe:
        B, S, d = xn.shape
        flat = xn.reshape(B * S, d)
        y = moe_ffn(flat, p["router"], p["we_gate"], p["we_up"], p["we_down"],
                    topk=cfg.topk, capacity_factor=cfg.capacity_factor,
                    dropless=(mode == "decode"),
                    groups=1 if mode == "decode" else cfg.moe_groups)
        if "ws_gate" in p:
            shared = swiglu_act(flat @ p["ws_gate"], flat @ p["ws_up"]) @ p["ws_down"]
            sig = jax.nn.sigmoid((flat @ p["ws_sig"]).astype(jnp.float32))
            y = y + (shared.astype(jnp.float32) * sig).astype(y.dtype)
        return y.reshape(B, S, d)
    if cfg.act == "swiglu":
        h = swiglu_act(xn @ p["w_gate"], xn @ p["w_up"])
    else:
        h = gelu(xn @ p["w_up"])
    h = constrain(h, "act_batch", "act_seq", "act_ff")
    return constrain(h @ p["w_down"], "act_batch", "act_seq", "act_embed")


def layer_apply(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig,
                spec: LayerSpec, mode: str = "train", pos=0,
                cache: Optional[dict] = None,
                enc_out: Optional[jax.Array] = None, cache_len: int = 0):
    """One full layer.  Returns (x_out, new_cache_dict)."""
    new_cache: Dict[str, Any] = {}
    if spec.mixer == "attn":
        y, nc = _attn_apply(p, x, cfg, spec, mode, pos, cache,
                            cache_len=cache_len)
        new_cache.update(nc)
        x = x + y
    elif spec.mixer == "ssm":
        y, nc = _ssm_apply(p, x, cfg, mode, cache)
        new_cache.update(nc)
        x = x + y
    elif spec.mixer == "hybrid":
        ya, nca = _attn_apply(p, x, cfg, spec, mode, pos, cache,
                              cache_len=cache_len)
        ys, ncs = _ssm_apply(p, x, cfg, mode, cache)
        new_cache.update(nca)
        new_cache.update(ncs)
        x = x + 0.5 * (ya + ys)
    if spec.cross:
        y, nc = _attn_apply(p, x, cfg, dataclasses.replace(spec, causal=False),
                            mode, pos, cache, prefix="x_", cross_src=enc_out,
                            cache_len=cache_len)
        new_cache.update(nc)
        x = x + y
    if spec.mixer != "ssm":
        x = x + _ffn_apply(p, x, cfg, spec, mode=mode)
    return x, new_cache
