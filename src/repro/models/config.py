"""Model / run configuration dataclasses shared by every architecture.

A single ``ModelConfig`` describes all six families (dense, MoE, SSM, hybrid,
enc-dec, VLM); family-specific fields are simply unused elsewhere.  Configs are
plain data — the model code in :mod:`repro.models` interprets them.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "pad_vocab"]


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Pad vocab to a multiple (Megatron-style) so the vocab axis shards."""
    return ((v + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None            # default d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e6

    # attention pattern
    window: Optional[int] = None               # sliding-window size (local attn)
    global_every: Optional[int] = None         # gemma3: 1 global per N layers
    causal: bool = True
    # broadcast KV to the query-head count before attention: when KVH and the
    # per-KV group G both fail to divide the TP axis but H does (qwen1.5-110b:
    # 8×8 vs 16), this is the only way the attention activations shard
    attn_broadcast_kv: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    topk: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 1          # grouped dispatch (= data-shard count)

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4

    # encoder-decoder (Whisper)
    enc_layers: int = 0
    enc_frames: int = 1500

    # VLM / hybrid extras
    img_tokens: int = 0                        # prepended patch embeddings
    meta_tokens: int = 0                       # Hymba learnable prefix

    # numerics
    norm_eps: float = 1e-6
    act: str = "swiglu"                        # swiglu | gelu
    dtype: str = "bfloat16"
    remat: str = "nothing_saveable"            # remat policy name

    # cost-probe mode (dry-run only): XLA's cost model counts a scan body
    # once regardless of trip count, so FLOP/byte/collective accounting needs
    # probes with *unrolled* scans.  0 = normal; 1/2 = inner scans fully
    # unrolled with the layer scan unrolled 1×/2× (see launch/dryrun.py).
    cost_probe: int = 0

    # ----------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve a 500k-token context with a bounded cache?"""
        return self.family in ("ssm", "hybrid") or (
            self.window is not None and self.global_every is not None)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND math."""
        d, V = self.d_model, self.padded_vocab
        hd = self.hd
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        # attention (absent for ssm family)
        if self.family != "ssm":
            qo = d * self.n_heads * hd * 2
            kv = d * self.n_kv_heads * hd * 2
            per_layer += qo + kv
        if self.family in ("ssm", "hybrid"):
            d_in = self.ssm_expand * self.d_model
            nh = d_in // self.ssm_headdim
            per_layer += d * (2 * d_in + 2 * self.ssm_state * 1 + nh) + d_in * d
        if self.n_experts:
            per_layer += self.n_experts * 3 * d * self.moe_d_ff
            per_layer += self.n_shared_experts * 3 * d * self.moe_d_ff
            per_layer += d * self.n_experts  # router
        elif self.family != "ssm":
            n_mats = 3 if self.act == "swiglu" else 2
            per_layer += n_mats * d * self.d_ff
        total = emb + self.n_layers * per_layer
        if self.enc_layers:
            enc_per = d * self.n_heads * hd * 4 + 3 * d * self.d_ff
            total += self.enc_layers * enc_per
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        routed_all = self.n_layers * self.n_experts * 3 * d * self.moe_d_ff
        routed_act = self.n_layers * self.topk * 3 * d * self.moe_d_ff
        return self.param_count() - routed_all + routed_act


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}
