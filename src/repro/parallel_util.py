"""Shared multiprocessing plumbing for every parallel driver in the repo.

One place owns the spawn-safety rules and pool construction: the sharded
reader (:mod:`repro.readers.parallel`), the TraceSet member-preparation
pool (:mod:`repro.core.diff`) and the parallel plan executor
(:mod:`repro.core.executor`) all fan work out through here, so the
serial-fallback behavior (stdin / ``-c`` / REPL ``__main__``) cannot drift
between drivers.

Pools always use the ``spawn`` start method: workers begin from a fresh
interpreter, which is the only start method that is safe after NumPy/JAX
have initialized thread pools in the parent.
"""

from __future__ import annotations

import multiprocessing as mp
import os
import threading
from typing import Any, Callable, List, Optional, Sequence, Tuple

__all__ = ["spawn_pool_ok", "spawn_unsafe_reason", "resolve_processes",
           "map_maybe_parallel", "SharedPool"]


def spawn_pool_ok() -> bool:
    """True when a ``multiprocessing`` spawn pool can start safely.

    Spawned workers re-import ``__main__`` from its ``__file__``.  When
    Python runs from stdin, ``-c``, or an interactive session, ``__main__``
    has no (or a nonexistent) ``__file__`` — the re-import then fails with
    a confusing FileNotFoundError/ModuleNotFoundError deep inside the pool
    (e.g. trying to load ``/tmp/<stdin>``).  Callers fall back to serial
    execution instead of crashing.
    """
    return spawn_unsafe_reason() is None


def spawn_unsafe_reason() -> Optional[str]:
    """Why a spawn pool cannot start, or None when it can.

    The reason string is surfaced in degradation warnings so a user who
    expected parallel execution can see exactly what blocked it.
    """
    import sys
    main = sys.modules.get("__main__")
    f = getattr(main, "__file__", None)
    if f is None:
        return ("__main__ has no importable file (Python running from "
                "stdin, -c, or an interactive session); spawn workers "
                "cannot re-import it")
    try:
        if not os.path.exists(f):
            return (f"__main__ file {f!r} does not exist on disk; spawn "
                    f"workers cannot re-import it")
    except (OSError, ValueError):  # pragma: no cover - exotic paths
        return f"__main__ file {f!r} is not a checkable path"
    return None


def resolve_processes(processes: Optional[int]) -> int:
    """Normalize a ``processes`` request: None means one worker per core."""
    if processes is None:
        return os.cpu_count() or 1
    return max(int(processes), 1)


def map_maybe_parallel(fn: Callable[[Any], Any], items: Sequence,
                       processes: Optional[int]
                       ) -> Tuple[List[Any], bool]:
    """``[fn(x) for x in items]`` through a spawn pool when that is safe
    and worth it; serially otherwise.

    Returns ``(results, pooled)`` — ``pooled`` tells the caller whether a
    pool actually ran (the sharded-reader tests assert on the fallback).
    """
    items = list(items)
    n = resolve_processes(processes) if processes is not None else 1
    if n <= 1 or len(items) <= 1 or not spawn_pool_ok():
        return [fn(a) for a in items], False
    with mp.get_context("spawn").Pool(min(n, len(items))) as pool:
        return pool.map(fn, items), True


class SharedPool:
    """A lazily-created spawn pool shared across several consumers.

    ``TraceSet.open(streaming=True, processes=N)`` hands one SharedPool to
    every member handle, so the members' work units all fan into a single
    pool — worker startup (interpreter + NumPy import) is paid once per
    session, not once per member or per terminal op.
    """

    def __init__(self, processes: Optional[int] = None):
        self.processes = resolve_processes(processes)
        self._pool = None
        self._lock = threading.Lock()

    def get(self):
        """The live pool, created on first use.  Raises RuntimeError with
        the spawn-safety reason when a pool cannot start — callers catch it
        and degrade to serial with that reason in the warning.  Safe to
        call from several threads (the trace-query service's worker lanes
        share one pool); ``Pool.map`` itself is thread-safe, only the lazy
        creation needs the lock."""
        with self._lock:
            if self._pool is None:
                reason = spawn_unsafe_reason()
                if reason is not None:
                    raise RuntimeError(reason)
                self._pool = mp.get_context("spawn").Pool(self.processes)
            return self._pool

    def map(self, fn: Callable[[Any], Any], items: Sequence) -> List[Any]:
        return self.get().map(fn, list(items))

    def close(self) -> None:
        if self._pool is not None:
            self._pool.terminate()
            self._pool.join()
            self._pool = None

    def __del__(self):  # pragma: no cover - interpreter teardown
        try:
            self.close()
        except Exception:
            pass
