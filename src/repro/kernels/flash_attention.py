"""Blocked (flash) attention as a Pallas TPU kernel.

Canonical TPU formulation: grid ``(BH, nq, nk)`` with the KV dimension
*arbitrary* (sequential) and online-softmax state carried in VMEM scratch
across KV steps.  Block sizes are MXU-aligned (multiples of 128 on the
lane dim; ``bq``/``bk`` default 128/256).  VMEM working set per step:

    q (bq×D) + k (bk×D) + v (bk×D) + acc (bq×D) + m,l (bq)  ≈ 4·bq·D f32

which for bq=bk=256, D=128 is ≈0.9 MB — far under the ~16 MB/core budget,
leaving room for the compiler to double-buffer the HBM→VMEM streams.

Causal + sliding-window masking happens on global row/col indices, so one
kernel serves full, local (gemma3), and prefix (hymba meta) attention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

# jax renamed TPUCompilerParams -> CompilerParams across 0.4/0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *, scale,
            causal, window, prefix_len, bq, bk, nk, sk_real):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, _NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32) * scale            # [bq, D]
    k = k_ref[0].astype(jnp.float32)                    # [bk, D]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]
    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    allow = cols < sk_real
    d = rows - cols
    if causal:
        allow &= d >= 0
    if window is not None:
        win_ok = d < window
        if prefix_len:
            win_ok |= (cols < prefix_len) & (d >= 0)
        allow &= win_ok
    s = jnp.where(allow, s, _NEG)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=1)
    acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        o_ref[0] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, prefix_len=0,
                    scale=None, bq: int = 128, bk: int = 256,
                    interpret: bool = True):
    """q [BH, Sq, D]; k/v [BH, Sk, D] (GQA pre-broadcast in ops.py)."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    scale = scale or D ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    qpad, kpad = nq * bq - Sq, nk * bk - Sk
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0)))

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, prefix_len=prefix_len,
                             bq=bq, bk=bk, nk=nk, sk_real=Sk)
    out = pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * bq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
