"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships as a triple:
    <name>.py — ``pl.pallas_call`` with explicit BlockSpec VMEM tiling
    ops.py    — jit'd public wrappers with shape plumbing + fallbacks
    ref.py    — pure-jnp oracles the tests assert against

Kernels (TPU is the *target*; this container validates them with
``interpret=True``):
    flash_attention — blocked causal/local GQA attention (MXU 128-aligned)
    time_bin        — Pipit's time_profile overlap histogram (the paper's
                      hottest analysis loop, §IV-B) as an events×bins tiler
    topk_gating     — MoE router top-k gating with fused softmax
"""
