"""Segment sum as a Pallas TPU kernel: the ``flat_profile`` reduction.

The hot loop of every per-name aggregate (flat profiles, per-rank busy
sums) is ``out[code[i]] += value[i]`` — a scatter-add, which TPUs hate.
Like :mod:`repro.kernels.time_bin`, the adaptation is a *one-hot matmul*:
a block of BE records builds its ``[BE, S]`` one-hot code matrix in VREGs
and lifts the ``[BE, K]`` value block onto the ``[S, K]`` accumulator on
the MXU via ``onehotᵀ @ values`` — scatter-free, fully dense.

Grid is 1-D over record blocks (sequential); the output block maps to the
same ``(S, K)`` tile every step so the kernel accumulates in place.
Padding records carry code ``-1`` and contribute nothing.  On a real TPU,
pad ``S`` to a multiple of 128 (MXU lane width) and ``K`` to 8 — in
interpret mode (CPU) any extent works.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["seg_sum"]


def _kernel(code_ref, val_ref, out_ref, *, n_seg):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    c = code_ref[...]                                    # [BE] int32 (<0 pad)
    v = val_ref[...].astype(jnp.float32)                 # [BE, K]
    be = c.shape[0]

    onehot = ((jax.lax.broadcasted_iota(jnp.int32, (be, n_seg), 1)
               == jnp.maximum(c, 0)[:, None])
              & (c >= 0)[:, None]).astype(jnp.float32)   # [BE, S]
    out_ref[...] += jax.lax.dot_general(
        onehot, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # [S, K]


def seg_sum(code, values, *, n_seg: int, be: int = 256,
            interpret: bool = True):
    """code [N] i32 (segment id per record, <0 ignored), values [N, K] f32
    → [n_seg, K] f32 per-segment column sums."""
    N = code.shape[0]
    k = values.shape[1]
    nb_blocks = max(-(-N // be), 1)
    pad = nb_blocks * be - N
    if pad:
        code = jnp.pad(code, (0, pad), constant_values=-1)
        values = jnp.pad(values, ((0, pad), (0, 0)))

    kern = functools.partial(_kernel, n_seg=n_seg)
    return pl.pallas_call(
        kern,
        grid=(nb_blocks,),
        in_specs=[
            pl.BlockSpec((be,), lambda i: (i,)),
            pl.BlockSpec((be, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_seg, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_seg, k), jnp.float32),
        interpret=interpret,
    )(code.astype(jnp.int32), values.astype(jnp.float32))
