"""Pure-jnp oracles for every Pallas kernel (the tests' ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.attention import reference_attention

__all__ = ["flash_attention_ref", "time_bin_ref", "topk_gating_ref"]


def flash_attention_ref(q, k, v, *, causal=True, window=None, prefix_len=0,
                        scale=None):
    """q/k/v [BH, S, D] — wraps the model oracle (adds/removes head axis)."""
    out = reference_attention(q[:, :, None, :], k[:, :, None, :],
                              v[:, :, None, :], causal=causal, window=window,
                              scale=scale) if prefix_len == 0 else \
        _prefix_ref(q, k, v, causal, window, prefix_len, scale)
    return out[:, :, 0, :] if prefix_len == 0 else out


def _prefix_ref(q, k, v, causal, window, prefix_len, scale):
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    scale = scale or D ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    d = qpos[:, None] - kpos[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= d >= 0
    if window is not None:
        win = d < window
        win |= (kpos[None, :] < prefix_len) & (d >= 0)
        m &= win
    s = jnp.where(m[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def time_bin_ref(start, end, func, *, n_funcs, n_bins, t0, t1):
    edges = jnp.linspace(t0, t1, n_bins + 1)
    ov = (jnp.minimum(end[:, None], edges[None, 1:])
          - jnp.maximum(start[:, None], edges[None, :-1]))
    ov = jnp.maximum(ov, 0.0)
    ov = jnp.where((func >= 0)[:, None], ov, 0.0)
    onehot = jax.nn.one_hot(jnp.maximum(func, 0), n_funcs, dtype=jnp.float32)
    return onehot.T @ ov


def topk_gating_ref(logits, k):
    vals, idx = jax.lax.top_k(logits.astype(jnp.float32), k)
    return idx.astype(jnp.int32), jax.nn.softmax(vals, axis=-1)
