"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python for correctness); on a real TPU pass
``interpret=False`` (or set ``REPRO_PALLAS_COMPILE=1``).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _flash
from .time_bin import time_bin as _time_bin
from .topk_gating import topk_gating as _topk

__all__ = ["flash_attention_gqa", "time_profile_matrix", "router_topk"]

_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


@functools.partial(jax.jit, static_argnames=("causal", "window", "prefix_len",
                                             "bq", "bk"))
def flash_attention_gqa(q, k, v, *, causal=True, window=None, prefix_len=0,
                        bq=128, bk=256):
    """GQA layout [B,S,H,D] / [B,S,KVH,D] → [B,S,H,D] via the flash kernel.

    KV heads are broadcast to the query-head count before the kernel (the
    kernel operates on a flat batch×head axis)."""
    B, S, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, -1, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, -1, D)
    out = _flash(qf, kf, vf, causal=causal, window=window,
                 prefix_len=prefix_len, bq=bq, bk=bk, interpret=_INTERPRET)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("n_funcs", "n_bins", "t0", "t1"))
def time_profile_matrix(start, end, func, rate=None, *, n_funcs, n_bins,
                        t0, t1):
    return _time_bin(start, end, func, rate, n_funcs=n_funcs, n_bins=n_bins,
                     t0=t0, t1=t1, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("k",))
def router_topk(logits, k: int):
    return _topk(logits, k, interpret=_INTERPRET)
