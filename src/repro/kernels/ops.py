"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python for correctness); on a real TPU pass
``interpret=False`` (or set ``REPRO_PALLAS_COMPILE=1``).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _flash
from .hist_bin import hist_bin as _hist_bin
from .pair_sum import pair_sum as _pair_sum
from .seg_sum import seg_sum as _seg_sum
from .time_bin import time_bin as _time_bin
from .topk_gating import topk_gating as _topk

__all__ = ["flash_attention_gqa", "time_profile_matrix", "router_topk",
           "segment_sum_matrix", "pair_sum_matrix", "histogram_counts"]

_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


@functools.partial(jax.jit, static_argnames=("causal", "window", "prefix_len",
                                             "bq", "bk"))
def flash_attention_gqa(q, k, v, *, causal=True, window=None, prefix_len=0,
                        bq=128, bk=256):
    """GQA layout [B,S,H,D] / [B,S,KVH,D] → [B,S,H,D] via the flash kernel.

    KV heads are broadcast to the query-head count before the kernel (the
    kernel operates on a flat batch×head axis)."""
    B, S, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, -1, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, -1, D)
    out = _flash(qf, kf, vf, causal=causal, window=window,
                 prefix_len=prefix_len, bq=bq, bk=bk, interpret=_INTERPRET)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("n_funcs", "n_bins", "t0", "t1",
                                             "be"))
def time_profile_matrix(start, end, func, rate=None, *, n_funcs, n_bins,
                        t0, t1, be=256):
    return _time_bin(start, end, func, rate, n_funcs=n_funcs, n_bins=n_bins,
                     t0=t0, t1=t1, be=be, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("k",))
def router_topk(logits, k: int):
    return _topk(logits, k, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("n_seg", "be"))
def segment_sum_matrix(code, values, *, n_seg, be=256):
    """code [N] (<0 ignored), values [N, K] → [n_seg, K] f32 segment sums
    (repro.kernels.seg_sum) — flat_profile / per-rank busy-sum backend."""
    return _seg_sum(code, values, n_seg=n_seg, be=be, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("n_a", "n_b", "be"))
def pair_sum_matrix(a, b, w, *, n_a, n_b, be=256):
    """a, b [N] (<0 ignored), w [N] → [n_a, n_b] f32 weighted 2-D
    scatter-add (repro.kernels.pair_sum) — comm_matrix backend."""
    return _pair_sum(a, b, w, n_a=n_a, n_b=n_b, be=be, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("n_bins", "be"))
def histogram_counts(coords, *, n_bins, be=256):
    """coords [N] f32 bin coordinates (<0 ignored) → [n_bins] f32 counts
    (repro.kernels.hist_bin) — message_histogram backend."""
    return _hist_bin(coords, n_bins=n_bins, be=be, interpret=_INTERPRET)
