"""MoE router top-k gating as a Pallas TPU kernel.

Fuses the router softmax-over-top-k with iterative argmax selection (k is
small: 4/8).  Grid is 1-D over token blocks; each step holds a ``[BT, E]``
logit tile in VMEM (BT=256, E≤128 → 128 KB) and runs k select-and-mask
sweeps in VREGs — no HBM round-trips between the k selections, which is the
fusion the XLA ``top_k`` + ``softmax`` pair doesn't do.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["topk_gating"]

_NEG = -1e30


def _kernel(logits_ref, idx_ref, gate_ref, *, k, n_experts):
    x = logits_ref[...].astype(jnp.float32)              # [BT, E]
    bt = x.shape[0]
    vals = []
    cols = jax.lax.broadcasted_iota(jnp.int32, (bt, n_experts), 1)
    for j in range(k):                                   # k is 4/8 — unrolled
        m = x.max(axis=1)                                # [BT]
        amax = jnp.argmax(x, axis=1).astype(jnp.int32)
        idx_ref[:, j] = amax
        vals.append(m)
        x = jnp.where(cols == amax[:, None], _NEG, x)
    v = jnp.stack(vals, axis=1)                          # [BT, k]
    v = v - v.max(axis=1, keepdims=True)
    ev = jnp.exp(v)
    gate_ref[...] = ev / ev.sum(axis=1, keepdims=True)


def topk_gating(logits, k: int, bt: int = 256, interpret: bool = True):
    """[T, E] f32 logits → (idx [T,k] i32, gates [T,k] f32)."""
    T, E = logits.shape
    bt = min(bt, T)
    nb = -(-T // bt)
    pad = nb * bt - T
    if pad:
        logits = jnp.pad(logits, ((0, pad), (0, 0)), constant_values=_NEG)

    kern = functools.partial(_kernel, k=k, n_experts=E)
    idx, gates = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[pl.BlockSpec((bt, E), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((bt, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb * bt, k), jnp.int32),
                   jax.ShapeDtypeStruct((nb * bt, k), jnp.float32)],
        interpret=interpret,
    )(logits.astype(jnp.float32))
    return idx[:T], gates[:T]
