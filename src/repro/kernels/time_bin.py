"""Pipit's ``time_profile`` overlap histogram as a Pallas TPU kernel.

The paper's hottest analysis loop (§IV-B): for every function call (start,
end, func) and every time bin, accumulate the overlap length into a
``[functions × bins]`` matrix.  The TPU adaptation replaces the pandas
groupby with a *one-hot matmul*: a block of BE events computes its
``[BE, NB]`` overlap matrix in VREGs, then lifts it to ``[F, NB]`` on the
MXU via ``onehot(func)ᵀ @ overlap`` — scatter-free accumulation, which is
exactly how a TPU wants to build histograms.

Grid is 1-D over event blocks (sequential), with the output block mapped to
the same ``(F, NB)`` tile every step so the kernel accumulates in place.
VMEM: BE·(3 vectors) + BE·NB + BE·F + F·NB  ≈ 1.3 MB at BE=256, NB=256,
F=128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["time_bin"]


def _kernel(start_ref, end_ref, func_ref, rate_ref, out_ref, *, n_funcs,
            n_bins, t0, bin_w):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    s = start_ref[...].astype(jnp.float32)              # [BE]
    e = end_ref[...].astype(jnp.float32)
    f = func_ref[...]                                   # [BE] int32 (<0 pad)
    r = rate_ref[...].astype(jnp.float32)               # [BE] weight/second

    be = s.shape[0]
    edges_lo = t0 + bin_w * jax.lax.broadcasted_iota(
        jnp.float32, (be, n_bins), 1)
    ov = (jnp.minimum(e[:, None], edges_lo + bin_w)
          - jnp.maximum(s[:, None], edges_lo))
    ov = jnp.maximum(ov, 0.0)                            # [BE, NB]
    ov = jnp.where((f >= 0)[:, None], ov * r[:, None], 0.0)

    onehot = (jax.lax.broadcasted_iota(jnp.int32, (be, n_funcs), 1)
              == jnp.maximum(f, 0)[:, None]).astype(jnp.float32)
    out_ref[...] += jax.lax.dot_general(
        onehot, ov, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # [F, NB]


def time_bin(start, end, func, rate=None, *, n_funcs: int, n_bins: int,
             t0: float, t1: float, be: int = 256, interpret: bool = True):
    """start/end [N] f32, func [N] i32, rate [N] (weight/sec; default 1)
    → [n_funcs, n_bins] f32 rate-weighted overlap."""
    N = start.shape[0]
    if rate is None:
        rate = jnp.ones_like(start)
    nb_blocks = max(-(-N // be), 1)
    pad = nb_blocks * be - N
    if pad:
        start = jnp.pad(start, (0, pad))
        end = jnp.pad(end, (0, pad))
        func = jnp.pad(func, (0, pad), constant_values=-1)
        rate = jnp.pad(rate, (0, pad))
    bin_w = (t1 - t0) / n_bins

    kern = functools.partial(_kernel, n_funcs=n_funcs, n_bins=n_bins,
                             t0=t0, bin_w=bin_w)
    return pl.pallas_call(
        kern,
        grid=(nb_blocks,),
        in_specs=[
            pl.BlockSpec((be,), lambda i: (i,)),
            pl.BlockSpec((be,), lambda i: (i,)),
            pl.BlockSpec((be,), lambda i: (i,)),
            pl.BlockSpec((be,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n_funcs, n_bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_funcs, n_bins), jnp.float32),
        interpret=interpret,
    )(start.astype(jnp.float32), end.astype(jnp.float32),
      func.astype(jnp.int32), rate.astype(jnp.float32))
