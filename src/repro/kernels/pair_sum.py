"""Weighted 2-D scatter-add as a Pallas TPU kernel: the ``comm_matrix``
sender×receiver reduction (also ``load_imbalance``'s function×rank sums).

``out[a[i], b[i]] += w[i]`` is a 2-D scatter — the TPU formulation is a
*pair of one-hot matmuls* fused into one: per block of BE records,
``onehot(a)ᵀ @ (onehot(b) * w)`` lands the whole ``[A, B]`` update on the
MXU in a single ``dot_general``.  Grid is 1-D over record blocks
(sequential), the output mapped to the same ``(A, B)`` tile every step so
the kernel accumulates in place.

Padding records carry ``a = -1`` and contribute nothing (the ``a``-side
mask zeroes the row; ``b`` is clamped for the iota compare).  On a real
TPU pad A and B to multiples of the MXU tile; interpret mode takes any
extent.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pair_sum"]


def _kernel(a_ref, b_ref, w_ref, out_ref, *, n_a, n_b):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]                                       # [BE] int32 (<0 pad)
    b = b_ref[...]                                       # [BE] int32
    w = w_ref[...].astype(jnp.float32)                   # [BE]
    be = a.shape[0]

    valid = (a >= 0) & (b >= 0)
    oa = ((jax.lax.broadcasted_iota(jnp.int32, (be, n_a), 1)
           == jnp.maximum(a, 0)[:, None])
          & valid[:, None]).astype(jnp.float32)          # [BE, A]
    ob = (jax.lax.broadcasted_iota(jnp.int32, (be, n_b), 1)
          == jnp.maximum(b, 0)[:, None]).astype(jnp.float32)  # [BE, B]
    out_ref[...] += jax.lax.dot_general(
        oa, ob * w[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # [A, B]


def pair_sum(a, b, w, *, n_a: int, n_b: int, be: int = 256,
             interpret: bool = True):
    """a [N] i32 (row id, <0 ignored), b [N] i32 (col id), w [N] f32
    → [n_a, n_b] f32 with w summed at (a, b)."""
    N = a.shape[0]
    nb_blocks = max(-(-N // be), 1)
    pad = nb_blocks * be - N
    if pad:
        a = jnp.pad(a, (0, pad), constant_values=-1)
        b = jnp.pad(b, (0, pad), constant_values=-1)
        w = jnp.pad(w, (0, pad))

    kern = functools.partial(_kernel, n_a=n_a, n_b=n_b)
    return pl.pallas_call(
        kern,
        grid=(nb_blocks,),
        in_specs=[
            pl.BlockSpec((be,), lambda i: (i,)),
            pl.BlockSpec((be,), lambda i: (i,)),
            pl.BlockSpec((be,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n_a, n_b), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_a, n_b), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.int32), b.astype(jnp.int32), w.astype(jnp.float32))
