"""Histogram binning as a Pallas TPU kernel: the ``message_histogram``
reduction.

Per block of BE samples the kernel floors the (pre-scaled) bin coordinate,
clamps it into ``[0, n_bins)``, builds the ``[BE, NB]`` one-hot bin matrix
in VREGs, and lifts the counts onto the ``[1, NB]`` accumulator with one
MXU ``dot_general`` against a ones-vector — the same scatter-free one-hot
matmul idiom as :mod:`repro.kernels.time_bin`.

Callers pass *bin coordinates* (sample scaled so bin ``i`` covers
``[i, i+1)``).  Feeding exact host-computed indices centered at
``idx + 0.5`` makes the in-kernel floor exact in f32 for any bin count
below 2²³ — that is how ``message_histogram`` keeps numpy-identical
counts; raw coordinates bin to f32 rounding instead.  Padding samples
carry a negative coordinate and are masked out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["hist_bin"]


def _kernel(x_ref, out_ref, *, n_bins):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)                   # [BE] (<0 pad)
    be = x.shape[0]
    idx = jnp.clip(jnp.floor(x).astype(jnp.int32), 0, n_bins - 1)

    onehot = ((jax.lax.broadcasted_iota(jnp.int32, (be, n_bins), 1)
               == idx[:, None])
              & (x >= 0.0)[:, None]).astype(jnp.float32)  # [BE, NB]
    ones = jnp.ones((1, be), jnp.float32)
    out_ref[...] += jax.lax.dot_general(
        ones, onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # [1, NB]


def hist_bin(coords, *, n_bins: int, be: int = 256, interpret: bool = True):
    """coords [N] f32 bin coordinates (<0 ignored; floor+clamp to bin id)
    → [n_bins] f32 counts."""
    N = coords.shape[0]
    nb_blocks = max(-(-N // be), 1)
    pad = nb_blocks * be - N
    if pad:
        coords = jnp.pad(coords, (0, pad), constant_values=-1.0)

    kern = functools.partial(_kernel, n_bins=n_bins)
    out = pl.pallas_call(
        kern,
        grid=(nb_blocks,),
        in_specs=[pl.BlockSpec((be,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, n_bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_bins), jnp.float32),
        interpret=interpret,
    )(coords.astype(jnp.float32))
    return out[0]
