"""Architecture registry: the 10 assigned architectures plus the paper-native
e2e driver config.  ``get_config(name)`` returns the exact published config;
``get_smoke_config(name)`` returns a reduced same-family config for CPU tests.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

_ARCHS = [
    "whisper_medium", "qwen2_moe_a2_7b", "qwen3_moe_235b_a22b",
    "qwen1_5_110b", "gemma3_27b", "qwen1_5_0_5b", "codeqwen1_5_7b",
    "hymba_1_5b", "phi_3_vision_4_2b", "mamba2_130m", "pipit_lm_100m",
]

_ALIASES = {
    "whisper-medium": "whisper_medium",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen1.5-110b": "qwen1_5_110b",
    "gemma3-27b": "gemma3_27b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "hymba-1.5b": "hymba_1_5b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "mamba2-130m": "mamba2_130m",
    "pipit-lm-100m": "pipit_lm_100m",
}

ARCH_NAMES: List[str] = list(_ALIASES.keys())


def _module(name: str):
    key = _ALIASES.get(name, name)
    if key not in _ARCHS:
        raise KeyError(f"unknown architecture {name!r}; have {ARCH_NAMES}")
    return importlib.import_module(f".{key}", __package__)


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}
