"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d=2048 16H(kv16)
per-expert ff=1408, 60 routed experts top-4 + 4 shared experts, QKV bias.

60 experts do not divide the 16-way model axis, so experts stay replicated
across TP and are FSDP-sharded on embed; per-expert ff shards TP (see
DESIGN.md §Arch-applicability).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=5632, vocab=151936, qkv_bias=True,
    n_experts=60, n_shared_experts=4, topk=4, moe_d_ff=1408,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=256, vocab=512, qkv_bias=True,
    n_experts=8, n_shared_experts=2, topk=2, moe_d_ff=32, rope_theta=1e4,
    capacity_factor=8.0,
)
