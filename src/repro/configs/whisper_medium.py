"""whisper-medium [arXiv:2212.04356]: enc-dec audio transformer.

24 encoder + 24 decoder layers, d_model=1024, 16 heads (kv=16), d_ff=4096,
vocab=51865.  Conv audio frontend is a stub: ``input_specs`` supplies
precomputed frame embeddings [B, 1500, 1024] (a learned linear projection
stands in for the conv stack).  GELU MLPs, QKV bias, tied embeddings.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=51865, enc_layers=24,
    enc_frames=1500, act="gelu", qkv_bias=True, tie_embeddings=True,
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="whisper-medium-smoke", family="encdec", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=512, enc_layers=2,
    enc_frames=24, act="gelu", qkv_bias=True, tie_embeddings=True,
    rope_theta=1e4,
)
