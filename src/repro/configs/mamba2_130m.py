"""mamba2-130m [arXiv:2405.21060]: attention-free SSD, 24L d=768,
ssm_state=128, head_dim=64 (d_inner=1536 → 24 SSD heads), vocab=50280,
tied embeddings."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=0, vocab=50280, ssm_state=128,
    ssm_headdim=64, ssm_expand=2, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-130m-smoke", family="ssm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=512, ssm_state=16,
    ssm_headdim=16, ssm_expand=2, tie_embeddings=True,
)
