"""qwen3-moe-235b-a22b: 94L d=4096 64H (GQA kv=4, head_dim=128) per-expert
ff=1536, 128 routed experts top-8, vocab=151936.  The most collective-rich
cell: experts shard 8-per-device on the 16-way model (EP) axis.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, head_dim=128, d_ff=1536, vocab=151936,
    n_experts=128, n_shared_experts=0, topk=8, moe_d_ff=1536,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe", n_layers=3, d_model=64,
    n_heads=8, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
    n_experts=16, n_shared_experts=0, topk=4, moe_d_ff=32, rope_theta=1e4,
    capacity_factor=8.0,
)
