"""gemma3-27b: dense 62L d=5376 32H (GQA kv=16, head_dim=128) d_ff=21504
vocab=262144, 5 local (1024-window, rope theta 1e4) : 1 global (theta 1e6)
attention pattern, 128k context; tied embeddings.

long_500k runnability: local layers keep a 1024-slot ring cache; only the
1-in-6 global layers hold the full 500k KV.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense", n_layers=62, d_model=5376,
    n_heads=32, n_kv_heads=16, head_dim=128, d_ff=21504, vocab=262144,
    window=1024, global_every=6, rope_theta=1e6, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma3-27b-smoke", family="dense", n_layers=7, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
    window=16, global_every=3, rope_theta=1e4, tie_embeddings=True,
)
