"""pipit-lm-100m: the paper-native end-to-end driver config — a ~100M dense
LM our trainer runs for a few hundred steps while the Pipit tracer records
the execution (examples/train_traced.py)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="pipit-lm-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=32000, tie_embeddings=True,
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="pipit-lm-100m-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=512, tie_embeddings=True,
    rope_theta=1e4,
)
