"""hymba-1.5b [arXiv:2411.13676]: hybrid 32L d=1600, 25 attn heads (GQA kv=5,
head_dim=64) in parallel with Mamba heads (ssm_state=16), d_ff=5504,
vocab=32001, 128 meta tokens (always-attendable prefix), 1024 sliding window.

Deviation noted in DESIGN.md: the paper keeps 3 full-attention layers; we use
SWA+meta everywhere (bounded cache on all layers for long_500k).
25 heads do not divide the 16-way TP axis → attention heads stay replicated;
SSM d_inner and MLP shard TP.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, head_dim=64, d_ff=5504, vocab=32001,
    ssm_state=16, ssm_headdim=64, ssm_expand=2, window=1024,
    meta_tokens=128, rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", family="hybrid", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
    ssm_state=8, ssm_headdim=16, ssm_expand=2, window=16, meta_tokens=8,
    rope_theta=1e4,
)
