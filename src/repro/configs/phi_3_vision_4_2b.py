"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct]: phi3-mini
backbone (32L d=3072 32H MHA d_ff=8192 vocab=32064) + CLIP frontend stub:
``input_specs`` provides precomputed patch embeddings [B, 144, 3072]
prepended to the token stream."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm", n_layers=32, d_model=3072,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32064, img_tokens=144,
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="phi-3-vision-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=512, img_tokens=8,
    rope_theta=1e4,
)
