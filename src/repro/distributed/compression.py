"""Gradient compression for cross-pod data parallelism.

At 512+ chips the inter-pod all-reduce of bf16 gradients dominates the
collective term (DCN links are ~10× slower than intra-pod ICI).  We provide
int8 block-quantized compression with error feedback:

    q = round(g / scale)   with per-block scale = max|g| / 127
    residual r ← g − q·scale is carried to the next step (error feedback keeps
    SGD convergence; Karimireddy et al., 2019).

The compressed all-reduce moves 4×/2× fewer bytes on the pod axis; the
decompress-accumulate happens in f32.  Used by the trainer when
``grad_compression="int8"``.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "ErrorFeedbackState",
           "compressed_psum"]

_BLOCK = 256


class ErrorFeedbackState(NamedTuple):
    residual: jax.Array


def _blocked(x: jax.Array) -> Tuple[jax.Array, int, int]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = -(-n // _BLOCK)
    pad = nb * _BLOCK - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(nb, _BLOCK), n, pad


def compress_int8(g: jax.Array, ef: Optional[ErrorFeedbackState] = None
                  ) -> Tuple[jax.Array, jax.Array, ErrorFeedbackState]:
    """g → (q int8 [nb,B], scale f32 [nb,1], new error-feedback state)."""
    gf = g.astype(jnp.float32)
    if ef is not None:
        gf = gf + ef.residual.astype(jnp.float32)
    blocks, n, pad = _blocked(gf)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    resid = (blocks - deq).reshape(-1)
    if pad:
        resid = resid[:n]
    return q, scale, ErrorFeedbackState(resid.reshape(g.shape).astype(g.dtype))


def decompress_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return deq[:n].reshape(shape).astype(dtype)


def compressed_psum(g: jax.Array, axis_name: str,
                    ef: Optional[ErrorFeedbackState] = None
                    ) -> Tuple[jax.Array, ErrorFeedbackState]:
    """int8-compressed all-reduce over ``axis_name`` (use under shard_map).

    The int8 payload is summed in int32 (values fit: ≤127×n_pods), scales are
    maxed — a conservative scheme that keeps the wire format at 1 byte/elem.
    """
    q, scale, ef2 = compress_int8(g, ef)
    qs = jax.lax.psum(q.astype(jnp.int32), axis_name)
    smax = jax.lax.pmax(scale, axis_name)
    out = decompress_int8(qs.astype(jnp.float32) / 1.0, smax, g.shape,
                          jnp.float32)
    n = jax.lax.psum(1, axis_name)
    return (out / n).astype(g.dtype), ef2


def pairwise_compressed_mean(g: jax.Array, axis_name: str, n_pods: int,
                             ef: Optional[ErrorFeedbackState] = None
                             ) -> Tuple[jax.Array, ErrorFeedbackState]:
    """Cross-pod gradient mean with an **int8 wire format** (shard_map only).

    Every pod quantizes its full gradient once and exchanges the int8 payload
    + f32 block scales with the other pods via ``ppermute`` hops (n−1 hops),
    accumulating in f32 locally.  Wire bytes/element = (n−1)·1 B vs a bf16
    all-reduce's 2·(n−1)/n·2 B — a 2× cut at n=2 (the production multi-pod
    mesh), equal at n=4; for big n use a ring reduce-scatter with per-hop
    requantization instead (future work).  Error feedback carries the
    quantization residual to the next step.
    """
    q, scale, ef2 = compress_int8(g, ef)
    acc = (q.astype(jnp.float32) * scale)
    qr, sr = q, scale
    for _ in range(n_pods - 1):
        perm = [(i, (i + 1) % n_pods) for i in range(n_pods)]
        qr = jax.lax.ppermute(qr, axis_name, perm)
        sr = jax.lax.ppermute(sr, axis_name, perm)
        acc = acc + qr.astype(jnp.float32) * sr
    out = acc.reshape(-1)[: g.size].reshape(g.shape) / n_pods
    return out.astype(jnp.float32), ef2
