"""Logical-axis → mesh-axis sharding rules + activation constraints
(MaxText-style).

Every parameter/cache leaf is declared with *logical* axes (see
``repro.models.layers.ParamDef``); a ``ShardingRules`` table maps logical axis
names to physical mesh axes.  The production mesh axes are

* ``pod``   — inter-pod data parallelism (multi-pod mesh only),
* ``data``  — intra-pod data parallel / FSDP axis,
* ``model`` — tensor/expert/sequence parallel axis.

The defaults implement FSDP(embed) × TP(heads/mlp/vocab) × EP(experts); archs
whose dimensions don't divide the axis (hymba's 25 heads, qwen2-moe's 60
experts) override single rules instead of forking the model code.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "DEFAULT_RULES", "rules_for", "logical_to_spec",
           "spec_tree", "batch_spec", "named_sharding_tree",
           "activation_sharding", "constrain", "shard_map_compat"]


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs,
                     manual_axes: Optional[frozenset] = None):
    """``jax.shard_map`` across the 0.4 → 0.8 API churn.

    jax 0.4 spells it ``shard_map(..., check_rep=, auto=)`` (``auto`` = the
    mesh axes that stay GSPMD-automatic); newer releases renamed the pair to
    ``check_vma=`` / ``axis_names=`` (the axes that are *manual*).
    ``manual_axes`` here always means the manual subset; replication
    checking is disabled either way (the int8-wire collective is
    deliberately non-replicated).
    """
    import inspect
    try:
        from jax import shard_map as _sm
    except ImportError:  # jax < 0.6
        from jax.experimental.shard_map import shard_map as _sm
    params = inspect.signature(_sm).parameters
    kw = {}
    if "check_vma" in params:
        kw["check_vma"] = False
        if manual_axes is not None and "axis_names" in params:
            kw["axis_names"] = set(manual_axes)
    else:
        kw["check_rep"] = False
        if manual_axes is not None and "auto" in params:
            kw["auto"] = frozenset(mesh.axis_names) - frozenset(manual_axes)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: Tuple[Tuple[str, Axis], ...]

    def as_dict(self) -> Dict[str, Axis]:
        return dict(self.rules)

    def override(self, **kw: Axis) -> "ShardingRules":
        d = self.as_dict()
        d.update(kw)
        return ShardingRules(tuple(d.items()))


# fsdp axes: both pod and data shard the embed dim of weights (ZeRO-3 style);
# on the single-pod mesh "pod" is absent and is dropped automatically.
_FSDP = ("pod", "data")

DEFAULT_RULES = ShardingRules((
    ("batch", _FSDP),          # activations' batch dim
    ("seq", None),
    ("embed", _FSDP),          # weights' d_model dim → FSDP
    ("embed2", None),
    ("vocab", "model"),
    ("heads", "model"),
    ("kv", None),              # few KV heads — replicate (GQA); per-arch
    ("mlp", "model"),
    ("expert_mlp", "model"),
    ("experts", "model"),      # EP
    ("ssm_in", "model"),
    ("layers", None),
    ("layers_inner", None),
    ("kv_seq", None),          # decode-cache sequence dim (long_500k: model)
    # --- activation logical axes (with_sharding_constraint targets) -------
    ("act_batch", _FSDP),
    ("act_seq", None),
    ("act_embed", None),
    ("act_heads", "model"),
    ("act_kv", None),          # per-arch: "model" when KVH divides
    ("act_kv_group", None),    # GQA carry [B,KVH,G,...]: shard KVH…
    ("act_q_group", "model"),  # …or the per-KV query group G
    ("act_ff", "model"),
    ("act_exp", "model"),
    ("act_ssm_heads", "model"),
    ("act_vocab", "model"),
))


def rules_for(cfg, mesh: Mesh, *, long_context: bool = False
              ) -> ShardingRules:
    """Per-arch rule adjustments for divisibility + shape kind."""
    r = DEFAULT_RULES
    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    if cfg.n_heads % msize:
        r = r.override(heads=None, act_heads=None)       # hymba: 25 heads
    if cfg.n_kv_heads % msize == 0:
        # enough KV heads to shard them (MHA/kv-rich GQA: qwen05, whisper,
        # codeqwen, phi3, gemma3, qwen2-moe)
        r = r.override(kv="model", act_kv="model", act_kv_group="model",
                       act_q_group=None)
    elif cfg.n_heads % msize == 0 and (cfg.n_heads // cfg.n_kv_heads) % msize:
        # neither KVH nor G divides, but H does (qwen1.5-110b 64H kv8):
        # KV is broadcast to H heads (cfg.attn_broadcast_kv) and the merged
        # head dim shards; divisibility checks guard the non-broadcast paths
        r = r.override(act_kv="model", act_kv_group="model",
                       act_q_group=None)
    if cfg.n_experts and cfg.n_experts % msize:
        r = r.override(experts=None, expert_mlp="model")  # qwen2-moe: 60 experts
    if cfg.d_model % dsize:
        r = r.override(embed=None, batch="data", act_batch="data")
    if cfg.family in ("ssm", "hybrid"):
        d_in = cfg.ssm_expand * cfg.d_model
        if d_in % msize:
            r = r.override(ssm_in=None)
        if (d_in // cfg.ssm_headdim) % msize:
            r = r.override(act_ssm_heads=None)
    if long_context:
        # batch=1: the 500k KV cache must shard on `model`.  Prefer sharding
        # KV heads (keeps attention local per head); fall back to the cache
        # sequence dim when heads don't divide.
        if cfg.n_kv_heads % msize == 0:
            r = r.override(kv="model")
        else:
            r = r.override(kv_seq="model")
    return r


def logical_to_spec(axes: Tuple[Optional[str], ...], rules: ShardingRules,
                    mesh: Mesh, shape: Optional[Tuple[int, ...]] = None) -> P:
    """Map one leaf's logical axes to a PartitionSpec, dropping mesh axes that
    are absent or that don't divide the dimension."""
    table = rules.as_dict()
    used = set()
    out = []
    for i, ax in enumerate(axes):
        phys = table.get(ax) if ax else None
        if phys is None:
            out.append(None)
            continue
        cand = (phys,) if isinstance(phys, str) else tuple(phys)
        cand = tuple(a for a in cand if a in mesh.shape and a not in used)
        if shape is not None and cand:
            n = 1
            kept = []
            for a in cand:
                if shape[i] % (n * mesh.shape[a]) == 0:
                    kept.append(a)
                    n *= mesh.shape[a]
            cand = tuple(kept)
        if not cand:
            out.append(None)
        else:
            used.update(cand)
            out.append(cand[0] if len(cand) == 1 else cand)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def spec_tree(defs, rules: ShardingRules, mesh: Mesh):
    """ParamDef tree → PartitionSpec tree (divisibility-checked)."""
    from ..models.layers import map_defs
    return map_defs(lambda d: logical_to_spec(d.axes, rules, mesh, d.shape),
                    defs)


def named_sharding_tree(defs, rules: ShardingRules, mesh: Mesh):
    from ..models.layers import map_defs
    return map_defs(
        lambda d: NamedSharding(mesh, logical_to_spec(d.axes, rules, mesh,
                                                      d.shape)), defs)


# ---------------------------------------------------------------------------
# activation sharding constraints (trace-time ambient context)
# ---------------------------------------------------------------------------
# GSPMD propagates input/param shardings, but long scan/while bodies lose
# them (the carried tuple gets one inferred sharding — measured: the
# attention online-softmax carry replicated the *global batch* per device,
# a 12× per-device FLOP blowup).  Model code calls ``constrain(x, axes…)``
# at key points; inside an ``activation_sharding(mesh, rules)`` context this
# becomes ``with_sharding_constraint``; otherwise it is a no-op, so tests
# and single-device runs are untouched.

import contextlib

_ACT_CTX: list = []


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: ShardingRules,
                        manual_axes: frozenset = frozenset()):
    """``manual_axes``: mesh axes that are *manual* in an enclosing
    shard_map (e.g. {"pod"} in the compressed-DP step) — they are stripped
    from constraint specs, and the constraint binds as a bare PartitionSpec
    against the context's abstract mesh."""
    _ACT_CTX.append((mesh, rules, manual_axes))
    try:
        yield
    finally:
        _ACT_CTX.pop()


def constrain(x, *axes):
    """Apply a logical-axis sharding constraint (no-op outside context)."""
    if not _ACT_CTX:
        return x
    mesh, rules, manual = _ACT_CTX[-1]
    spec = logical_to_spec(tuple(axes), rules, mesh, tuple(x.shape))
    if manual:
        parts = []
        for prt in spec:
            if prt is None:
                parts.append(None)
            elif isinstance(prt, tuple):
                kept = tuple(a for a in prt if a not in manual)
                parts.append(kept if len(kept) > 1 else
                             (kept[0] if kept else None))
            else:
                parts.append(None if prt in manual else prt)
        return jax.lax.with_sharding_constraint(x, P(*parts))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_spec(mesh: Mesh, batch: int) -> P:
    """Sharding for [B, ...] host inputs: batch over (pod, data) if divisible."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n = 1
    kept = []
    for a in axes:
        if batch % (n * mesh.shape[a]) == 0:
            kept.append(a)
            n *= mesh.shape[a]
    if not kept:
        return P()
    return P(tuple(kept) if len(kept) > 1 else kept[0])
