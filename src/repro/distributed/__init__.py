from .sharding import (ShardingRules, DEFAULT_RULES, rules_for, spec_tree,
                       batch_spec, logical_to_spec)
from .compression import compress_int8, decompress_int8, ErrorFeedbackState

__all__ = ["ShardingRules", "DEFAULT_RULES", "rules_for", "spec_tree",
           "batch_spec", "logical_to_spec", "compress_int8",
           "decompress_int8", "ErrorFeedbackState"]
