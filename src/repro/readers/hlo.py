"""HLO reader: a compiled XLA program becomes a Pipit trace.

This closes the paper's loop on a CPU-only container: the *planned*
execution of a real compiled multi-pod program is modeled as a per-device
event timeline that every Pipit operation (comm_matrix, comm_comp_breakdown,
time_profile, critical path) can analyze.

Model (documented in DESIGN.md §Hardware adaptation):

* the entry computation's instructions execute in text order, one logical
  "process" per modeled device (SPMD ⇒ identical programs);
* compute ops (fusion/dot/etc.) take ``max(flops/peak, bytes/hbm_bw)``
  seconds; dot FLOPs come from resolved operand shapes, byte counts from the
  result + operand shapes on the line;
* collectives take ``wire_bytes/link_bw`` and emit ring MpiSend/MpiRecv
  instants to the neighbor device; ``*-start``/``*-done`` pairs model
  *asynchronous* collectives: the transfer runs on thread 1 while compute
  continues on thread 0 — Pipit's ``comm_comp_breakdown`` then measures the
  overlap the compiler actually scheduled;
* ``while`` bodies are expanded ``trip_count`` times (parsed from the loop
  condition).

Timestamps are nanoseconds.
"""

from __future__ import annotations

import os
import re
from typing import Dict, List, Optional

import numpy as np

from ..analysis.hlostats import DTYPE_BYTES, shape_bytes
from ..analysis.roofline import HW
from ..core.constants import (ENTER, ET, LEAVE, MPI_RECV, MPI_SEND, MSG_SIZE,
                              NAME, PARTNER, PROC, TAG, THREAD, TS)
from ..core.errors import (IngestReport, TraceReadError, check_on_error,
                           require_nonempty)
from ..core.frame import EventFrame
from ..core.registry import register_reader
from ..core.trace import Trace

__all__ = ["read_hlo", "read_hlo_file"]

_DEF = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
_OPKIND = re.compile(r"=\s*(?:\([^)]*\)|[\w\[\],{}]+)?\s*([a-z][\w\-]*)\(")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_WHILE = re.compile(r"while\(.*?\)\s*,\s*condition=%?([\w\.\-]+)\s*,\s*body=%?([\w\.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "iota", "broadcast", "reshape", "transpose", "copy"}


def _line_bytes(line: str) -> int:
    return sum(shape_bytes(f"{m.group(1)}[{m.group(2)}]")
               for m in re.finditer(r"(\w+)\[([\d,]*)\]", line)
               if m.group(1) in DTYPE_BYTES)


def _dot_flops(line: str, shapes: Dict[str, tuple]) -> float:
    m = _DEF.match(line)
    if not m:
        return 0.0
    res = 1
    for x in m.group(3).split(","):
        if x:
            res *= int(x)
    ops = re.findall(r"%([\w\.\-]+)", line)
    k = 1
    c = _CONTRACT.search(line)
    if c and len(ops) >= 2:
        lhs = shapes.get(ops[1], ())
        for ci in (int(x) for x in c.group(1).split(",") if x):
            if ci < len(lhs):
                k *= lhs[ci]
    return 2.0 * res * k


def _sniff_hlo(path: str, head: str) -> bool:
    return head.lstrip().startswith("HloModule")


@register_reader("hlo", extensions=(".hlo", ".hlo.txt"), sniff=_sniff_hlo,
                 priority=30)
def read_hlo_file(path: str, on_error: str = "strict",
                  report: Optional[IngestReport] = None, **kw) -> Trace:
    """Registry entry point: read an HLO text dump from a file path.

    The HLO parser is line-regex based and inherently lenient — unmatched
    lines are simply not events — so the only hard fault is a dump with no
    ``ENTRY`` computation: ``on_error="strict"`` raises, ``"skip"``
    returns an empty trace with the fault recorded."""
    check_on_error(on_error, ("strict", "skip"))
    rpt = report if report is not None else IngestReport()
    require_nonempty(path, os.path.getsize(path), what="HLO dump")
    rpt.begin(path)
    with open(path) as f:
        text = f.read()
    try:
        t = read_hlo(text, **kw)
    except ValueError as e:
        if on_error == "strict":
            raise TraceReadError(path, str(e)) from e
        rpt.skip(path, 1, "", str(e))
        t = Trace(EventFrame(), label=kw.get("label") or path)
    else:
        rpt.add_rows(path, len(t.events))
    t._ingest = rpt
    return t


def read_hlo(hlo_text: str, *, n_procs: int = 8, label: Optional[str] = None,
             hw: Dict[str, float] = HW, group_size: int = 256,
             max_events_per_proc: int = 200_000) -> Trace:
    shapes: Dict[str, tuple] = {}
    comp_lines: Dict[str, List[str]] = {}
    comp = "?"
    entry = None
    trips: Dict[str, int] = {}
    conds: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        if line and not line.startswith(" "):
            h = _COMP_HDR.match(line.strip())
            if h and "{" in line:
                comp = h.group(1)
                comp_lines.setdefault(comp, [])
                if line.startswith("ENTRY"):
                    entry = comp
        m = _DEF.match(line)
        if m:
            shapes[m.group(1)] = tuple(int(x) for x in m.group(3).split(",") if x)
        w = _WHILE.search(line)
        if w:
            conds[w.group(2)] = w.group(1)
        comp_lines.setdefault(comp, []).append(line)
    for body, cond in conds.items():
        consts: List[int] = []
        for line in comp_lines.get(cond, []):
            consts += [int(x) for x in _CONST_INT.findall(line)]
        trips[body] = max(consts) if consts else 1

    # -- single-device schedule --------------------------------------------
    events: List[tuple] = []   # (t_enter, t_leave, name, thread, partner_sz)
    pending_async: Dict[str, float] = {}

    def emit(comp_name: str, t0: float) -> float:
        t = t0
        for line in comp_lines.get(comp_name, []):
            if len(events) >= max_events_per_proc:
                return t
            k = _OPKIND.search(line)
            if not k:
                continue
            kind = k.group(1)
            if kind in _SKIP:
                continue
            if kind == "while":
                w = _WHILE.search(line)
                if w:
                    body = w.group(2)
                    for it in range(trips.get(body, 1)):
                        t = emit(body, t)
                        if len(events) >= max_events_per_proc:
                            return t
                continue
            base = next((c for c in _COLLECTIVES if kind.startswith(c)), None)
            if base is not None:
                g = group_size
                fac = (g - 1) / g
                b = _line_bytes(line)
                wire = {"all-gather": fac * b, "all-reduce": 2 * fac * b,
                        "reduce-scatter": fac * b, "all-to-all": fac * b,
                        "collective-permute": float(b)}[base]
                dur = max(wire / hw["ici_bw"] * 1e9, 1.0)
                name = _DEF.match(line)
                nm = name.group(1) if name else base
                if kind.endswith("-start"):
                    pending_async[nm.replace("-start", "")] = t
                    events.append((t, t + dur, base, 1, wire))
                    continue
                if kind.endswith("-done"):
                    # wait until the async transfer (started earlier) is done
                    ops = re.findall(r"%([\w\.\-]+)", line)
                    st = pending_async.pop(ops[1].replace("-start", ""), t) \
                        if len(ops) > 1 else t
                    t = max(t, st + dur)
                    continue
                events.append((t, t + dur, base, 0, wire))
                t += dur
                continue
            # compute-ish op
            fl = _dot_flops(line, shapes) if kind == "dot" else 0.0
            by = _line_bytes(line)
            dur = max(fl / hw["peak_flops"] * 1e9, by / hw["hbm_bw"] * 1e9)
            if dur < 50.0 and kind not in ("dot", "fusion", "custom-call",
                                           "convolution"):
                continue   # drop sub-50ns bookkeeping ops
            if kind == "fusion" or kind == "call":
                c = _CALLS.search(line)
                if c and any(" dot(" in l for l in comp_lines.get(c.group(1), [])):
                    for l2 in comp_lines.get(c.group(1), []):
                        if " dot(" in l2:
                            fl += _dot_flops(l2, shapes)
                    dur = max(dur, fl / hw["peak_flops"] * 1e9)
            events.append((t, t + max(dur, 1.0), kind, 0, None))
            t += max(dur, 1.0)
        return t

    if entry is None:
        raise ValueError("no ENTRY computation in HLO dump")
    emit(entry, 0.0)

    # -- replicate across modeled devices + ring messages --------------------
    ts, et, name, proc, thread, partner, size = [], [], [], [], [], [], []
    for p in range(n_procs):
        for (t0, t1, nm, th, wire) in events:
            ts += [t0, t1]
            et += [ENTER, LEAVE]
            name += [nm, nm]
            proc += [p, p]
            thread += [th, th]
            partner += [-1, -1]
            size += [np.nan, np.nan]
            if wire is not None:
                mid = 0.5 * (t0 + t1)
                ts += [mid, mid + 1]
                et += ["MpiSend", "MpiRecv"]
                name += [MPI_SEND, MPI_RECV]
                proc += [p, p]
                thread += [th, th]
                partner += [(p + 1) % n_procs, (p - 1) % n_procs]
                size += [wire, wire]
    ev = EventFrame({
        TS: np.asarray(ts, np.float64), ET: np.asarray(et),
        NAME: np.asarray(name), PROC: np.asarray(proc, np.int64),
        THREAD: np.asarray(thread, np.int64),
        PARTNER: np.asarray(partner, np.int64),
        MSG_SIZE: np.asarray(size, np.float64),
        TAG: np.zeros(len(ts), np.int64),
    })
    tr = Trace(ev.sort_by([PROC, TS]), label=label or "hlo")
    tr.definitions["modeled"] = {"n_procs": n_procs, "group_size": group_size,
                                 "hw": dict(hw)}
    return tr
