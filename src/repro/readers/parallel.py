"""Parallel reading driver (paper §VI, Fig. 5 center).

Trace archives are naturally sharded per location (OTF2 keeps one event
stream per rank; our JSONL traces can be split the same way).  This driver
fans a reader over shards with ``multiprocessing`` and concatenates the
resulting frames — the paper's strategy for scaling trace ingest with cores.
"""

from __future__ import annotations

import multiprocessing as mp
import os
from typing import Callable, List, Optional, Sequence

from ..core.frame import concat
from ..core.trace import Trace

__all__ = ["read_parallel", "split_jsonl_by_process"]

_READERS = {}


def _read_one(args):
    kind, path = args
    if kind == "jsonl":
        from .jsonl import read_jsonl
        return read_jsonl(path).events
    if kind == "csv":
        from .csvreader import read_csv
        return read_csv(path).events
    if kind == "otf2j":
        from .otf2j import read_otf2_json
        return read_otf2_json(path).events
    if kind == "chrome":
        from .chrome import read_chrome
        return read_chrome(path).events
    raise ValueError(kind)


def read_parallel(paths: Sequence[str], kind: str = "jsonl",
                  processes: Optional[int] = None,
                  label: Optional[str] = None) -> Trace:
    """Read per-location shards in parallel and merge into one Trace."""
    processes = processes or min(len(paths), os.cpu_count() or 1)
    if processes <= 1 or len(paths) == 1:
        frames = [_read_one((kind, p)) for p in paths]
    else:
        with mp.get_context("spawn").Pool(processes) as pool:
            frames = pool.map(_read_one, [(kind, p) for p in paths])
    from ..core.constants import PROC, TS
    ev = concat(frames).sort_by([PROC, TS])
    return Trace(ev, label=label or f"parallel[{len(paths)}]")


def split_jsonl_by_process(path: str, out_dir: str) -> List[str]:
    """Shard a JSONL trace by process id (one file per rank)."""
    import json
    os.makedirs(out_dir, exist_ok=True)
    handles = {}
    try:
        with open(path) as f:
            for line in f:
                if not line.strip():
                    continue
                p = json.loads(line).get("proc", 0)
                if p not in handles:
                    handles[p] = open(os.path.join(out_dir, f"rank_{p}.jsonl"),
                                      "w")
                handles[p].write(line)
    finally:
        for h in handles.values():
            h.close()
    return [os.path.join(out_dir, f"rank_{p}.jsonl")
            for p in sorted(handles)]
