"""Parallel reading driver (paper §VI, Fig. 5 center).

Trace archives are naturally sharded per location (OTF2 keeps one event
stream per rank; our JSONL traces can be split the same way).  This driver
fans a reader over shards with ``multiprocessing`` and concatenates the
resulting frames — the paper's strategy for scaling trace ingest with cores.

Format dispatch goes through the unified reader registry
(:mod:`repro.core.registry`), so ``kind="auto"`` sniffs each shard and any
user-registered format works here too.  When the caller (typically a lazy
query plan, see :mod:`repro.core.query`) restricts processes, shards whose
registered ``shard_procs`` hint proves they cannot contribute are *skipped
before parsing* — predicate pushdown into the reader.
"""

from __future__ import annotations

import os
from typing import List, Optional, Sequence, Set, Tuple

import numpy as np

from ..core.constants import (DERIVED_COLUMNS, ENTER, ET, INSTANT, LEAVE,
                              NAME, PROC, TS)
from ..core.frame import Categorical, EventFrame, concat
from ..core.registry import resolve_reader
from ..core.trace import Trace
# spawn-safety rules and pool construction live in repro.parallel_util so
# every parallel driver (this reader, TraceSet preparation, the plan
# executor) shares one serial-fallback behavior; spawn_pool_ok is
# re-exported here because it is this module's historical public home
from ..parallel_util import map_maybe_parallel, spawn_pool_ok

__all__ = ["read_parallel", "open_many", "select_shards",
           "split_jsonl_by_process", "spawn_pool_ok"]


def _ensure_registered() -> None:
    # Importing the reader modules populates the registry.  Needed both in
    # the parent (when only this module was imported) and in spawned pool
    # workers, which start from a fresh interpreter.
    from . import chrome, csvreader, hlo, jsonl, otf2j  # noqa: F401


def _read_one(args) -> EventFrame:
    kind, path, reader_kwargs = args
    _ensure_registered()
    ev = resolve_reader(path, kind).read(path, **(reader_kwargs or {})).events
    # per-shard derived structure (pack sidecars) indexes the shard's own
    # rows; the merged sort below invalidates it — strip before concat
    return ev.drop(*DERIVED_COLUMNS)


def select_shards(paths: Sequence[str], kind: str = "auto",
                  procs: Optional[Set[int]] = None,
                  proc_bounds: Optional[Tuple[float, float]] = None
                  ) -> List[str]:
    """Shards that can contribute events under the given process restriction.

    A shard is kept when its reader provides no ``shard_procs`` hint (unknown
    contents are never skipped) or when any hinted process id satisfies both
    the explicit set and the [lo, hi] bounds.
    """
    paths = list(paths)
    if procs is None and proc_bounds is None:
        return paths
    _ensure_registered()
    keep: List[str] = []
    for p in paths:
        spec = resolve_reader(p, kind)
        hint = spec.shard_procs(p) if spec.shard_procs else None
        if hint is None:
            keep.append(p)
            continue
        if any((procs is None or q in procs)
               and (proc_bounds is None
                    or proc_bounds[0] <= q <= proc_bounds[1])
               for q in hint):
            keep.append(p)
    return keep


def read_parallel(paths: Sequence[str], kind: str = "auto",
                  processes: Optional[int] = None,
                  label: Optional[str] = None,
                  procs: Optional[Set[int]] = None,
                  proc_bounds: Optional[Tuple[float, float]] = None,
                  **reader_kwargs) -> Trace:
    """Read per-location shards in parallel and merge into one Trace.

    Extra keyword arguments are forwarded to every per-shard reader (e.g.
    ``n_procs=...`` for HLO shards).
    """
    _ensure_registered()
    sel = select_shards(paths, kind, procs=procs, proc_bounds=proc_bounds)
    if not sel:
        # canonical empty frame: analysis ops on a fully-pruned read must
        # see the uniform columns, not a column-less frame
        empty = EventFrame({
            TS: np.asarray([], np.int64),
            ET: Categorical.from_codes(np.asarray([], np.int32),
                                       np.asarray([ENTER, LEAVE, INSTANT])),
            NAME: Categorical.from_codes(np.asarray([], np.int32),
                                         np.asarray([], dtype=object)),
            PROC: np.asarray([], np.int64),
        })
        return Trace(empty, label=label or "parallel[0]")
    processes = processes or min(len(sel), os.cpu_count() or 1)
    args = [(kind, p, reader_kwargs) for p in sel]
    frames, _pooled = map_maybe_parallel(_read_one, args, processes)
    ev = concat(frames).sort_by([PROC, TS])
    return Trace(ev, label=label or f"parallel[{len(sel)}]")


def _open_one(args) -> Trace:
    kind, item, reader_kwargs = args
    _ensure_registered()
    return Trace.open(item, format=kind, **(reader_kwargs or {}))


def open_many(paths: Sequence, kind: str = "auto",
              processes: Optional[int] = None,
              **reader_kwargs) -> List[Trace]:
    """Open N *whole traces* (batched ingest for TraceSet / cross-run diffs).

    Unlike :func:`read_parallel`, which merges per-location shards of ONE
    trace, this returns one Trace per item.  Each item goes through the
    reader registry exactly like ``Trace.open`` (format sniffed per member
    when ``kind="auto"``) and may itself be a list of shard paths, which is
    read through the sharded driver.  ``processes`` > 1 opens members in a
    ``multiprocessing`` pool (spawn: the calling script needs the standard
    ``if __name__ == "__main__"`` guard); the default is serial, since
    members opened for comparison are often already in memory or small.
    """
    _ensure_registered()
    if isinstance(paths, (str, os.PathLike)):
        paths = [paths]  # a bare path must not be iterated char-by-char
    items = list(paths)
    args = [(kind, os.fspath(p) if isinstance(p, (str, os.PathLike)) else
             [os.fspath(q) for q in p], reader_kwargs) for p in items]
    if not args:
        return []
    traces, _pooled = map_maybe_parallel(_open_one, args, processes)
    return traces


def split_jsonl_by_process(path: str, out_dir: str) -> List[str]:
    """Shard a JSONL trace by process id (one file per rank)."""
    import json
    os.makedirs(out_dir, exist_ok=True)
    handles = {}
    try:
        with open(path) as f:
            for line in f:
                if not line.strip():
                    continue
                p = json.loads(line).get("proc", 0)
                if p not in handles:
                    handles[p] = open(os.path.join(out_dir, f"rank_{p}.jsonl"),
                                      "w")
                handles[p].write(line)
    finally:
        for h in handles.values():
            h.close()
    return [os.path.join(out_dir, f"rank_{p}.jsonl")
            for p in sorted(handles)]
