"""Chrome Trace Format reader (paper's Nsight-Systems / PyTorch-profiler path).

CTF is the JSON envelope both the PyTorch profiler and Nsight exports emit:
``{"traceEvents": [{"ph": "B"|"E"|"X"|"i", "ts": us, "dur": us, "pid": ..,
"tid": .., "name": .., "args": {..}}, ...]}``.  ``X`` (complete) events are
split into Enter/Leave pairs; ``pid``→Process, ``tid``→Thread.  Message /
flow events (``ph`` in s/t/f) become MpiSend/MpiRecv instants so the comm
ops work on GPU traces too.
"""

from __future__ import annotations

import json
from typing import Optional

import numpy as np

from ..core.constants import (ENTER, ET, INSTANT, LEAVE, MPI_RECV, MPI_SEND,
                              MSG_SIZE, NAME, PARTNER, PROC, TAG, THREAD, TS)
from ..core.frame import Categorical, EventFrame
from ..core.registry import register_reader
from ..core.trace import Trace

_ET_CATS = np.asarray([ENTER, LEAVE, INSTANT])


def _sniff_chrome(path: str, head: str) -> bool:
    h = head.lstrip()
    if not h.startswith(("{", "[")):
        return False
    if '"traceEvents"' in head:
        return True
    return h.startswith("[") and '"ph"' in head


@register_reader("chrome", extensions=(".json",), sniff=_sniff_chrome,
                 priority=20)
def read_chrome(path_or_buf, label: Optional[str] = None) -> Trace:
    if isinstance(path_or_buf, str):
        with open(path_or_buf) as f:
            doc = json.load(f)
        label = label or path_or_buf
    else:
        doc = json.load(path_or_buf)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc

    # normalize pids to dense process ids
    pids = sorted({e.get("pid", 0) for e in events})
    pid_of = {p: i for i, p in enumerate(pids)}

    ts, et, names, procs, threads = [], [], [], [], []
    sizes, partners, tags = [], [], []
    has_msg = False

    def emit(t, code, name, pid, tid, size=np.nan, partner=-1, tag=0):
        ts.append(int(t * 1000))  # us -> ns
        et.append(code)
        names.append(name)
        procs.append(pid_of.get(pid, 0))
        threads.append(tid)
        sizes.append(size)
        partners.append(partner)
        tags.append(tag)

    for e in events:
        ph = e.get("ph", "X")
        name = str(e.get("name", ""))
        pid = e.get("pid", 0)
        tid = int(e.get("tid", 0) or 0)
        t = float(e.get("ts", 0.0))
        args = e.get("args") or {}
        if ph == "X":
            dur = float(e.get("dur", 0.0))
            emit(t, 0, name, pid, tid)
            emit(t + dur, 1, name, pid, tid)
        elif ph == "B":
            emit(t, 0, name, pid, tid)
        elif ph == "E":
            emit(t, 1, name, pid, tid)
        elif ph in ("i", "I", "n"):
            emit(t, 2, name, pid, tid)
        elif ph == "s":  # flow start == send
            has_msg = True
            emit(t, 2, MPI_SEND, pid, tid, size=float(args.get("size", 0.0)),
                 partner=int(args.get("partner", -1)), tag=int(e.get("id", 0)))
        elif ph in ("t", "f"):  # flow step/finish == recv
            has_msg = True
            emit(t, 2, MPI_RECV, pid, tid, size=float(args.get("size", 0.0)),
                 partner=int(args.get("partner", -1)), tag=int(e.get("id", 0)))
        # metadata events (ph == "M") are folded into definitions
    ev = EventFrame({
        TS: np.asarray(ts, np.int64),
        ET: Categorical.from_codes(np.asarray(et, np.int32), _ET_CATS),
        NAME: np.asarray(names, dtype=object),
        PROC: np.asarray(procs, np.int64),
        THREAD: np.asarray(threads, np.int64),
    })
    if has_msg:
        ev[MSG_SIZE] = np.asarray(sizes)
        ev[PARTNER] = np.asarray(partners, np.int64)
        ev[TAG] = np.asarray(tags, np.int64)
    defs = {"pids": pids}
    return Trace(ev, definitions=defs, label=label)
