"""Chrome Trace Format reader (paper's Nsight-Systems / PyTorch-profiler path).

CTF is the JSON envelope both the PyTorch profiler and Nsight exports emit:
``{"traceEvents": [{"ph": "B"|"E"|"X"|"i", "ts": us, "dur": us, "pid": ..,
"tid": .., "name": .., "args": {..}}, ...]}``.  ``X`` (complete) events are
split into Enter/Leave pairs; ``pid``→Process, ``tid``→Thread.  Message /
flow events (``ph`` in s/t/f) become MpiSend/MpiRecv instants so the comm
ops work on GPU traces too.
"""

from __future__ import annotations

import json
import os
from typing import Iterator, List, Optional

import numpy as np

from ..core.constants import (ENTER, ET, INSTANT, LEAVE, MPI_RECV, MPI_SEND,
                              MSG_SIZE, NAME, PARTNER, PROC, TAG, THREAD, TS)
from ..core.errors import (IngestReport, TraceReadError, check_on_error,
                           require_nonempty)
from ..core.frame import Categorical, EventFrame, optimize_dtypes
from ..core.registry import (PlanHints, ProcSpan, even_groups,
                             register_chunked, register_reader,
                             register_units)
from ..core.trace import Trace

_ET_CATS = np.asarray([ENTER, LEAVE, INSTANT])


def _sniff_chrome(path: str, head: str) -> bool:
    h = head.lstrip()
    if not h.startswith(("{", "[")):
        return False
    if '"traceEvents"' in head:
        return True
    return h.startswith("[") and '"ph"' in head


def _dispatch_event(e: dict, emit) -> None:
    """The single CTF phase-code switch: decode one event object into row
    emissions.  Shared by the whole-file and chunked readers so a new
    ``ph`` mapping can never land in only one path.  ``emit(t_us, code,
    name, pid, tid, size=..., partner=..., tag=...)`` receives the *raw*
    pid — callers densify/filter."""
    ph = e.get("ph", "X")
    name = str(e.get("name", ""))
    pid = e.get("pid", 0)
    tid = int(e.get("tid", 0) or 0)
    t = float(e.get("ts", 0.0))
    args = e.get("args") or {}
    if ph == "X":
        dur = float(e.get("dur", 0.0))
        emit(t, 0, name, pid, tid)
        emit(t + dur, 1, name, pid, tid)
    elif ph == "B":
        emit(t, 0, name, pid, tid)
    elif ph == "E":
        emit(t, 1, name, pid, tid)
    elif ph in ("i", "I", "n"):
        emit(t, 2, name, pid, tid)
    elif ph == "s":  # flow start == send
        emit(t, 2, MPI_SEND, pid, tid, size=float(args.get("size", 0.0)),
             partner=int(args.get("partner", -1)), tag=int(e.get("id", 0)))
    elif ph in ("t", "f"):  # flow step/finish == recv
        emit(t, 2, MPI_RECV, pid, tid, size=float(args.get("size", 0.0)),
             partner=int(args.get("partner", -1)), tag=int(e.get("id", 0)))
    # metadata events (ph == "M") are folded into definitions


@register_reader("chrome", extensions=(".json",), sniff=_sniff_chrome,
                 priority=20)
def read_chrome(path_or_buf, label: Optional[str] = None,
                on_error: str = "strict",
                report: Optional[IngestReport] = None) -> Trace:
    check_on_error(on_error, ("strict", "skip"))
    rpt = report if report is not None else IngestReport()
    is_path = isinstance(path_or_buf, str)
    src = path_or_buf if is_path else "<buffer>"
    if is_path:
        require_nonempty(path_or_buf, os.path.getsize(path_or_buf),
                         what="chrome trace")
        label = label or path_or_buf
    rpt.begin(src)
    events = None
    try:
        if is_path:
            with open(path_or_buf) as f:
                doc = json.load(f)
        else:
            doc = json.load(path_or_buf)
        events = doc["traceEvents"] if isinstance(doc, dict) else doc
        if not isinstance(events, list):
            raise ValueError("traceEvents is not an array")
    except (ValueError, KeyError) as e:
        if on_error == "strict":
            locus = (f"line {e.lineno}"
                     if isinstance(e, json.JSONDecodeError) else None)
            reason = ("no traceEvents array" if isinstance(e, KeyError)
                      else f"invalid JSON ({e})")
            raise TraceReadError(src, reason, locus=locus) from e
        events = None
    if events is None:
        # skip mode on a damaged document: salvage the longest valid event
        # prefix with the incremental decoder (the same machinery the
        # chunked reader uses, so both paths keep identical survivors)
        if is_path:
            events = list(_iter_array_items(path_or_buf, on_error="skip",
                                            report=rpt))
        else:
            try:
                path_or_buf.seek(0)
                events = list(_iter_array_items_f(
                    path_or_buf, src, on_error="skip", report=rpt))
            except (OSError, ValueError, AttributeError):
                events = []

    # normalize pids to dense process ids (non-dict entries can't carry one;
    # the dispatch loop below raises/skips them with a per-event locus)
    pids = sorted({e.get("pid", 0) for e in events if isinstance(e, dict)})
    pid_of = {p: i for i, p in enumerate(pids)}

    ts, et, names, procs, threads = [], [], [], [], []
    sizes, partners, tags = [], [], []
    has_msg = False

    def emit(t, code, name, pid, tid, size=np.nan, partner=-1, tag=0):
        # round, don't truncate: CTF timestamps are float µs, and ns values
        # that went through a /1000 round-trip sit epsilon below the integer
        nonlocal has_msg
        p = pid_of.get(pid, 0)  # before any append — emits stay atomic
        if not np.isnan(size):  # only flow (message) events carry a size
            has_msg = True
        ts.append(round(t * 1000))  # us -> ns
        et.append(code)
        names.append(name)
        procs.append(p)
        threads.append(tid)
        sizes.append(size)
        partners.append(partner)
        tags.append(tag)

    for i, e in enumerate(events):
        try:
            if not isinstance(e, dict):
                raise ValueError("not an object")
            _dispatch_event(e, emit)
        except (ValueError, TypeError) as exc:
            if on_error == "strict":
                raise TraceReadError(src, f"malformed trace event ({exc})",
                                     locus=f"event {i}") from exc
            rpt.skip(src, 1, f"event {i}", str(exc))
    rpt.add_rows(src, len(ts))
    ev = EventFrame({
        TS: np.asarray(ts, np.int64),
        ET: Categorical.from_codes(np.asarray(et, np.int32), _ET_CATS),
        NAME: np.asarray(names, dtype=object),
        PROC: np.asarray(procs, np.int64),
        THREAD: np.asarray(threads, np.int64),
    })
    if has_msg:
        ev[MSG_SIZE] = np.asarray(sizes)
        ev[PARTNER] = np.asarray(partners, np.int64)
        ev[TAG] = np.asarray(tags, np.int64)
    defs = {"pids": pids}
    t = Trace(optimize_dtypes(ev), definitions=defs, label=label)
    t._ingest = rpt
    return t


# ---------------------------------------------------------------------------
# chunked (out-of-core) reading
# ---------------------------------------------------------------------------

def _iter_array_items(path: str, block: int = 1 << 16,
                      on_error: str = "strict",
                      report: Optional[IngestReport] = None
                      ) -> Iterator[dict]:
    """Incrementally decode the JSON array of trace events in ``path``
    without loading the document: scan to the ``traceEvents`` array (or a
    bare top-level array), then ``raw_decode`` one object at a time from a
    bounded text buffer.

    A damaged tail (truncation mid-event, bit-flipped body, appended
    garbage) raises :class:`TraceReadError` under ``on_error="strict"``;
    under ``"skip"`` the valid prefix is yielded and the undecodable
    remainder is recorded as ``bytes_lost`` in ``report``.

    ``errors="replace"`` keeps non-UTF-8 garbage from raising out of the
    raw ``read()``: the replacement characters fail ``raw_decode`` instead,
    which routes through ``damaged()`` with a byte locus under both
    policies."""
    with open(path, errors="replace") as f:
        yield from _iter_array_items_f(f, path, block, on_error, report)


def _iter_array_items_f(f, path: str, block: int = 1 << 16,
                        on_error: str = "strict",
                        report: Optional[IngestReport] = None
                        ) -> Iterator[dict]:
    dec = json.JSONDecoder()

    def damaged(reason: str, lost: int) -> None:
        locus = f"byte ~{max(f.tell() - lost, 0)}"
        if on_error == "strict":
            raise TraceReadError(path, reason, locus=locus)
        if report is not None:
            report.lose_bytes(path, lost, locus, reason)

    buf = f.read(block)
    key = '"traceEvents"'
    if buf.lstrip().startswith("["):
        start = buf.find("[")
    else:
        # scan to the key with a bounded sliding window (keep only a
        # key-length tail across reads — a large metadata prefix must
        # not accumulate in the reader that exists to bound RSS)...
        while True:
            k = buf.find(key)
            if k >= 0:
                buf = buf[k + len(key):]
                break
            buf = buf[-len(key):]
            nxt = f.read(block)
            if not nxt:
                damaged("no traceEvents array found", 0)
                return
            buf += nxt
        # ...then to the opening bracket (only ':' and whitespace can
        # sit between the key and its array)
        while True:
            start = buf.find("[")
            if start >= 0:
                break
            nxt = f.read(block)
            if not nxt:
                damaged("traceEvents key with no array (truncated file?)",
                        len(buf))
                return
            buf = nxt
    buf = buf[start + 1:]
    pos = 0
    while True:
        # skip separators
        while True:
            stripped = buf[pos:].lstrip()
            pos = len(buf) - len(stripped)
            if stripped.startswith(","):
                pos += 1
                continue
            break
        if pos < len(buf) and buf[pos] == "]":
            return
        try:
            obj, end = dec.raw_decode(buf, pos)
        except ValueError:
            nxt = f.read(block)
            if not nxt:
                lost = len(buf) - pos
                if lost:
                    damaged("truncated or corrupt traceEvents array "
                            f"({lost} undecodable bytes at end of data)",
                            lost)
                else:
                    # clean cut between events: nothing undecodable, but
                    # the closing bracket never arrived
                    damaged("truncated traceEvents array "
                            "(missing closing bracket)", 0)
                return
            buf = buf[pos:] + nxt
            pos = 0
            continue
        yield obj
        pos = end
        if pos > block:
            buf = buf[pos:]
            pos = 0


def _decode_batch(batch: List[dict], hints: Optional[PlanHints],
                  pid_of: dict, path: str = "<buffer>",
                  on_error: str = "strict",
                  report: Optional[IngestReport] = None,
                  base_idx: int = 0) -> Optional[EventFrame]:
    """One uniform-column EventFrame from a batch of CTF event objects,
    with pids densified through ``pid_of`` — the same sorted-dense mapping
    the whole-file reader builds, so chunked and in-memory reads agree.
    Malformed event objects follow the reader ``on_error`` contract; the
    skip decision precedes pushdown, so survivors match the eager read."""
    tw = hints.time_window if hints is not None else None
    check_proc = hints is not None and (hints.procs is not None
                                        or hints.proc_bounds is not None)
    ts, et, names, procs, threads = [], [], [], [], []
    sizes, partners, tags = [], [], []

    def emit(t, code, name, pid, tid, size=np.nan, partner=-1, tag=0):
        p = pid_of.get(pid, 0)
        if check_proc and not hints.admits_proc(p):
            return
        v = round(t * 1000)
        if tw is not None and not (tw[0] <= v <= tw[1]):
            return
        ts.append(v)
        et.append(code)
        names.append(name)
        procs.append(p)
        threads.append(tid)
        sizes.append(size)
        partners.append(partner)
        tags.append(tag)

    for i, e in enumerate(batch):
        try:
            if not isinstance(e, dict):
                raise ValueError("not an object")
            _dispatch_event(e, emit)
        except (ValueError, TypeError) as exc:
            if on_error == "strict":
                raise TraceReadError(path, f"malformed trace event ({exc})",
                                     locus=f"event {base_idx + i}") from exc
            if report is not None:
                report.skip(path, 1, f"event {base_idx + i}", str(exc))
    if report is not None:
        report.add_rows(path, len(ts))
    if not ts:
        return None
    ev = EventFrame({
        TS: np.asarray(ts, np.int64),
        ET: Categorical.from_codes(np.asarray(et, np.int32), _ET_CATS),
        NAME: np.asarray(names, dtype=object),
        PROC: np.asarray(procs, np.int64),
        THREAD: np.asarray(threads, np.int64),
        MSG_SIZE: np.asarray(sizes),
        PARTNER: np.asarray(partners, np.int64),
        TAG: np.asarray(tags, np.int64),
    })
    return optimize_dtypes(ev)


@register_chunked("chrome")
def iter_chunks_chrome(path: str, chunk_rows: int,
                       hints: Optional[PlanHints] = None,
                       label: Optional[str] = None,
                       known_pids: Optional[tuple] = None,
                       on_error: str = "strict",
                       report: Optional[IngestReport] = None
                       ) -> Iterator[EventFrame]:
    """Stream a Chrome trace in bounded chunks via incremental JSON array
    decoding (an ``X`` event expands to two rows, so chunks may slightly
    exceed ``chunk_rows``).

    A cheap pre-pass collects the pid set so pids densify to exactly the
    sorted 0..N-1 mapping the whole-file reader uses — Process ids (and
    therefore pushdown and per-process results) are identical either way,
    at the cost of decoding the stream twice; memory stays bounded.
    ``known_pids`` (the sorted raw pid tuple) skips that pre-pass — the
    parallel unit planner runs it once and shares the table with every
    worker.  ``on_error="skip"`` salvages the valid event prefix of a
    damaged file (losses counted in ``report``); the pre-pass runs with
    the same policy but stays silent so counts reflect one pass."""
    check_on_error(on_error, ("strict", "skip"))
    require_nonempty(path, os.path.getsize(path), what="chrome trace")
    if report is not None:
        report.begin(path)
    if known_pids is not None:
        pids = set(known_pids)
    else:
        pids = set()
        for obj in _iter_array_items(path, on_error=on_error):
            if isinstance(obj, dict):
                pids.add(obj.get("pid", 0))
    pid_of = {p: i for i, p in enumerate(sorted(pids))}
    batch: List[dict] = []
    seen = 0
    for obj in _iter_array_items(path, on_error=on_error, report=report):
        batch.append(obj)
        if len(batch) >= max(chunk_rows // 2, 1):
            ev = _decode_batch(batch, hints, pid_of, path, on_error,
                               report, seen)
            seen += len(batch)
            if ev is not None:
                yield ev
            batch = []
    if batch:
        ev = _decode_batch(batch, hints, pid_of, path, on_error,
                           report, seen)
        if ev is not None:
            yield ev


@register_units("chrome")
def plan_units_chrome(path: str, n_units: int):
    """Per-pid work units: one pid pre-pass (paid once, in the planner)
    yields the dense process table; units are contiguous groups of dense
    process ids, each carrying the shared pid table so workers skip their
    own pre-pass.  Workers still each decode the JSON stream — the win is
    in row assembly and aggregation, not the decode."""
    pids = set()
    try:
        for obj in _iter_array_items(path):
            if isinstance(obj, dict):
                pids.add(obj.get("pid", 0))
    except TraceReadError:
        # damaged file: no parallel plan — the serial path owns the
        # strict-raise / skip-salvage decision
        return None
    raw = tuple(sorted(pids))
    n = max(min(int(n_units), len(raw)), 1)
    if n <= 1:
        return None
    extra = (("known_pids", raw),)
    return [ProcSpan(path, procs, extra)
            for procs in even_groups(range(len(raw)), n)]


def write_chrome(trace_or_events, path: str) -> None:
    """Serialize a trace to Chrome Trace Format (inverse of
    :func:`read_chrome`): B/E phase events preserve exact event order,
    flow events carry the message instants."""
    ev = getattr(trace_or_events, "events", trace_or_events)
    cols = ev.columns
    ts = np.asarray(ev[TS], np.int64)
    et = ev[ET]
    names = ev[NAME]
    procs = np.asarray(ev[PROC], np.int64)
    threads = (np.asarray(ev[THREAD], np.int64) if THREAD in cols
               else np.zeros(len(ev), np.int64))
    sizes = np.asarray(ev[MSG_SIZE], np.float64) if MSG_SIZE in cols else None
    partners = np.asarray(ev[PARTNER], np.int64) if PARTNER in cols else None
    tags = np.asarray(ev[TAG], np.int64) if TAG in cols else None
    with open(path, "w") as f:
        f.write('{"traceEvents": [\n')
        first = True
        for i in range(len(ev)):
            e = et[i]
            nm = str(names[i])
            d = {"name": nm, "pid": int(procs[i]), "tid": int(threads[i]),
                 "ts": ts[i] / 1000.0}
            if e == ENTER:
                d["ph"] = "B"
            elif e == LEAVE:
                d["ph"] = "E"
            elif nm == MPI_SEND and partners is not None:
                d["ph"] = "s"
                d["id"] = int(tags[i])
                d["args"] = {"size": float(np.nan_to_num(sizes[i])),
                             "partner": int(partners[i])}
            elif nm == MPI_RECV and partners is not None:
                d["ph"] = "f"
                d["id"] = int(tags[i])
                d["args"] = {"size": float(np.nan_to_num(sizes[i])),
                             "partner": int(partners[i])}
            else:
                d["ph"] = "i"
            f.write(("" if first else ",\n") + json.dumps(d))
            first = False
        f.write("\n]}\n")

