"""CSV trace reader — the paper's Fig. 1 format.

Header names are matched case-insensitively after stripping; a timestamp
header of ``Timestamp (s)`` / ``(ms)`` / ``(us)`` is converted to ns.  Extra
columns are kept verbatim (numeric when they parse as floats).
"""

from __future__ import annotations

import io
from typing import Optional

import numpy as np

from ..core.constants import ET, MSG_SIZE, NAME, PARTNER, PROC, TAG, THREAD, TS
from ..core.frame import Categorical, EventFrame
from ..core.registry import rank_shard_procs, register_reader
from ..core.trace import Trace

_UNIT = {"(s)": 1e9, "(ms)": 1e6, "(us)": 1e3, "(ns)": 1.0}

_CANON = {
    "timestamp": TS, "time": TS, "event type": ET, "event": ET, "name": NAME,
    "function": NAME, "process": PROC, "rank": PROC, "thread": THREAD,
    "msg size": MSG_SIZE, "size": MSG_SIZE, "partner": PARTNER, "tag": TAG,
}


def _canon_header(h: str):
    h = h.strip()
    scale = 1.0
    low = h.lower()
    for u, s in _UNIT.items():
        if low.endswith(u):
            low = low[: -len(u)].strip()
            scale = s
    return _CANON.get(low, h), scale


def _sniff_csv(path: str, head: str) -> bool:
    line = head.splitlines()[0] if head else ""
    if line.count(",") < 2:
        return False
    toks = [_canon_header(t)[0] for t in line.split(",")]
    return TS in toks and (ET in toks or NAME in toks)


@register_reader("csv", extensions=(".csv",), sniff=_sniff_csv,
                 shard_procs=rank_shard_procs)
def read_csv(path_or_buf, label: Optional[str] = None) -> Trace:
    if isinstance(path_or_buf, str):
        with open(path_or_buf) as f:
            text = f.read()
        label = label or path_or_buf
    else:
        text = path_or_buf.read()
    lines = [ln for ln in text.splitlines() if ln.strip()]
    if not lines:
        return Trace(EventFrame(), label=label)
    raw_headers = [h for h in lines[0].split(",")]
    headers, scales = [], []
    for h in raw_headers:
        name, scale = _canon_header(h)
        headers.append(name)
        scales.append(scale)
    ncol = len(headers)
    cols = [[] for _ in range(ncol)]
    for ln in lines[1:]:
        parts = [p.strip() for p in ln.split(",")]
        if len(parts) < ncol:
            parts += [""] * (ncol - len(parts))
        for i in range(ncol):
            cols[i].append(parts[i])

    ev = EventFrame()
    for i, h in enumerate(headers):
        vals = cols[i]
        arr: object
        try:
            arr = np.asarray([float(v) if v else np.nan for v in vals])
            if h == TS:
                arr = (arr * scales[i]).astype(np.int64)
            elif h in (PROC, THREAD, PARTNER, TAG):
                arr = np.nan_to_num(arr, nan=-1).astype(np.int64)
        except ValueError:
            arr = Categorical.from_values(np.asarray(vals, dtype=object).astype(str))
        ev[h] = arr
    return Trace(ev, label=label)
