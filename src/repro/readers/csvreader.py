"""CSV trace reader — the paper's Fig. 1 format.

Header names are matched case-insensitively after stripping; a timestamp
header of ``Timestamp (s)`` / ``(ms)`` / ``(us)`` is converted to ns.  Extra
columns are kept verbatim (numeric when they parse as floats).
"""

from __future__ import annotations

import itertools
from typing import Iterator, List, Optional

import numpy as np

from ..core.constants import ET, MSG_SIZE, NAME, PARTNER, PROC, TAG, THREAD, TS
from ..core.frame import Categorical, EventFrame, optimize_dtypes
from ..core.registry import (ByteSpan, PlanHints, even_edges,
                             rank_shard_procs, register_chunked,
                             register_reader, register_units)
from ..core.trace import Trace

_UNIT = {"(s)": 1e9, "(ms)": 1e6, "(us)": 1e3, "(ns)": 1.0}

_CANON = {
    "timestamp": TS, "time": TS, "event type": ET, "event": ET, "name": NAME,
    "function": NAME, "process": PROC, "rank": PROC, "thread": THREAD,
    "msg size": MSG_SIZE, "size": MSG_SIZE, "partner": PARTNER, "tag": TAG,
}


def _canon_header(h: str):
    h = h.strip()
    scale = 1.0
    low = h.lower()
    for u, s in _UNIT.items():
        if low.endswith(u):
            low = low[: -len(u)].strip()
            scale = s
    return _CANON.get(low, h), scale


def _sniff_csv(path: str, head: str) -> bool:
    line = head.splitlines()[0] if head else ""
    if line.count(",") < 2:
        return False
    toks = [_canon_header(t)[0] for t in line.split(",")]
    return TS in toks and (ET in toks or NAME in toks)


def _parse_header(line: str):
    headers, scales = [], []
    for h in line.split(","):
        name, scale = _canon_header(h)
        headers.append(name)
        scales.append(scale)
    return headers, scales


def _rows_to_frame(headers: List[str], scales: List[float],
                   rows: List[List[str]],
                   decisions: Optional[List[str]] = None):
    """Build a frame from parsed rows; returns ``(frame, decisions)`` where
    ``decisions[i]`` records each column's inferred type ("num" / "cat").
    Passing previous ``decisions`` pins them — chunked reads must not let a
    column's dtype flip between chunks (a chunk whose string column happens
    to be all-numeric would otherwise silently diverge from the whole-file
    read)."""
    ncol = len(headers)
    cols = [[] for _ in range(ncol)]
    for parts in rows:
        if len(parts) < ncol:
            parts = parts + [""] * (ncol - len(parts))
        for i in range(ncol):
            cols[i].append(parts[i])
    ev = EventFrame()
    out_dec: List[str] = []
    for i, h in enumerate(headers):
        vals = cols[i]
        arr: object
        want = decisions[i] if decisions is not None else None
        if want == "cat":
            arr = None
        else:
            try:
                arr = np.asarray([float(v) if v else np.nan for v in vals])
                if h == TS:
                    arr = (arr * scales[i]).astype(np.int64)
                elif h in (PROC, THREAD, PARTNER, TAG):
                    arr = np.nan_to_num(arr, nan=-1).astype(np.int64)
            except ValueError:
                if want == "num":
                    from ..core.streaming import StreamingUnsupported
                    raise StreamingUnsupported(
                        f"CSV column {h!r} was typed numeric (by an "
                        f"earlier chunk's values, or by its canonical "
                        f"name under a parallel byte-range read) but "
                        f"holds non-numeric values; the whole-file read "
                        f"types columns over all rows — open with "
                        f"streaming=False") from None
                arr = None
        if arr is None:
            arr = Categorical.from_values(
                np.asarray(vals, dtype=object).astype(str))
            out_dec.append("cat")
        else:
            out_dec.append("num")
        ev[h] = arr
    return ev, out_dec


def _infer_decisions(headers: List[str], rows: List[List[str]],
                     prev: Optional[List[str]]) -> List[str]:
    """Per-column num/cat decisions from (unfiltered) chunk rows, merged
    with earlier chunks': cat is sticky; num -> cat means an earlier chunk
    was already yielded with the wrong dtype, which the whole-file read
    would have typed differently — fail loudly."""
    out: List[str] = []
    for i, h in enumerate(headers):
        dec = "num"
        for parts in rows:
            v = parts[i] if i < len(parts) else ""
            if not v:
                continue
            try:
                float(v)
            except ValueError:
                dec = "cat"
                break
        if prev is not None:
            if prev[i] == "cat":
                dec = "cat"
            elif prev[i] == "num" and dec == "cat":
                from ..core.streaming import StreamingUnsupported
                raise StreamingUnsupported(
                    f"CSV column {h!r} parsed as numeric in an earlier "
                    f"chunk but holds non-numeric values later; the "
                    f"whole-file read types columns over all rows — open "
                    f"with streaming=False")
        out.append(dec)
    return out


@register_reader("csv", extensions=(".csv",), sniff=_sniff_csv,
                 shard_procs=rank_shard_procs)
def read_csv(path_or_buf, label: Optional[str] = None) -> Trace:
    if isinstance(path_or_buf, str):
        with open(path_or_buf) as f:
            text = f.read()
        label = label or path_or_buf
    else:
        text = path_or_buf.read()
    lines = [ln for ln in text.splitlines() if ln.strip()]
    if not lines:
        return Trace(EventFrame(), label=label)
    headers, scales = _parse_header(lines[0])
    rows = [[p.strip() for p in ln.split(",")] for ln in lines[1:]]
    ev, _ = _rows_to_frame(headers, scales, rows)
    return Trace(optimize_dtypes(ev), label=label)


@register_chunked("csv")
def iter_chunks_csv(path: str, chunk_rows: int,
                    hints: Optional[PlanHints] = None,
                    label: Optional[str] = None,
                    byte_range: Optional[tuple] = None
                    ) -> Iterator[EventFrame]:
    """Stream a CSV trace in bounded chunks, with process/time pushdown
    applied per row before the columns are built.  ``byte_range=(lo, hi)``
    restricts the read to data lines starting inside the span (parallel
    work units); the header is always parsed.  Caveat: extra-column
    num/cat type decisions are then made per span — ambiguous columns that
    the whole-file read types over all rows should use serial streaming."""
    if byte_range is not None:
        from .jsonl import iter_lines_range
        # strict decoding, like the serial text-mode open: invalid UTF-8
        # must fail identically in both modes, not diverge silently.
        # Decoding per complete line is split-safe — multi-byte characters
        # never straddle a line boundary.
        with open(path, "rb") as f:
            header = f.readline().decode("utf-8")
            if not header.strip():
                return
            headers, scales = _parse_header(header)
            # a span's rows cannot type columns (value inference over a
            # slice can disagree with the whole-file read — e.g. a span
            # whose Name values all look numeric); pin every canonical
            # column by NAME instead, which is what the unit planner's
            # canonical-only guard guarantees is possible
            fixed = [("cat" if h in (ET, NAME) else "num")
                     for h in headers]
            lo = max(int(byte_range[0]), f.tell())
            src = (ln.decode("utf-8")
                   for ln in iter_lines_range(f, lo, int(byte_range[1])))
            yield from _iter_csv_lines(src, headers, scales, hints,
                                       chunk_rows, fixed_decisions=fixed)
        return
    with open(path) as f:
        header = f.readline()
        if not header.strip():
            return
        headers, scales = _parse_header(header)
        yield from _iter_csv_lines(f, headers, scales, hints, chunk_rows)


def _iter_csv_lines(f, headers, scales, hints, chunk_rows,
                    fixed_decisions: Optional[List[str]] = None
                    ) -> Iterator[EventFrame]:
    try:
        p_i = headers.index(PROC)
    except ValueError:
        p_i = None
    try:
        t_i = headers.index(TS)
    except ValueError:
        t_i = None
    tw = hints.time_window if hints is not None else None
    check_proc = (hints is not None and p_i is not None
                  and (hints.procs is not None
                       or hints.proc_bounds is not None))
    decisions = None
    while True:
        lines = list(itertools.islice(f, chunk_rows))
        if not lines:
            break
        all_rows, rows = [], []
        for ln in lines:
            if not ln.strip():
                continue
            parts = [p.strip() for p in ln.split(",")]
            all_rows.append(parts)
            if check_proc and len(parts) > p_i:
                try:
                    if not hints.admits_proc(int(float(parts[p_i]))):
                        continue
                except ValueError:
                    pass
            if tw is not None and t_i is not None and len(parts) > t_i:
                try:
                    t = float(parts[t_i]) * scales[t_i]
                    if not (tw[0] <= t <= tw[1]):
                        continue
                except ValueError:
                    pass
            rows.append(parts)
        # type decisions must come from the *unfiltered* rows: the
        # whole-file read types columns over every row, and pushdown
        # may drop exactly the rows whose values are non-numeric.  A
        # byte-range read pins them by column name instead (see above).
        if fixed_decisions is not None:
            decisions = fixed_decisions
        elif all_rows:
            decisions = _infer_decisions(headers, all_rows, decisions)
        if rows:
            ev, _ = _rows_to_frame(headers, scales, rows, decisions)
            yield optimize_dtypes(ev)


_CANONICAL = (TS, ET, NAME, PROC, THREAD, MSG_SIZE, PARTNER, TAG)


@register_units("csv")
def plan_units_csv(path: str, n_units: int):
    """Split the data region (past the header line) into ~equal byte
    spans; the chunked reader aligns spans to line boundaries.

    Only files whose header holds canonical columns are split: canonical
    columns are typed by *name*, so byte-range workers agree with the
    whole-file read by construction.  Extra columns are typed by value
    inference over rows — per-span inference could silently diverge from
    serial streaming, so such files stay one (serial-semantics) unit.

    Canonical columns holding non-canonical *content* (every Name numeric,
    a letter in Process, ...) are malformed traces: one mode fails loudly
    where the other succeeds, but results never diverge silently.
    """
    import os
    size = os.path.getsize(path)
    with open(path, "rb") as f:
        header = f.readline().decode("utf-8", errors="replace")
        start = f.tell()
    headers, _scales = _parse_header(header)
    if any(h not in _CANONICAL for h in headers):
        return None
    n = max(min(int(n_units), size - start), 1)
    if n <= 1 or start >= size:
        return None
    edges = even_edges(start, size, n)
    return [ByteSpan(path, lo, hi)
            for lo, hi in zip(edges[:-1], edges[1:]) if hi > lo]


def write_csv(trace_or_events, path: str) -> None:
    """Serialize a trace to the canonical-header CSV format (inverse of
    :func:`read_csv`; used by the cross-reader conformance suite)."""
    ev = getattr(trace_or_events, "events", trace_or_events)
    cols = ev.columns
    ts = np.asarray(ev[TS], np.int64)
    mats = {c: ev[c] for c in cols if c != TS}
    with open(path, "w") as f:
        f.write(",".join([TS] + [c for c in cols if c != TS]) + "\n")
        names = [c for c in cols if c != TS]
        for i in range(len(ev)):
            parts = [str(int(ts[i]))]
            for c in names:
                v = mats[c][i]
                if isinstance(v, (float, np.floating)) and np.isnan(v):
                    parts.append("")
                else:
                    parts.append(str(v))
            f.write(",".join(parts) + "\n")

