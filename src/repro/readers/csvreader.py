"""CSV trace reader — the paper's Fig. 1 format.

Header names are matched case-insensitively after stripping; a timestamp
header of ``Timestamp (s)`` / ``(ms)`` / ``(us)`` is converted to ns.  Extra
columns are kept verbatim (numeric when they parse as floats).
"""

from __future__ import annotations

import itertools
import os
from typing import Iterator, List, Optional

import numpy as np

from ..core.constants import ET, MSG_SIZE, NAME, PARTNER, PROC, TAG, THREAD, TS
from ..core.errors import (IngestReport, TraceReadError, check_on_error,
                           require_nonempty)
from ..core.frame import Categorical, EventFrame, optimize_dtypes
from ..core.registry import (ByteSpan, PlanHints, even_edges,
                             rank_shard_procs, register_chunked,
                             register_reader, register_units)
from ..core.trace import Trace

_UNIT = {"(s)": 1e9, "(ms)": 1e6, "(us)": 1e3, "(ns)": 1.0}

_CANON = {
    "timestamp": TS, "time": TS, "event type": ET, "event": ET, "name": NAME,
    "function": NAME, "process": PROC, "rank": PROC, "thread": THREAD,
    "msg size": MSG_SIZE, "size": MSG_SIZE, "partner": PARTNER, "tag": TAG,
}


def _canon_header(h: str):
    h = h.strip()
    scale = 1.0
    low = h.lower()
    for u, s in _UNIT.items():
        if low.endswith(u):
            low = low[: -len(u)].strip()
            scale = s
    return _CANON.get(low, h), scale


def _sniff_csv(path: str, head: str) -> bool:
    line = head.splitlines()[0] if head else ""
    if line.count(",") < 2:
        return False
    toks = [_canon_header(t)[0] for t in line.split(",")]
    return TS in toks and (ET in toks or NAME in toks)


def _parse_header(line: str):
    headers, scales = [], []
    for h in line.split(","):
        name, scale = _canon_header(h)
        headers.append(name)
        scales.append(scale)
    return headers, scales


#: canonical columns whose values must be numeric — a non-numeric value in
#: one of these is a malformed *row*, never a license to silently retype
#: the whole column as categorical (the pre-fault-tolerance behavior)
_NUMERIC_CANON = (TS, PROC, THREAD, MSG_SIZE, PARTNER, TAG)


def _row_fault(parts: List[str], num_idx: List[tuple]) -> Optional[str]:
    """Why this data row is malformed, or None when it is well-formed."""
    for i, h in num_idx:
        v = parts[i] if i < len(parts) else ""
        if not v:
            continue
        try:
            float(v)
        except ValueError:
            return f"column {h!r} value {v!r} is not numeric"
    return None


def _validate_rows(numbered_rows, headers: List[str], path: str,
                   on_error: str, report: Optional[IngestReport],
                   origin: str = "") -> List[List[str]]:
    """Filter ``(lineno, parts)`` pairs down to well-formed rows.  Strict
    raises :class:`TraceReadError` with file:line context at the first bad
    row; skip drops it and counts it in ``report``.  The decision is per
    physical row, so eager / chunked / byte-span reads of one damaged file
    keep identical survivors."""
    num_idx = [(i, h) for i, h in enumerate(headers) if h in _NUMERIC_CANON]
    out: List[List[str]] = []
    for lineno, parts in numbered_rows:
        fault = _row_fault(parts, num_idx)
        if fault is None:
            out.append(parts)
            continue
        locus = f"{origin}line {lineno}"
        if on_error == "strict":
            raise TraceReadError(path, f"malformed CSV row ({fault})",
                                 locus=locus)
        if report is not None:
            report.skip(path, 1, locus, fault)
    return out


def _rows_to_frame(headers: List[str], scales: List[float],
                   rows: List[List[str]],
                   decisions: Optional[List[str]] = None):
    """Build a frame from parsed rows; returns ``(frame, decisions)`` where
    ``decisions[i]`` records each column's inferred type ("num" / "cat").
    Passing previous ``decisions`` pins them — chunked reads must not let a
    column's dtype flip between chunks (a chunk whose string column happens
    to be all-numeric would otherwise silently diverge from the whole-file
    read)."""
    ncol = len(headers)
    cols = [[] for _ in range(ncol)]
    for parts in rows:
        if len(parts) < ncol:
            parts = parts + [""] * (ncol - len(parts))
        for i in range(ncol):
            cols[i].append(parts[i])
    ev = EventFrame()
    out_dec: List[str] = []
    for i, h in enumerate(headers):
        vals = cols[i]
        arr: object
        want = decisions[i] if decisions is not None else None
        if want == "cat":
            arr = None
        else:
            try:
                arr = np.asarray([float(v) if v else np.nan for v in vals])
                if h == TS:
                    arr = (arr * scales[i]).astype(np.int64)
                elif h in (PROC, THREAD, PARTNER, TAG):
                    arr = np.nan_to_num(arr, nan=-1).astype(np.int64)
            except ValueError:
                if want == "num":
                    from ..core.streaming import StreamingUnsupported
                    raise StreamingUnsupported(
                        f"CSV column {h!r} was typed numeric (by an "
                        f"earlier chunk's values, or by its canonical "
                        f"name under a parallel byte-range read) but "
                        f"holds non-numeric values; the whole-file read "
                        f"types columns over all rows — open with "
                        f"streaming=False") from None
                arr = None
        if arr is None:
            arr = Categorical.from_values(
                np.asarray(vals, dtype=object).astype(str))
            out_dec.append("cat")
        else:
            out_dec.append("num")
        ev[h] = arr
    return ev, out_dec


def _infer_decisions(headers: List[str], rows: List[List[str]],
                     prev: Optional[List[str]]) -> List[str]:
    """Per-column num/cat decisions from (unfiltered) chunk rows, merged
    with earlier chunks': cat is sticky; num -> cat means an earlier chunk
    was already yielded with the wrong dtype, which the whole-file read
    would have typed differently — fail loudly."""
    out: List[str] = []
    for i, h in enumerate(headers):
        dec = "num"
        for parts in rows:
            v = parts[i] if i < len(parts) else ""
            if not v:
                continue
            try:
                float(v)
            except ValueError:
                dec = "cat"
                break
        if prev is not None:
            if prev[i] == "cat":
                dec = "cat"
            elif prev[i] == "num" and dec == "cat":
                from ..core.streaming import StreamingUnsupported
                raise StreamingUnsupported(
                    f"CSV column {h!r} parsed as numeric in an earlier "
                    f"chunk but holds non-numeric values later; the "
                    f"whole-file read types columns over all rows — open "
                    f"with streaming=False")
        out.append(dec)
    return out


@register_reader("csv", extensions=(".csv",), sniff=_sniff_csv,
                 shard_procs=rank_shard_procs)
def read_csv(path_or_buf, label: Optional[str] = None,
             on_error: str = "strict",
             report: Optional[IngestReport] = None) -> Trace:
    check_on_error(on_error, ("strict", "skip"))
    rpt = report if report is not None else IngestReport()
    if isinstance(path_or_buf, str):
        require_nonempty(path_or_buf, os.path.getsize(path_or_buf),
                         what="csv trace")
        with open(path_or_buf, "rb") as f:
            lines = f.read().splitlines()
        label = label or path_or_buf
    else:
        lines = path_or_buf.read().splitlines()
    src = path_or_buf if isinstance(path_or_buf, str) else "<buffer>"
    rpt.begin(src)
    numbered = []
    for i, ln in enumerate(lines):
        if isinstance(ln, bytes):
            try:
                ln = ln.decode("utf-8")
            except UnicodeDecodeError as e:
                # the undecodable unit is the physical line — same skip
                # granularity as a malformed row, so every execution mode
                # drops the identical line set
                if on_error == "strict":
                    raise TraceReadError(
                        src, f"undecodable bytes — not UTF-8 ({e})",
                        locus=f"line {i + 1}") from e
                rpt.skip(src, 1, f"line {i + 1}",
                         "undecodable bytes (not UTF-8)")
                continue
        if ln.strip():
            numbered.append((i + 1, ln))
    if not numbered:
        t = Trace(EventFrame(), label=label)
        t._ingest = rpt
        return t
    headers, scales = _parse_header(numbered[0][1])
    data = [(no, [p.strip() for p in ln.split(",")])
            for no, ln in numbered[1:]]
    rows = _validate_rows(data, headers, src, on_error, rpt)
    rpt.add_rows(src, len(rows))
    ev, _ = _rows_to_frame(headers, scales, rows)
    t = Trace(optimize_dtypes(ev), label=label)
    t._ingest = rpt
    return t


def _decode_header(raw: bytes, path: str) -> str:
    """The header is the anchor (it types every column): undecodable bytes
    there are fatal under every policy, with the file named."""
    try:
        return raw.decode("utf-8")
    except UnicodeDecodeError as e:
        raise TraceReadError(path, f"undecodable bytes in CSV header — "
                                   f"not UTF-8 ({e})", locus="line 1") from e


def _decoded_lines(blines, path: str, on_error: str,
                   report: Optional[IngestReport], origin: str = "",
                   first_line: int = 2) -> Iterator[str]:
    """Per-line UTF-8 decode with the reader's error policy: strict raises
    with file:line context, skip drops exactly that physical line (counted
    in ``report``) — the same granularity as a malformed row, so serial,
    chunked and span-parallel reads keep identical survivors."""
    n = first_line
    for bln in blines:
        try:
            yield bln.decode("utf-8")
        except UnicodeDecodeError as e:
            locus = f"{origin}line {n}"
            if on_error == "strict":
                raise TraceReadError(path, f"undecodable bytes — not "
                                           f"UTF-8 ({e})", locus=locus) from e
            if report is not None:
                report.skip(path, 1, locus, "undecodable bytes (not UTF-8)")
        n += 1


@register_chunked("csv")
def iter_chunks_csv(path: str, chunk_rows: int,
                    hints: Optional[PlanHints] = None,
                    label: Optional[str] = None,
                    byte_range: Optional[tuple] = None,
                    on_error: str = "strict",
                    report: Optional[IngestReport] = None
                    ) -> Iterator[EventFrame]:
    """Stream a CSV trace in bounded chunks, with process/time pushdown
    applied per row before the columns are built.  ``byte_range=(lo, hi)``
    restricts the read to data lines starting inside the span (parallel
    work units); the header is always parsed.  ``on_error="skip"`` drops
    malformed rows (non-numeric values in canonical numeric columns) with
    exact counts in ``report``.  Caveat: extra-column num/cat type
    decisions are made per span — ambiguous columns that the whole-file
    read types over all rows should use serial streaming."""
    check_on_error(on_error, ("strict", "skip"))
    require_nonempty(path, os.path.getsize(path), what="csv trace")
    if report is not None and byte_range is None:
        report.begin(path)
    if byte_range is not None:
        from .jsonl import iter_lines_range
        # Decoding per complete line is split-safe — multi-byte characters
        # never straddle a line boundary — and per-line policy keeps the
        # surviving rows identical across serial / chunked / span reads.
        with open(path, "rb") as f:
            header = _decode_header(f.readline(), path)
            if not header.strip():
                return
            headers, scales = _parse_header(header)
            # a span's rows cannot type columns (value inference over a
            # slice can disagree with the whole-file read — e.g. a span
            # whose Name values all look numeric); pin every canonical
            # column by NAME instead, which is what the unit planner's
            # canonical-only guard guarantees is possible
            fixed = [("cat" if h in (ET, NAME) else "num")
                     for h in headers]
            lo = max(int(byte_range[0]), f.tell())
            src = _decoded_lines(
                iter_lines_range(f, lo, int(byte_range[1])), path,
                on_error, report, origin=f"span@{lo}+")
            yield from _iter_csv_lines(src, headers, scales, hints,
                                       chunk_rows, fixed_decisions=fixed,
                                       path=path, on_error=on_error,
                                       report=report,
                                       origin=f"span@{lo}+")
        return
    with open(path, "rb") as f:
        header = _decode_header(f.readline(), path)
        if not header.strip():
            return
        headers, scales = _parse_header(header)
        yield from _iter_csv_lines(
            _decoded_lines(f, path, on_error, report), headers, scales,
            hints, chunk_rows, path=path, on_error=on_error, report=report)


def _iter_csv_lines(f, headers, scales, hints, chunk_rows,
                    fixed_decisions: Optional[List[str]] = None,
                    path: str = "<buffer>", on_error: str = "strict",
                    report: Optional[IngestReport] = None,
                    origin: str = "") -> Iterator[EventFrame]:
    try:
        p_i = headers.index(PROC)
    except ValueError:
        p_i = None
    try:
        t_i = headers.index(TS)
    except ValueError:
        t_i = None
    tw = hints.time_window if hints is not None else None
    check_proc = (hints is not None and p_i is not None
                  and (hints.procs is not None
                       or hints.proc_bounds is not None))
    decisions = None
    lineno = 1 if not origin else 0  # serial mode: header was line 1
    while True:
        lines = list(itertools.islice(f, chunk_rows))
        if not lines:
            break
        numbered = []
        for ln in lines:
            lineno += 1
            if not ln.strip():
                continue
            numbered.append((lineno, [p.strip() for p in ln.split(",")]))
        # malformed rows are resolved *first* (strict raises, skip drops)
        # so type decisions and pushdown only ever see well-formed rows —
        # identical to the whole-file read's order of operations
        all_rows = _validate_rows(numbered, headers, path, on_error,
                                  report, origin)
        rows = []
        for parts in all_rows:
            if check_proc and len(parts) > p_i:
                try:
                    if not hints.admits_proc(int(float(parts[p_i]))):
                        continue
                except ValueError:
                    pass
            if tw is not None and t_i is not None and len(parts) > t_i:
                try:
                    t = float(parts[t_i]) * scales[t_i]
                    if not (tw[0] <= t <= tw[1]):
                        continue
                except ValueError:
                    pass
            rows.append(parts)
        if report is not None:
            report.add_rows(path, len(rows))
        # type decisions must come from the *unfiltered* (but validated)
        # rows: the whole-file read types columns over every surviving
        # row, and pushdown may drop exactly the rows whose values are
        # non-numeric.  A byte-range read pins them by column name.
        if fixed_decisions is not None:
            decisions = fixed_decisions
        elif all_rows:
            decisions = _infer_decisions(headers, all_rows, decisions)
        if rows:
            ev, _ = _rows_to_frame(headers, scales, rows, decisions)
            yield optimize_dtypes(ev)


_CANONICAL = (TS, ET, NAME, PROC, THREAD, MSG_SIZE, PARTNER, TAG)


@register_units("csv")
def plan_units_csv(path: str, n_units: int):
    """Split the data region (past the header line) into ~equal byte
    spans; the chunked reader aligns spans to line boundaries.

    Only files whose header holds canonical columns are split: canonical
    columns are typed by *name*, so byte-range workers agree with the
    whole-file read by construction.  Extra columns are typed by value
    inference over rows — per-span inference could silently diverge from
    serial streaming, so such files stay one (serial-semantics) unit.

    Canonical columns holding non-canonical *content* (every Name numeric,
    a letter in Process, ...) are malformed traces: one mode fails loudly
    where the other succeeds, but results never diverge silently.
    """
    import os
    size = os.path.getsize(path)
    with open(path, "rb") as f:
        header = f.readline().decode("utf-8", errors="replace")
        start = f.tell()
    headers, _scales = _parse_header(header)
    if any(h not in _CANONICAL for h in headers):
        return None
    n = max(min(int(n_units), size - start), 1)
    if n <= 1 or start >= size:
        return None
    edges = even_edges(start, size, n)
    return [ByteSpan(path, lo, hi)
            for lo, hi in zip(edges[:-1], edges[1:]) if hi > lo]


def write_csv(trace_or_events, path: str) -> None:
    """Serialize a trace to the canonical-header CSV format (inverse of
    :func:`read_csv`; used by the cross-reader conformance suite)."""
    ev = getattr(trace_or_events, "events", trace_or_events)
    cols = ev.columns
    ts = np.asarray(ev[TS], np.int64)
    mats = {c: ev[c] for c in cols if c != TS}
    with open(path, "w") as f:
        f.write(",".join([TS] + [c for c in cols if c != TS]) + "\n")
        names = [c for c in cols if c != TS]
        for i in range(len(ev)):
            parts = [str(int(ts[i]))]
            for c in names:
                v = mats[c][i]
                if isinstance(v, (float, np.floating)) and np.isnan(v):
                    parts.append("")
                else:
                    parts.append(str(v))
            f.write(",".join(parts) + "\n")

