"""repro.readers — multi-format trace readers into the uniform data model
(paper §III-B).

Every reader returns a :class:`repro.core.Trace` whose events frame has at
least the canonical columns ``Timestamp (ns) / Event Type / Name / Process``
plus normalized message columns (``_msg_size``, ``_partner``, ``_tag``) when
the format records communication.  Formats:

=================  ==========================================================
``csvreader``      the paper's Fig. 1 CSV
``jsonl``          Pipit-native JSON-lines (one event per line)
``chrome``         Chrome Trace Format (Nsight Systems / PyTorch profiler
                   exports use this envelope)
``otf2j``          schema-faithful OTF2 rendering (definitions + per-location
                   event streams; the binary OTF2 C library is unavailable
                   offline, so archives are JSON with OTF2's exact structure)
``pack``           pipitpack, the native columnar binary store: per-column
                   mmap arrays + chunk index + optional structure sidecar —
                   convert once (``trace.save_pack`` / tools/pack.py), then
                   reopen with zero parsing (docs/pack-format.md)
``hlo``            compiled XLA programs (post-SPMD HLO text) → modeled
                   per-device timelines; the bridge that lets Pipit analyze
                   our own TPU framework's planned executions
``parallel``       multiprocessing driver that fans out any reader over
                   per-location shards (paper §VI)
=================  ==========================================================
"""

from .chrome import read_chrome
from .csvreader import read_csv
from .hlo import read_hlo, read_hlo_file
from .jsonl import read_jsonl, write_jsonl
from .otf2j import read_otf2_json, write_otf2_json
from .pack import PackWriter, read_pack, write_pack
from .parallel import open_many, read_parallel, select_shards

__all__ = [
    "read_csv", "read_jsonl", "write_jsonl", "read_chrome", "read_otf2_json",
    "write_otf2_json", "read_hlo", "read_hlo_file", "read_pack",
    "write_pack", "PackWriter", "read_parallel", "open_many",
    "select_shards",
]
