"""Pipit-native JSON-lines format: one event object per line.

Keys (short forms keep files small): ``ts`` (ns), ``et`` (Enter/Leave/Instant),
``name``, ``proc``, ``thread``, and for messages ``size``/``partner``/``tag``.
This is the format our own framework's tracer emits.

Ingest is dtype-optimized: function names are dictionary-interned while
parsing (one dict lookup per event instead of a 10M-string ``np.unique``
pass) and integer id columns are downcast to the narrowest safe dtype
(:func:`repro.core.frame.optimize_dtypes`).  The chunked reader
(``iter_chunks``) never holds more than ``chunk_rows`` events and applies
the plan's process/time-window pushdown while parsing.
"""

from __future__ import annotations

import itertools
import json
import os
from typing import Iterator, Optional

import numpy as np

from ..core.constants import (ENTER, ET, INSTANT, LEAVE, MSG_SIZE, NAME,
                              PARTNER, PROC, TAG, THREAD, TS)
from ..core.errors import (IngestReport, TraceReadError, check_on_error,
                           require_nonempty)
from ..core.frame import Categorical, EventFrame, optimize_dtypes
from ..core.registry import (ByteSpan, PlanHints, even_edges,
                             rank_shard_procs, register_chunked,
                             register_reader, register_units)
from ..core.trace import Trace

_ET_CODE = {ENTER: 0, LEAVE: 1, INSTANT: 2}
_ET_CATS = np.asarray([ENTER, LEAVE, INSTANT])


def _sniff_jsonl(path: str, head: str) -> bool:
    for line in head.splitlines():
        line = line.strip()
        if not line:
            continue
        if not line.startswith("{"):
            return False
        try:
            d = json.loads(line)
        except ValueError:
            # head is a fixed-size prefix: an event line longer than the
            # sniff window arrives truncated mid-JSON.  Accept only when the
            # extension *also* claims jsonl — content alone can't distinguish
            # a truncated event from some unrelated big JSON with a "ts" key,
            # and misrouting it would crash deep inside read_jsonl.
            return (len(line) >= 4096 and path.lower().endswith(".jsonl")
                    and '"ts"' in line[:256])
        return isinstance(d, dict) and "ts" in d
    return False


class _JsonlParser:
    """Shared line-batch parser: interns names into a per-file dictionary
    (codes stay stable across chunks of one file).

    ``on_error="strict"`` raises :class:`TraceReadError` with file:line
    context on the first malformed line; ``"skip"`` drops malformed lines,
    counting each in ``report``.  The skip decision is per physical line,
    so eager, chunked and byte-span-parallel reads of the same damaged
    file keep exactly the same surviving rows.
    """

    def __init__(self, path: str = "<buffer>", on_error: str = "strict",
                 report: Optional[IngestReport] = None,
                 line_origin: str = ""):
        self._name_code = {}
        self._names = []
        self.path = path
        self.on_error = check_on_error(on_error, ("strict", "skip"))
        self.report = report
        self._origin = line_origin  # e.g. "span@512+" for byte-span units
        self._line = 0

    def parse(self, lines, hints: Optional[PlanHints] = None
              ) -> Optional[EventFrame]:
        """One EventFrame per line batch; None when every row was pushed
        down away.  Always emits the uniform column set (thread/message
        columns included) so chunks of one file concatenate cleanly."""
        tw = hints.time_window if hints is not None else None
        check_proc = hints is not None and (hints.procs is not None
                                            or hints.proc_bounds is not None)
        name_code = self._name_code
        names = self._names
        ts, et, ncodes, procs, threads = [], [], [], [], []
        sizes, partners, tags = [], [], []
        n = 0
        for line in lines:
            self._line += 1
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
                if not isinstance(d, dict):
                    raise ValueError("not an event object")
                p = int(d.get("proc", 0))
                t = int(d["ts"])
                thread = int(d.get("thread", 0))
                s = d.get("size")
                size = float(s) if s is not None else np.nan
                pr = d.get("partner")
                partner = int(pr) if pr is not None else -1
                g = d.get("tag")
                tag = int(g) if g is not None else 0
                etc = _ET_CODE.get(d.get("et", ENTER), 2)
                nm = d.get("name", "")
            except (ValueError, KeyError, TypeError) as e:
                locus = f"{self._origin}line {self._line}"
                if self.on_error == "strict":
                    raise TraceReadError(self.path,
                                         f"malformed event line ({e})",
                                         locus=locus) from e
                if self.report is not None:
                    self.report.skip(self.path, 1, locus, str(e))
                continue
            if check_proc and not hints.admits_proc(p):
                continue
            if tw is not None and not (tw[0] <= t <= tw[1]):
                continue
            c = name_code.get(nm)
            if c is None:
                c = len(names)
                name_code[nm] = c
                names.append(nm)
            ts.append(t)
            et.append(etc)
            ncodes.append(c)
            procs.append(p)
            threads.append(thread)
            sizes.append(size)
            partners.append(partner)
            tags.append(tag)
            n += 1
        if self.report is not None:
            self.report.add_rows(self.path, n)
        if n == 0:
            return None
        ev = EventFrame({
            TS: np.asarray(ts, np.int64),
            ET: Categorical.from_codes(np.asarray(et, np.int32), _ET_CATS),
            NAME: Categorical.from_codes(np.asarray(ncodes, np.int32),
                                         np.asarray(names, dtype=object)),
            PROC: np.asarray(procs, np.int64),
            THREAD: np.asarray(threads, np.int64),
            MSG_SIZE: np.asarray(sizes),
            PARTNER: np.asarray(partners, np.int64),
            TAG: np.asarray(tags, np.int64),
        })
        return ev


def _sorted_names(ev: EventFrame) -> EventFrame:
    """Remap the interned (first-seen-order) name codes onto a sorted
    category table — the exact Categorical ``np.unique`` ingest produced, so
    downstream group orders are unchanged."""
    cat = ev.column(NAME)
    if not isinstance(cat, Categorical) or len(cat.categories) == 0:
        return ev
    order = np.argsort(cat.categories.astype(str), kind="stable")
    inv = np.empty(len(order), np.int64)
    inv[order] = np.arange(len(order))
    ev[NAME] = Categorical(inv[cat.codes].astype(np.int32),
                           cat.categories[order])
    return ev


@register_reader("jsonl", extensions=(".jsonl",), sniff=_sniff_jsonl,
                 shard_procs=rank_shard_procs, priority=10)
def read_jsonl(path_or_buf, label: Optional[str] = None,
               on_error: str = "strict",
               report: Optional[IngestReport] = None) -> Trace:
    rpt = report if report is not None else IngestReport()
    if isinstance(path_or_buf, str):
        require_nonempty(path_or_buf, os.path.getsize(path_or_buf),
                         what="jsonl trace")
        # binary: json.loads accepts bytes, and a non-UTF-8 garbage line
        # then fails as a per-line ValueError (strict raises with file:line,
        # skip drops that line) instead of an unlocated UnicodeDecodeError
        # escaping the text-mode iterator
        f = open(path_or_buf, "rb")
        label = label or path_or_buf
        close = True
    else:
        f, close = path_or_buf, False
    src = path_or_buf if isinstance(path_or_buf, str) else "<buffer>"
    rpt.begin(src)
    try:
        ev = _JsonlParser(src, on_error, rpt).parse(f)
    finally:
        if close:
            f.close()
    if ev is None:
        t = Trace(EventFrame(), label=label)
        t._ingest = rpt
        return t
    ev = _sorted_names(ev)
    # whole-file reads keep the historical column shape: thread / message
    # columns only when the trace actually has them
    if not np.any(np.asarray(ev[THREAD], np.int64)):
        ev = ev.drop(THREAD)
    if not (np.any(~np.isnan(np.asarray(ev[MSG_SIZE], np.float64)))
            or np.any(np.asarray(ev[PARTNER], np.int64) >= 0)):
        ev = ev.drop(MSG_SIZE, PARTNER, TAG)
    t = Trace(optimize_dtypes(ev), label=label)
    t._ingest = rpt
    return t


def iter_lines_range(f, lo: int, hi: int) -> Iterator[bytes]:
    """Lines of the binary stream ``f`` whose first byte lies in [lo, hi) —
    the record-ownership rule :class:`~repro.core.registry.ByteSpan` work
    units rely on.  Split offsets may land anywhere; every line belongs to
    exactly one span."""
    if lo > 0:
        f.seek(lo - 1)
        if f.read(1) != b"\n":
            f.readline()  # skip the tail of the line owned by the span below
    else:
        f.seek(0)
    while True:
        start = f.tell()
        if start >= hi:
            return
        line = f.readline()
        if not line:
            return
        yield line


@register_chunked("jsonl")
def iter_chunks_jsonl(path: str, chunk_rows: int,
                      hints: Optional[PlanHints] = None,
                      label: Optional[str] = None,
                      byte_range: Optional[tuple] = None,
                      on_error: str = "strict",
                      report: Optional[IngestReport] = None
                      ) -> Iterator[EventFrame]:
    """Stream ``path`` in EventFrame chunks of at most ``chunk_rows`` events
    without ever holding the file, applying pushdown while parsing.
    ``byte_range=(lo, hi)`` restricts the read to the lines starting inside
    that span (parallel work units).  ``on_error="skip"`` drops malformed
    lines (counted in ``report``) instead of raising — per physical line,
    so every execution mode keeps identical surviving rows."""
    require_nonempty(path, os.path.getsize(path), what="jsonl trace")
    if report is not None and byte_range is None:
        report.begin(path)
    origin = (f"span@{int(byte_range[0])}+" if byte_range is not None
              else "")
    parser = _JsonlParser(path, on_error, report, line_origin=origin)
    if byte_range is not None:
        with open(path, "rb") as f:
            src = iter_lines_range(f, int(byte_range[0]), int(byte_range[1]))
            while True:
                lines = list(itertools.islice(src, chunk_rows))
                if not lines:
                    break
                ev = parser.parse(lines, hints)
                if ev is not None:
                    yield optimize_dtypes(ev)
        return
    with open(path, "rb") as f:  # binary for the same reason as read_jsonl
        while True:
            lines = list(itertools.islice(f, chunk_rows))
            if not lines:
                break
            ev = parser.parse(lines, hints)
            if ev is not None:
                yield optimize_dtypes(ev)


@register_units("jsonl")
def plan_units_jsonl(path: str, n_units: int):
    """Split one JSONL file into ~equal byte spans; the chunked reader
    aligns each span to line boundaries, so the spans partition the events
    exactly."""
    import os
    size = os.path.getsize(path)
    n = max(min(int(n_units), size), 1)
    if n <= 1:
        return None
    edges = even_edges(0, size, n)
    return [ByteSpan(path, lo, hi)
            for lo, hi in zip(edges[:-1], edges[1:]) if hi > lo]


def write_jsonl(trace_or_events, path: str) -> None:
    ev = getattr(trace_or_events, "events", trace_or_events)
    cols = ev.columns
    ts = np.asarray(ev[TS], np.int64)
    et = ev[ET]
    names = ev[NAME]
    procs = np.asarray(ev[PROC], np.int64)
    threads = np.asarray(ev[THREAD], np.int64) if THREAD in cols else None
    sizes = np.asarray(ev[MSG_SIZE], np.float64) if MSG_SIZE in cols else None
    partners = np.asarray(ev[PARTNER], np.int64) if PARTNER in cols else None
    tags = np.asarray(ev[TAG], np.int64) if TAG in cols else None
    with open(path, "w") as f:
        for i in range(len(ev)):
            d = {"ts": int(ts[i]), "et": str(et[i]), "name": str(names[i]),
                 "proc": int(procs[i])}
            if threads is not None and threads[i]:
                d["thread"] = int(threads[i])
            if sizes is not None and not np.isnan(sizes[i]):
                d["size"] = sizes[i]
            if partners is not None and partners[i] >= 0:
                d["partner"] = int(partners[i])
            if tags is not None and tags[i]:
                d["tag"] = int(tags[i])
            f.write(json.dumps(d) + "\n")
