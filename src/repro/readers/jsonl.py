"""Pipit-native JSON-lines format: one event object per line.

Keys (short forms keep files small): ``ts`` (ns), ``et`` (Enter/Leave/Instant),
``name``, ``proc``, ``thread``, and for messages ``size``/``partner``/``tag``.
This is the format our own framework's tracer emits.
"""

from __future__ import annotations

import json
from typing import Iterable, Optional

import numpy as np

from ..core.constants import (ENTER, ET, INSTANT, LEAVE, MSG_SIZE, NAME,
                              PARTNER, PROC, TAG, THREAD, TS)
from ..core.frame import Categorical, EventFrame
from ..core.registry import rank_shard_procs, register_reader
from ..core.trace import Trace

_ET_CODE = {ENTER: 0, LEAVE: 1, INSTANT: 2}
_ET_CATS = np.asarray([ENTER, LEAVE, INSTANT])


def _sniff_jsonl(path: str, head: str) -> bool:
    for line in head.splitlines():
        line = line.strip()
        if not line:
            continue
        if not line.startswith("{"):
            return False
        try:
            d = json.loads(line)
        except ValueError:
            # head is a fixed-size prefix: an event line longer than the
            # sniff window arrives truncated mid-JSON.  Accept only when the
            # extension *also* claims jsonl — content alone can't distinguish
            # a truncated event from some unrelated big JSON with a "ts" key,
            # and misrouting it would crash deep inside read_jsonl.
            return (len(line) >= 4096 and path.lower().endswith(".jsonl")
                    and '"ts"' in line[:256])
        return isinstance(d, dict) and "ts" in d
    return False


@register_reader("jsonl", extensions=(".jsonl",), sniff=_sniff_jsonl,
                 shard_procs=rank_shard_procs, priority=10)
def read_jsonl(path_or_buf, label: Optional[str] = None) -> Trace:
    if isinstance(path_or_buf, str):
        f = open(path_or_buf)
        label = label or path_or_buf
        close = True
    else:
        f, close = path_or_buf, False
    ts, et, names, procs, threads = [], [], [], [], []
    sizes, partners, tags = [], [], []
    has_msg = False
    try:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            ts.append(int(d["ts"]))
            et.append(_ET_CODE.get(d.get("et", ENTER), 2))
            names.append(d.get("name", ""))
            procs.append(int(d.get("proc", 0)))
            threads.append(int(d.get("thread", 0)))
            s = d.get("size")
            p = d.get("partner")
            g = d.get("tag")
            if s is not None or p is not None:
                has_msg = True
            sizes.append(float(s) if s is not None else np.nan)
            partners.append(int(p) if p is not None else -1)
            tags.append(int(g) if g is not None else 0)
    finally:
        if close:
            f.close()
    ev = EventFrame({
        TS: np.asarray(ts, np.int64),
        ET: Categorical.from_codes(np.asarray(et, np.int32), _ET_CATS),
        NAME: np.asarray(names, dtype=object),
        PROC: np.asarray(procs, np.int64),
    })
    if any(t != 0 for t in threads):
        ev[THREAD] = np.asarray(threads, np.int64)
    if has_msg:
        ev[MSG_SIZE] = np.asarray(sizes)
        ev[PARTNER] = np.asarray(partners, np.int64)
        ev[TAG] = np.asarray(tags, np.int64)
    return Trace(ev, label=label)


def write_jsonl(trace_or_events, path: str) -> None:
    ev = getattr(trace_or_events, "events", trace_or_events)
    cols = ev.columns
    ts = np.asarray(ev[TS], np.int64)
    et = ev[ET]
    names = ev[NAME]
    procs = np.asarray(ev[PROC], np.int64)
    threads = np.asarray(ev[THREAD], np.int64) if THREAD in cols else None
    sizes = np.asarray(ev[MSG_SIZE], np.float64) if MSG_SIZE in cols else None
    partners = np.asarray(ev[PARTNER], np.int64) if PARTNER in cols else None
    tags = np.asarray(ev[TAG], np.int64) if TAG in cols else None
    with open(path, "w") as f:
        for i in range(len(ev)):
            d = {"ts": int(ts[i]), "et": str(et[i]), "name": str(names[i]),
                 "proc": int(procs[i])}
            if threads is not None and threads[i]:
                d["thread"] = int(threads[i])
            if sizes is not None and not np.isnan(sizes[i]):
                d["size"] = sizes[i]
            if partners is not None and partners[i] >= 0:
                d["partner"] = int(partners[i])
            if tags is not None and tags[i]:
                d["tag"] = int(tags[i])
            f.write(json.dumps(d) + "\n")
