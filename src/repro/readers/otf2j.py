"""OTF2-structured reader (schema-faithful JSON rendering).

The binary OTF2 C library cannot be installed offline, so archives are stored
as JSON **with OTF2's exact logical structure** (see Eschweiler et al. [10]):

* ``definitions``: string table, region table (name refs into strings),
  location groups (= MPI ranks) and locations (= threads),
* per-location **event streams**, each a list of
  ``[timestamp, kind, ...]`` records with kinds ``E`` (Enter, region ref),
  ``L`` (Leave, region ref), ``S`` (MpiSend: receiver, length, tag),
  ``R`` (MpiRecv: sender, length, tag).

Two on-disk layouts are accepted, mirroring OTF2's anchor-plus-streams:

* single file: one JSON object with ``definitions`` and ``events`` keyed by
  location id;
* directory: ``definitions.json`` + ``locations/<id>.json`` one stream per
  file — this is the layout the parallel reader (paper §VI) fans out over.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.constants import (ENTER, ET, INSTANT, LEAVE, MPI_RECV, MPI_SEND,
                              MSG_SIZE, NAME, PARTNER, PROC, TAG, THREAD, TS)
from ..core.errors import (IngestReport, TraceReadError, check_on_error,
                           require_nonempty)
from ..core.frame import Categorical, EventFrame, optimize_dtypes
from ..core.registry import (PlanHints, ProcSpan, even_groups,
                             register_chunked, register_reader,
                             register_units)
from ..core.trace import Trace

_ET_CATS = np.asarray([ENTER, LEAVE, INSTANT])


def _sniff_otf2j(path: str, head: str) -> bool:
    if os.path.isdir(path):
        return os.path.exists(os.path.join(path, "definitions.json"))
    return '"definitions"' in head and '"strings"' in head


def _stream_to_columns(loc: dict, events: List[list], strings: List[str],
                       regions: List[dict]):
    """Decode one location's event stream into column lists."""
    n = len(events)
    ts = np.empty(n, np.int64)
    et = np.empty(n, np.int32)
    name_code = np.empty(n, np.int64)  # index into regions, or -1 for msgs
    sizes = np.full(n, np.nan)
    partners = np.full(n, -1, np.int64)
    tags = np.zeros(n, np.int64)
    is_send = np.zeros(n, bool)
    is_recv = np.zeros(n, bool)
    for i, rec in enumerate(events):
        try:
            ts[i] = rec[0]
            kind = rec[1]
            if kind == "E":
                et[i] = 0
                if not 0 <= int(rec[2]) < len(regions):
                    raise ValueError(f"region ref {rec[2]} out of range")
                name_code[i] = rec[2]
            elif kind == "L":
                et[i] = 1
                if not 0 <= int(rec[2]) < len(regions):
                    raise ValueError(f"region ref {rec[2]} out of range")
                name_code[i] = rec[2]
            elif kind == "S":
                et[i] = 2
                name_code[i] = -1
                is_send[i] = True
                partners[i] = rec[2]
                sizes[i] = rec[3]
                tags[i] = rec[4] if len(rec) > 4 else 0
            elif kind == "R":
                et[i] = 2
                name_code[i] = -1
                is_recv[i] = True
                partners[i] = rec[2]
                sizes[i] = rec[3]
                tags[i] = rec[4] if len(rec) > 4 else 0
            else:  # metric/other -> instant named by region ref
                et[i] = 2
                name_code[i] = (rec[2] if len(rec) > 2
                                and 0 <= int(rec[2]) < len(regions) else -1)
        except (ValueError, TypeError, IndexError, KeyError) as e:
            raise ValueError(f"record {i}: {e}") from e
    region_names = np.asarray(
        [strings[r["name"]] if isinstance(r, dict) else strings[r] for r in regions]
        + [MPI_SEND, MPI_RECV], dtype=object)
    code = np.where(is_send, len(regions), np.where(is_recv, len(regions) + 1,
                                                    np.maximum(name_code, 0)))
    names = region_names[code]
    return ts, et, names, sizes, partners, tags


def _unpack_definitions(doc, path: str = "<doc>"):
    """The (strings, regions, locations) triple from an archive document.
    Definitions are the anchor every stream decodes against — a damaged
    table is never skippable, so structural faults raise regardless of
    the ``on_error`` policy."""
    try:
        defs = doc["definitions"]
        return defs, defs["strings"], defs["regions"], defs["locations"]
    except (KeyError, TypeError) as e:
        raise TraceReadError(
            path, f"corrupt OTF2 definitions (missing or bad {e})") from e


def _decode_archive(doc: dict, label: Optional[str], locations_subset=None,
                    path: str = "<doc>", on_error: str = "strict",
                    report: Optional[IngestReport] = None) -> Trace:
    defs, strings, regions, locs = _unpack_definitions(doc, path)
    all_cols: Dict[str, list] = {k: [] for k in
                                 (TS, ET, NAME, PROC, THREAD, MSG_SIZE, PARTNER, TAG)}
    for loc in locs:
        try:
            lid = str(loc["id"])
            rank = int(loc["group"])
        except (KeyError, TypeError) as e:
            raise TraceReadError(
                path, f"corrupt OTF2 location table entry ({e})") from e
        if locations_subset is not None and lid not in locations_subset:
            continue
        stream = doc["events"].get(lid, [])
        try:
            ts, et, names, sizes, partners, tags = _stream_to_columns(
                loc, stream, strings, regions)
        except (ValueError, TypeError, IndexError, KeyError) as e:
            if on_error == "strict":
                raise TraceReadError(path, f"malformed event stream ({e})",
                                     locus=f"location {lid}") from e
            if report is not None:
                report.skip(path, 1, f"location {lid}",
                            f"location dropped ({e})")
            continue
        n = len(ts)
        if report is not None:
            report.add_rows(path, n)
        all_cols[TS].append(ts)
        all_cols[ET].append(et)
        all_cols[NAME].append(names)
        all_cols[PROC].append(np.full(n, rank, np.int64))
        all_cols[THREAD].append(np.full(n, loc.get("thread", 0), np.int64))
        all_cols[MSG_SIZE].append(sizes)
        all_cols[PARTNER].append(partners)
        all_cols[TAG].append(tags)
    if not all_cols[TS]:
        return Trace(EventFrame(), label=label)
    ev = EventFrame({
        TS: np.concatenate(all_cols[TS]),
        ET: Categorical.from_codes(np.concatenate(all_cols[ET]).astype(np.int32),
                                   _ET_CATS),
        NAME: np.concatenate(all_cols[NAME]),
        PROC: np.concatenate(all_cols[PROC]),
        THREAD: np.concatenate(all_cols[THREAD]),
        MSG_SIZE: np.concatenate(all_cols[MSG_SIZE]),
        PARTNER: np.concatenate(all_cols[PARTNER]),
        TAG: np.concatenate(all_cols[TAG]),
    })
    # canonical order: (process, thread, time) — stable for matching
    ev = ev.sort_by([PROC, THREAD, TS])
    return Trace(optimize_dtypes(ev), definitions=defs, label=label)


def _load_definitions(anchor: str) -> dict:
    """Load and parse ``definitions.json`` — always strict (see
    :func:`_unpack_definitions`)."""
    if not os.path.exists(anchor):
        raise TraceReadError(anchor, "missing definitions.json — not an "
                                     "OTF2-structured archive")
    require_nonempty(anchor, os.path.getsize(anchor),
                     what="OTF2 definitions table")
    try:
        with open(anchor) as f:
            return json.load(f)
    except ValueError as e:
        locus = (f"line {e.lineno}"
                 if isinstance(e, json.JSONDecodeError) else None)
        raise TraceReadError(anchor, f"corrupt definitions JSON ({e})",
                             locus=locus) from e


@register_reader("otf2j", extensions=(".otf2.json",), sniff=_sniff_otf2j,
                 priority=20)
def read_otf2_json(path: str, label: Optional[str] = None,
                   locations_subset=None, on_error: str = "strict",
                   report: Optional[IngestReport] = None) -> Trace:
    check_on_error(on_error, ("strict", "skip"))
    rpt = report if report is not None else IngestReport()
    label = label or path
    rpt.begin(path)
    if os.path.isdir(path):
        defs = _load_definitions(os.path.join(path, "definitions.json"))
        events = {}
        locdir = os.path.join(path, "locations")
        names = sorted(os.listdir(locdir)) if os.path.isdir(locdir) else []
        for fn in names:
            lid = os.path.splitext(fn)[0]
            if locations_subset is not None and lid not in locations_subset:
                continue
            fp = os.path.join(locdir, fn)
            try:
                require_nonempty(fp, os.path.getsize(fp),
                                 what="OTF2 location stream")
                with open(fp) as f:
                    events[lid] = json.load(f)
            except (ValueError, OSError) as e:
                if on_error == "strict":
                    if isinstance(e, TraceReadError):
                        raise
                    raise TraceReadError(
                        fp, f"corrupt location stream ({e})") from e
                rpt.skip(fp, 1, "", f"location stream dropped ({e})")
        doc = {"definitions": defs, "events": events}
    else:
        require_nonempty(path, os.path.getsize(path),
                         what="OTF2-structured trace")
        try:
            with open(path) as f:
                doc = json.load(f)
        except ValueError as e:
            if on_error == "strict":
                locus = (f"line {e.lineno}"
                         if isinstance(e, json.JSONDecodeError) else None)
                raise TraceReadError(path, f"corrupt archive JSON ({e})",
                                     locus=locus) from e
            rpt.lose_bytes(path, os.path.getsize(path), "",
                           f"corrupt archive JSON ({e})")
            t = Trace(EventFrame(), label=label)
            t._ingest = rpt
            return t
    t = _decode_archive(doc, label, locations_subset, path=path,
                        on_error=on_error, report=rpt)
    t._ingest = rpt
    return t


def _location_frame(loc: dict, stream: List[list], strings, regions
                    ) -> EventFrame:
    ts, et, names, sizes, partners, tags = _stream_to_columns(
        loc, stream, strings, regions)
    n = len(ts)
    return EventFrame({
        TS: ts,
        ET: Categorical.from_codes(et, _ET_CATS),
        NAME: names,
        PROC: np.full(n, loc["group"], np.int64),
        THREAD: np.full(n, loc.get("thread", 0), np.int64),
        MSG_SIZE: sizes,
        PARTNER: partners,
        TAG: tags,
    })


@register_chunked("otf2j")
def iter_chunks_otf2j(path: str, chunk_rows: int,
                      hints: Optional[PlanHints] = None,
                      label: Optional[str] = None,
                      locations_subset=None, on_error: str = "strict",
                      report: Optional[IngestReport] = None):
    """Stream an OTF2-structured archive location by location.

    The directory layout (``definitions.json`` + ``locations/<id>.json``) is
    the truly out-of-core path: one location stream in memory at a time,
    and locations whose rank the plan excludes are *never opened* (process
    pushdown at file granularity).  A single-file archive is decoded whole
    but still yielded in bounded slices.

    ``on_error="skip"`` drops corrupt location streams (counted per
    location in ``report``) — the same per-location decision the eager
    reader makes, so survivors match across execution modes.  A corrupt
    definitions table always raises.
    """
    check_on_error(on_error, ("strict", "skip"))
    if report is not None:
        report.begin(path)
    is_dir = os.path.isdir(path)
    if is_dir:
        defs = _load_definitions(os.path.join(path, "definitions.json"))
        doc = None
    else:
        require_nonempty(path, os.path.getsize(path),
                         what="OTF2-structured trace")
        try:
            with open(path) as f:
                doc = json.load(f)
        except ValueError as e:
            if on_error == "strict":
                locus = (f"line {e.lineno}"
                         if isinstance(e, json.JSONDecodeError) else None)
                raise TraceReadError(path, f"corrupt archive JSON ({e})",
                                     locus=locus) from e
            if report is not None:
                report.lose_bytes(path, os.path.getsize(path), "",
                                  f"corrupt archive JSON ({e})")
            return
    _, strings, regions, locs = _unpack_definitions(
        {"definitions": defs} if is_dir else doc, path)
    tw = hints.time_window if hints is not None else None
    for loc in locs:
        try:
            lid = str(loc["id"])
            rank = int(loc["group"])
        except (KeyError, TypeError) as e:
            raise TraceReadError(
                path, f"corrupt OTF2 location table entry ({e})") from e
        if locations_subset is not None and lid not in locations_subset:
            continue
        if hints is not None and not hints.admits_proc(rank):
            continue
        if is_dir:
            fn = os.path.join(path, "locations", f"{lid}.json")
            if not os.path.exists(fn):
                continue
            try:
                require_nonempty(fn, os.path.getsize(fn),
                                 what="OTF2 location stream")
                with open(fn) as f:
                    stream = json.load(f)
            except (ValueError, OSError) as e:
                if on_error == "strict":
                    if isinstance(e, TraceReadError):
                        raise
                    raise TraceReadError(
                        fn, f"corrupt location stream ({e})") from e
                if report is not None:
                    report.skip(fn, 1, "",
                                f"location stream dropped ({e})")
                continue
        else:
            stream = doc["events"].get(lid, [])
        if not stream:
            continue
        try:
            ev = optimize_dtypes(
                _location_frame(loc, stream, strings, regions))
        except (ValueError, TypeError, IndexError, KeyError) as e:
            if on_error == "strict":
                raise TraceReadError(path, f"malformed event stream ({e})",
                                     locus=f"location {lid}") from e
            if report is not None:
                report.skip(path, 1, f"location {lid}",
                            f"location dropped ({e})")
            continue
        if report is not None:
            report.add_rows(path, len(ev))
        if tw is not None:
            ts = np.asarray(ev[TS], np.float64)
            ev = ev.mask((ts >= tw[0]) & (ts <= tw[1]))
        for lo in range(0, len(ev), chunk_rows):
            sub = ev.take(np.arange(lo, min(lo + chunk_rows, len(ev))))
            if len(sub):
                yield sub


@register_units("otf2j")
def plan_units_otf2j(path: str, n_units: int):
    """Per-rank work units for the directory layout: the anchor's location
    table (cheap to read) maps ranks to per-location stream files, so
    disjoint rank groups parallelize with file-level pushdown.  Single-file
    archives decode the whole document per reader call and are not split.
    """
    if not os.path.isdir(path):
        return None
    try:
        with open(os.path.join(path, "definitions.json")) as f:
            defs = json.load(f)
        ranks = sorted({int(loc["group"])
                        for loc in defs.get("locations", [])})
    except (OSError, ValueError, TypeError, KeyError, AttributeError):
        # damaged anchor: no parallel plan — the serial path owns the
        # strict-raise / skip decision
        return None
    n = max(min(int(n_units), len(ranks)), 1)
    if n <= 1:
        return None
    return [ProcSpan(path, procs) for procs in even_groups(ranks, n)]


def write_otf2_json(trace_or_events, path: str, split_locations: bool = False) -> None:
    """Serialize a trace into the OTF2-structured archive (inverse reader)."""
    ev = getattr(trace_or_events, "events", trace_or_events)
    procs = np.asarray(ev[PROC], np.int64)
    threads = np.asarray(ev[THREAD], np.int64) if THREAD in ev else np.zeros_like(procs)
    ts = np.asarray(ev[TS], np.int64)
    names = ev[NAME]
    et = ev[ET]
    sizes = np.asarray(ev[MSG_SIZE], np.float64) if MSG_SIZE in ev else np.full(len(ev), np.nan)
    partners = np.asarray(ev[PARTNER], np.int64) if PARTNER in ev else np.full(len(ev), -1)
    tags = np.asarray(ev[TAG], np.int64) if TAG in ev else np.zeros(len(ev), np.int64)

    uniq_names = sorted({str(n) for n, e in zip(names, et) if e in (ENTER, LEAVE)})
    string_of = {n: i for i, n in enumerate(uniq_names)}
    strings = uniq_names
    regions = [{"name": i} for i in range(len(uniq_names))]

    loc_key = procs * (threads.max() + 1 if len(threads) else 1) + threads
    uniq_locs = np.unique(loc_key)
    locations = []
    events: Dict[str, list] = {}
    for li, lk in enumerate(uniq_locs):
        rows = np.nonzero(loc_key == lk)[0]
        rows = rows[np.argsort(ts[rows], kind="stable")]
        locations.append({"id": li, "group": int(procs[rows[0]]),
                          "thread": int(threads[rows[0]])})
        stream = []
        for r in rows:
            e = et[r]
            nm = str(names[r])
            if e == ENTER:
                stream.append([int(ts[r]), "E", string_of[nm]])
            elif e == LEAVE:
                stream.append([int(ts[r]), "L", string_of[nm]])
            elif nm == MPI_SEND:
                stream.append([int(ts[r]), "S", int(partners[r]),
                               float(np.nan_to_num(sizes[r])), int(tags[r])])
            elif nm == MPI_RECV:
                stream.append([int(ts[r]), "R", int(partners[r]),
                               float(np.nan_to_num(sizes[r])), int(tags[r])])
        events[str(li)] = stream
    defs = {"strings": strings, "regions": regions, "locations": locations}
    if split_locations:
        os.makedirs(os.path.join(path, "locations"), exist_ok=True)
        with open(os.path.join(path, "definitions.json"), "w") as f:
            json.dump(defs, f)
        for lid, stream in events.items():
            with open(os.path.join(path, "locations", f"{lid}.json"), "w") as f:
                json.dump(stream, f)
    else:
        with open(path, "w") as f:
            json.dump({"definitions": defs, "events": events}, f)
