"""pipitpack — the native columnar binary trace store (parse once, mmap ever
after), with per-chunk integrity and salvage.

Every other format we read is *text*: re-opening a 10M-event trace means
re-decoding hundreds of MB of JSON/CSV before the first vectorized kernel
runs, and that decode dominates cache-miss execution end to end.  A pack
file stores the uniform data model (paper Fig. 1) as little-endian
per-column arrays plus a small JSON footer holding:

* the **name table** (``Name`` is stored as int32 codes),
* the **chunk index**: fixed-row chunks with each chunk's row range, time
  range, process set, byte span and CRC-32 — chunked/streaming reads skip
  chunks a plan's time-window or process restriction provably cannot need
  *without touching their bytes* (index pushdown),
* an optional **structure sidecar**: matching / depth / parent / inc / exc
  computed once at pack time, so reopening skips ``derive_structure``
  entirely (eager opens attach the columns; streaming chunks carry
  row-localized slices the :class:`~repro.core.streaming.CallStitcher`
  consumes instead of re-deriving per chunk),
* a **content id** (SHA-256 over all column + sidecar bytes) — the
  plan-result cache (:mod:`repro.core.plancache`) keys pack sources by it,
  so copies and rewrites with identical content share cache entries.

Format version 2 file layout (version 1, whole-file column-major, is still
fully readable)::

    #pipitpack 2\\n                      ASCII magic line (sniffable)
    <chunk group 0> <chunk group 1> ...  one group per index chunk
    <sidecar arrays, back to back>       (optional)
    <footer JSON, utf-8>
    <footer length, uint64 LE> <b"PIPITPK\\0">   last 16 bytes

where each **chunk group** is self-describing and individually verifiable::

    <column slices for this chunk's rows, back to back>
    <trailer JSON>                       seq, row range, ts range, procs,
                                         column sizes, names first interned
                                         in this chunk
    <trailer length, uint32 LE> <CRC-32, uint32 LE> <b"PPKCHNK\\n">

The CRC covers the column slices plus the trailer, so a bit flip anywhere
in a group is detected; the trailing group magic makes groups discoverable
by scanning even when the footer itself is lost (a torn write, a crashed
writer, a truncated copy).  That scan is the salvage path: the name table
is rebuilt incrementally from each trailer's ``new_names``, so every chunk
that checksums clean is recovered **byte-identically**.

``on_error`` open policies (``read_pack`` / ``iter_chunks_pack``):

* ``"strict"`` (default) — no checksum pass; structural damage raises
  :class:`~repro.core.errors.TraceReadError` with the file and byte offset.
* ``"skip_chunk"`` — footer must be intact; every chunk group is CRC
  verified and failing groups are dropped (quarantined) with a warning.
* ``"salvage"`` — like ``skip_chunk``, but a lost/corrupt footer triggers
  the trailer scan instead of failing.  Recovers every intact chunk from a
  truncated or bit-flipped pack.

Quarantine counters surface in :func:`io_stats`; ``tools/pack.py --verify
--repair`` wraps :func:`verify_pack` / :func:`repair_pack`.

Write paths: ``Trace.save_pack(path)`` / ``write_pack`` (in-memory),
``StreamingTrace.save_pack`` / :class:`PackWriter` (out-of-core append —
one chunk group is buffered at a time, then written with its trailer), and
``tools/pack.py`` (the CLI converter for any registered format).
``PackWriter(path, atomic=False)`` writes groups straight to ``path`` so a
killed writer leaves a salvageable prefix — the crash-consistency mode
``tracegen.big_trace`` uses.
"""

from __future__ import annotations

import hashlib
import json
import mmap
import os
import struct
import tempfile
import warnings
import zlib
from typing import Any, Dict, Iterator, List, Optional, Set, Tuple

import numpy as np

from ..core import structure
from ..core.constants import (DEPTH, ENTER, ET, EXC, INC, INSTANT, LEAVE,
                              MATCH, MATCH_TS, MSG_SIZE, NAME, PARENT,
                              PARTNER, PROC, TAG, THREAD, TS)
from ..core.errors import TraceReadError, check_on_error, require_nonempty
from ..core.frame import Categorical, EventFrame
from ..core.registry import (PlanHints, RowSpan, even_groups,
                             register_chunked, register_reader,
                             register_units)
from ..core.trace import Trace

__all__ = ["write_pack", "read_pack", "PackWriter", "read_footer",
           "content_id", "io_stats", "reset_io_stats", "verify_pack",
           "repair_pack", "scan_chunk_groups", "committed_prefix",
           "DEFAULT_PACK_CHUNK_ROWS"]

MAGIC = b"#pipitpack 1\n"
MAGIC2 = b"#pipitpack 2\n"
MAGIC_PREFIX = b"#pipitpack "
TAIL_MAGIC = b"PIPITPK\x00"
CHUNK_MAGIC = b"PPKCHNK\n"
VERSION = 2
DEFAULT_PACK_CHUNK_ROWS = 250_000

_ET_CODE = {ENTER: 0, LEAVE: 1, INSTANT: 2}
_ET_CATS = np.asarray([ENTER, LEAVE, INSTANT])

#: (footer key, canonical column, on-disk dtype) — event columns in file order
_EVENT_COLS = (
    ("ts", TS, "<i8"),
    ("et", ET, "<i1"),
    ("name", NAME, "<i4"),
    ("proc", PROC, "<i4"),
    ("thread", THREAD, "<i4"),
    ("size", MSG_SIZE, "<f8"),
    ("partner", PARTNER, "<i4"),
    ("tag", TAG, "<i4"),
)
_COL_DTYPE = {k: d for k, _c, d in _EVENT_COLS}
#: fill value for an optional column a chunk group did not store
_COL_FILL = {"thread": 0, "size": np.nan, "partner": -1, "tag": 0}
#: sidecar arrays (footer key, canonical column, dtype)
_SIDECAR_COLS = (
    ("matching", MATCH, "<i8"),
    ("depth", DEPTH, "<i4"),
    ("parent", PARENT, "<i8"),
    ("inc", INC, "<f8"),
    ("exc", EXC, "<f8"),
)

_ON_ERROR_MODES = ("strict", "skip_chunk", "salvage")


# ---------------------------------------------------------------------------
# io accounting (tests / benchmarks assert pushdown actually skips chunks,
# and the fault suite asserts salvage quarantines exactly the damaged ones)
# ---------------------------------------------------------------------------

_IO_STATS = {"chunks_read": 0, "chunks_skipped": 0, "chunks_quarantined": 0,
             "footers_rebuilt": 0, "sidecars_dropped": 0,
             "verify_cache_hits": 0}

#: aspects ("chunks", "sidecar") whose CRC sweep passed, keyed by
#: (abspath, size, mtime_ns, inode, committed-group count) — a
#: verified-clean file needs no re-sweep until it changes on disk, so
#: steady-state verifying reopens (service handle revalidation, repeated
#: queries) cost the same as a strict open.  The group count is part of
#: the key because append workloads can grow a pack within one mtime
#: granule on coarse-mtime filesystems; size alone is not enough once a
#: finalize rewrites the tail in place.  Failures are never cached:
#: damage is re-diagnosed on every open.
_VERIFIED_CLEAN: Dict[tuple, set] = {}
_VERIFIED_CLEAN_MAX = 256


def _verify_key(path: str, st: os.stat_result, n_groups: int = -1) -> tuple:
    return (os.path.abspath(path), st.st_size, st.st_mtime_ns, st.st_ino,
            int(n_groups))


def _mark_verified(key: tuple, aspect: str) -> None:
    if key not in _VERIFIED_CLEAN and \
            len(_VERIFIED_CLEAN) >= _VERIFIED_CLEAN_MAX:
        _VERIFIED_CLEAN.clear()
    _VERIFIED_CLEAN.setdefault(key, set()).add(aspect)


def io_stats() -> Dict[str, int]:
    """Process-local counters since the last :func:`reset_io_stats`
    (advisory; parallel pool workers count in their own process):
    footer-index chunks read vs skipped by pushdown, plus the fault-path
    counters — chunks quarantined by CRC/scan failure, footers rebuilt by
    trailer scan, sidecars dropped as corrupt."""
    return dict(_IO_STATS)


def reset_io_stats() -> None:
    for k in _IO_STATS:
        _IO_STATS[k] = 0


# ---------------------------------------------------------------------------
# footer access
# ---------------------------------------------------------------------------

_FOOTER_CACHE: Dict[str, Tuple[Tuple[int, int], dict]] = {}


def read_footer(path: str) -> dict:
    """Parse and return the footer of ``path`` (cached per (size, mtime)).

    Raises :class:`TraceReadError` (a ValueError) when the file is not a
    readable pack, always naming the path and what was wrong.
    """
    path = os.fspath(path)
    st = os.stat(path)
    if st.st_size == 0:
        raise TraceReadError(path, "empty file (0 bytes) — not a pack")
    key = (st.st_size, st.st_mtime_ns)
    hit = _FOOTER_CACHE.get(path)
    if hit is not None and hit[0] == key:
        return hit[1]
    with open(path, "rb") as f:
        head = f.read(len(MAGIC))
        if not head.startswith(MAGIC_PREFIX):
            raise TraceReadError(path, "not a pipitpack file")
        if head not in (MAGIC, MAGIC2):
            raise TraceReadError(
                path, f"unsupported pack version {head[len(MAGIC_PREFIX):]!r}"
                      f" (this reader supports 1 and {VERSION})")
        if st.st_size < len(MAGIC) + 16:
            raise TraceReadError(path, "truncated pack (no footer)")
        f.seek(-16, os.SEEK_END)
        flen, tail = struct.unpack("<Q", f.read(8))[0], f.read(8)
        if tail != TAIL_MAGIC:
            raise TraceReadError(path, "bad pack trailer (truncated write?)")
        if flen > st.st_size - len(MAGIC) - 16:
            raise TraceReadError(path, "bad pack trailer (footer length "
                                       "exceeds file)")
        f.seek(st.st_size - 16 - flen)
        try:
            footer = json.loads(f.read(flen).decode("utf-8"))
        except (ValueError, UnicodeDecodeError) as e:
            raise TraceReadError(path, f"corrupt pack footer ({e})") from e
    if footer.get("version") not in (1, VERSION):
        raise TraceReadError(path, f"unsupported pack version "
                                   f"{footer.get('version')!r} (this reader "
                                   f"supports 1 and {VERSION})")
    if len(_FOOTER_CACHE) > 256:
        _FOOTER_CACHE.clear()
    _FOOTER_CACHE[path] = (key, footer)
    return footer


def is_pack(path: str) -> bool:
    try:
        with open(path, "rb") as f:
            return f.read(len(MAGIC_PREFIX)) == MAGIC_PREFIX
    except OSError:
        return False


def content_id(path: str) -> Optional[str]:
    """The pack's stored content id (SHA-256 over column + sidecar bytes),
    or None when ``path`` is not a readable pack.  Footer-only read — the
    plan cache calls this per terminal op."""
    try:
        if not is_pack(path):
            return None
        return read_footer(path).get("content_id")
    except (OSError, ValueError):
        return None


# ---------------------------------------------------------------------------
# writer
# ---------------------------------------------------------------------------

def _int_column(arr: np.ndarray, dtype: str, what: str) -> np.ndarray:
    out = np.asarray(arr)
    info = np.iinfo(np.dtype(dtype))
    if len(out) and (out.min() < info.min or out.max() > info.max):
        raise ValueError(f"pack {what} column value out of {dtype} range "
                         f"[{info.min}, {info.max}]")
    return out.astype(dtype, copy=False)


def _et_codes(ev: EventFrame) -> np.ndarray:
    """Canonical 0/1/2 Enter/Leave/Instant codes; richer instant subtypes
    (MpiSend/...) render as plain instants, like every on-disk format."""
    col = ev.column(ET)
    if isinstance(col, Categorical):
        remap = np.asarray([_ET_CODE.get(str(c), 2) for c in col.categories],
                           np.int8)
        return remap[col.codes]
    return np.asarray([_ET_CODE.get(str(v), 2) for v in np.asarray(col)],
                      np.int8)


class PackWriter:
    """Out-of-core pack writer: append EventFrames in stream order, then
    :meth:`finish`.  One chunk group (``chunk_rows`` rows) is buffered at a
    time and written with its CRC'd trailer as soon as it fills, so memory
    stays bounded and every already-written group is recoverable even if
    the process dies; the chunk index, name interner and content hash
    accumulate as groups are flushed.

    ``atomic=True`` (default) stages the file next to ``path`` and
    ``os.replace``\\ s it at finish — no partial pack ever lands.
    ``atomic=False`` writes straight to ``path``: a crash mid-write leaves
    a footer-less prefix that ``on_error="salvage"`` / ``tools/pack.py
    --repair`` recovers group by group (the live-ingestion / crash
    -consistency mode).

    Usable as a context manager: leaving the ``with`` block without having
    called :meth:`finish` (including via an exception) aborts the write —
    except in append mode, where the committed prefix is durable data and
    abort merely closes the file.

    **Append mode** (:meth:`open_append`): the writer targets ``path``
    in place and exposes :meth:`commit`.  Each commit flushes the
    buffered rows as one self-describing chunk group — the CRC'd trailer
    *is* the commit record — and (with ``fsync=True``) makes it durable,
    so a reader at any instant sees exactly the committed prefix and a
    SIGKILLed writer loses at most the uncommitted tail.
    :func:`committed_prefix` / ``live=True`` reads consume that prefix
    while the writer is still running; :meth:`finalize` seals the footer
    (after which the file is a perfectly ordinary pack).  Reopening an
    existing append shard resumes after its last committed group,
    truncating any uncommitted tail (and, when resuming a *finalized*
    pack, its footer/sidecar — a new finalize rewrites them).

    Timestamps are stored as integer nanoseconds; float timestamps
    quantize by truncation, exactly like every text writer in this repo
    (``write_jsonl``'s ``int(ts)``).  The structure sidecar is always
    consistent with the *stored* values.
    """

    def __init__(self, path: str, chunk_rows: int = DEFAULT_PACK_CHUNK_ROWS,
                 atomic: bool = True, append: bool = False,
                 fsync: bool = False):
        self.path = os.fspath(path)
        self.chunk_rows = int(chunk_rows)
        if self.chunk_rows <= 0:
            raise ValueError("chunk_rows must be positive")
        self.append_mode = bool(append)
        self.atomic = bool(atomic) and not self.append_mode
        self._fsync = bool(fsync)
        self._buf: List[Dict[str, np.ndarray]] = []
        self._buf_rows = 0
        self._flushed = 0  # rows written out in finalized groups
        self._name_code: Dict[str, int] = {}
        self._names: List[str] = []
        self._names_written = 0  # names already recorded by an earlier trailer
        self._chunks: List[dict] = []  # finalized chunk index records
        self._has_thread = False
        self._has_messages = False
        self._hash = hashlib.sha256()
        self._finished = False
        d = os.path.dirname(os.path.abspath(self.path)) or "."
        if self.atomic:
            fd, self._tmp = tempfile.mkstemp(prefix=".pack_tmp_", dir=d)
            self._out = os.fdopen(fd, "wb")
        else:
            self._tmp = self.path
            if self.append_mode and os.path.exists(self.path) \
                    and os.path.getsize(self.path) > 0:
                self._resume()
                return
            self._out = open(self.path, "wb")
        self._out.write(MAGIC2)
        self._off = len(MAGIC2)

    @classmethod
    def open_append(cls, path: str,
                    chunk_rows: int = DEFAULT_PACK_CHUNK_ROWS,
                    fsync: bool = True) -> "PackWriter":
        """Open ``path`` as an append-mode shard (creating it if absent,
        resuming after its last committed group otherwise).  ``fsync=True``
        (default) makes every :meth:`commit` durable before it returns —
        the crash-consistency contract live readers rely on."""
        return cls(path, chunk_rows=chunk_rows, atomic=False, append=True,
                   fsync=fsync)

    def _resume(self) -> None:
        """Rebuild writer state from ``path``'s committed prefix and
        truncate the uncommitted tail (or the footer/sidecar of a
        finalized pack being reopened for append)."""
        snap = committed_prefix(self.path)
        self._chunks = [dict(c) for c in snap["chunks"]]
        self._names = list(snap["names"])
        self._name_code = {s: i for i, s in enumerate(self._names)}
        self._names_written = len(self._names)
        self._flushed = snap["rows"]
        self._has_thread = bool(snap["has_thread"])
        self._has_messages = bool(snap["has_messages"])
        if self._chunks:
            last = self._chunks[-1]
            self._off = (last["offset"] + last["nbytes"] + last["tlen"]
                         + 8 + len(CHUNK_MAGIC))
        else:
            self._off = len(MAGIC2)
        self._out = open(self.path, "r+b")
        # re-feed the content hash with the committed column bytes so a
        # later finalize produces the same content_id a fresh writer would
        for ch in self._chunks:
            self._out.seek(ch["offset"])
            self._hash.update(self._out.read(ch["nbytes"]))
        self._out.seek(self._off)
        self._out.truncate(self._off)
        _FOOTER_CACHE.pop(self.path, None)
        _LIVE_SCAN.pop(os.path.abspath(self.path), None)

    @property
    def watermark(self) -> dict:
        """The committed watermark of this writer: rows/groups durable on
        disk (buffered-but-uncommitted rows are *not* included)."""
        return {"rows": self._flushed, "groups": len(self._chunks),
                "ts_min": (min(c["ts_min"] for c in self._chunks)
                           if self._chunks else None),
                "ts_max": (max(c["ts_max"] for c in self._chunks)
                           if self._chunks else None),
                "bytes": self._off, "finalized": self._finished}

    def commit(self) -> dict:
        """Flush all buffered rows as one committed chunk group and make
        it durable (``fsync=True`` writers).  The group trailer + CRC +
        magic are the commit record: once they hit the disk, the group is
        part of the committed prefix every concurrent/live reader sees.
        Returns the new :attr:`watermark`.  A commit with no buffered
        rows just syncs and returns the current watermark."""
        if self._finished:
            raise RuntimeError("PackWriter already finished")
        if self._buf_rows:
            self._flush_group(self._buf_rows)
        self._out.flush()
        if self._fsync:
            os.fsync(self._out.fileno())
        return self.watermark

    # -- context manager ---------------------------------------------------
    def __enter__(self) -> "PackWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self._finished:
            self.abort()

    # -- append ------------------------------------------------------------
    def append(self, frame_or_trace) -> None:
        """Append one EventFrame (or Trace) worth of events, in stream
        order.  Missing optional columns (thread / message triplet) are
        synthesized; name codes are re-interned into the file-global
        table."""
        ev = getattr(frame_or_trace, "events", frame_or_trace)
        n = len(ev)
        if n == 0:
            return
        ts = _int_column(ev[TS], "<i8", "ts")
        et = _et_codes(ev)
        name = self._intern(ev)
        proc = _int_column(ev[PROC], "<i4", "proc")
        if THREAD in ev:
            thread = _int_column(ev[THREAD], "<i4", "thread")
        else:
            thread = np.zeros(n, "<i4")
        if MSG_SIZE in ev:
            size = np.asarray(ev[MSG_SIZE], np.float64).astype("<f8",
                                                               copy=False)
        else:
            size = np.full(n, np.nan, "<f8")
        if PARTNER in ev:
            partner = _int_column(ev[PARTNER], "<i4", "partner")
        else:
            partner = np.full(n, -1, "<i4")
        if TAG in ev:
            tag = _int_column(ev[TAG], "<i4", "tag")
        else:
            tag = np.zeros(n, "<i4")
        self._buf.append({"ts": ts, "et": et, "name": name, "proc": proc,
                          "thread": thread, "size": size, "partner": partner,
                          "tag": tag})
        self._buf_rows += n
        while self._buf_rows >= self.chunk_rows:
            self._flush_group(self.chunk_rows)

    def _intern(self, ev: EventFrame) -> np.ndarray:
        cat = ev.cat(NAME)
        local = np.empty(len(cat.categories), np.int32)
        for i, c in enumerate(cat.categories):
            s = str(c)
            g = self._name_code.get(s)
            if g is None:
                g = len(self._names)
                self._name_code[s] = g
                self._names.append(s)
            local[i] = g
        return local[cat.codes].astype("<i4", copy=False)

    def _take(self, nrows: int) -> Dict[str, np.ndarray]:
        """Pop exactly ``nrows`` buffered rows (front of the stream)."""
        parts: Dict[str, List[np.ndarray]] = {k: [] for k, _c, _d
                                              in _EVENT_COLS}
        need = nrows
        while need:
            blk = self._buf[0]
            bn = len(blk["ts"])
            if bn <= need:
                for k in parts:
                    parts[k].append(blk[k])
                self._buf.pop(0)
                need -= bn
            else:
                for k in parts:
                    parts[k].append(blk[k][:need])
                    blk[k] = blk[k][need:]
                need = 0
        self._buf_rows -= nrows
        return {k: (v[0] if len(v) == 1 else np.concatenate(v))
                for k, v in parts.items()}

    def _flush_group(self, nrows: int) -> None:
        """Write one self-describing chunk group: column slices, trailer,
        (length, CRC-32) and the group magic."""
        cols = self._take(nrows)
        n = len(cols["ts"])
        thread_any = bool(np.any(cols["thread"]))
        msg_any = bool(np.any(~np.isnan(cols["size"]))
                       or np.any(cols["partner"] >= 0))
        keep = {"ts": True, "et": True, "name": True, "proc": True,
                "thread": thread_any, "size": msg_any, "partner": msg_any,
                "tag": msg_any}
        blobs: List[bytes] = []
        colmeta: List[list] = []
        for key, _c, dt in _EVENT_COLS:
            if not keep[key]:
                continue
            b = np.ascontiguousarray(
                cols[key].astype(dt, copy=False)).tobytes()
            blobs.append(b)
            colmeta.append([key, dt, len(b)])
        data = b"".join(blobs)
        trailer = {
            "seq": len(self._chunks), "lo": self._flushed, "rows": n,
            "ts_min": int(cols["ts"].min()), "ts_max": int(cols["ts"].max()),
            "procs": sorted(int(p) for p in np.unique(cols["proc"]).tolist()),
            "cols": colmeta, "name_base": self._names_written,
            "new_names": self._names[self._names_written:],
        }
        tblob = json.dumps(trailer, separators=(",", ":")).encode("utf-8")
        crc = zlib.crc32(tblob, zlib.crc32(data))
        off = self._off
        self._out.write(data)
        self._out.write(tblob)
        self._out.write(struct.pack("<II", len(tblob), crc))
        self._out.write(CHUNK_MAGIC)
        self._hash.update(data)
        self._chunks.append({
            "lo": self._flushed, "hi": self._flushed + n,
            "ts_min": trailer["ts_min"], "ts_max": trailer["ts_max"],
            "procs": trailer["procs"], "offset": off, "nbytes": len(data),
            "tlen": len(tblob), "crc": crc, "cols": colmeta,
        })
        self._off += len(data) + len(tblob) + 8 + len(CHUNK_MAGIC)
        self._flushed += n
        self._names_written = len(self._names)
        self._has_thread = self._has_thread or thread_any
        self._has_messages = self._has_messages or msg_any

    # -- finish ------------------------------------------------------------
    def abort(self) -> None:
        """Discard the partial write (atomic staging file, or the in-place
        partial pack) without finishing.  Append-mode shards are *not*
        unlinked: the committed prefix is durable data — abort just stops
        writing, exactly like a crash after the last commit."""
        self._out.close()
        if not self.append_mode:
            try:
                os.unlink(self._tmp)
            except OSError:
                pass
        self._finished = True

    def finish(self, sidecar: Any = "auto",
               _sidecar_arrays: Optional[dict] = None) -> str:
        """Flush the final partial group, write the sidecar + footer, and
        (in atomic mode) land the file at ``path``.

        ``sidecar=True`` derives the structure sidecar (matching / depth /
        parent / inc / exc) from the just-written groups via a memmap
        pass — this is the only whole-trace step.  ``"auto"`` means True.
        ``_sidecar_arrays`` lets ``write_pack`` hand in structure a Trace
        already materialized.
        """
        if self._finished:
            raise RuntimeError("PackWriter already finished")
        if self._buf_rows:
            self._flush_group(self._buf_rows)
        want_sidecar = bool(sidecar) or _sidecar_arrays is not None
        sidecar_meta = None
        sidecar_crc = None
        if want_sidecar and self._flushed:
            arrays = _sidecar_arrays
            if arrays is None:
                self._out.flush()  # the memmap pass reads the written groups
                arrays = self._derive_sidecar()
            sidecar_meta = []
            crc = 0
            for key, _col, dt in _SIDECAR_COLS:
                arr = np.ascontiguousarray(
                    np.asarray(arrays[key]).astype(dt, copy=False))
                if len(arr) != self._flushed:
                    raise ValueError(
                        f"sidecar {key!r} has {len(arr)} rows, pack has "
                        f"{self._flushed}")
                b = arr.tobytes()
                self._hash.update(b)
                crc = zlib.crc32(b, crc)
                self._out.write(b)
                sidecar_meta.append({"key": key, "dtype": dt,
                                     "offset": self._off})
                self._off += len(b)
            sidecar_crc = crc
        keep = self._store_flags()
        footer = {
            "version": VERSION,
            "rows": self._flushed,
            "chunk_rows": self.chunk_rows,
            "columns": [{"key": k, "dtype": d} for k, _c, d in _EVENT_COLS
                        if keep[k]],
            "names": self._names,
            "has_thread": self._has_thread,
            "has_messages": self._has_messages,
            "chunks": self._chunks,
            "procs": sorted({p for c in self._chunks for p in c["procs"]}),
            "sidecar": sidecar_meta,
            "sidecar_crc": sidecar_crc,
            "content_id": self._hash.hexdigest(),
        }
        blob = json.dumps(footer, separators=(",", ":")).encode("utf-8")
        self._out.write(blob)
        self._out.write(struct.pack("<Q", len(blob)))
        self._out.write(TAIL_MAGIC)
        self._out.flush()
        if self._fsync:
            os.fsync(self._out.fileno())
        self._out.close()
        if self.atomic:
            os.replace(self._tmp, self.path)
        self._finished = True
        _FOOTER_CACHE.pop(self.path, None)
        _LIVE_SCAN.pop(os.path.abspath(self.path), None)
        return self.path

    def finalize(self, sidecar: Any = "auto") -> str:
        """Seal the append shard: flush the remaining buffered rows,
        derive + write the structure sidecar, and write the footer.  The
        file becomes an ordinary finalized pack (strict opens, sidecar
        fast path, content id).  Alias for :meth:`finish` — named for the
        append/finalize protocol."""
        return self.finish(sidecar=sidecar)

    def _store_flags(self) -> Dict[str, bool]:
        """Which optional columns any group stored (footer-level view;
        individual groups record their own column sets)."""
        keep = {k: True for k, _c, _d in _EVENT_COLS}
        keep["thread"] = self._has_thread
        if not self._has_messages:
            keep["size"] = keep["partner"] = keep["tag"] = False
        return keep

    def _derive_sidecar(self) -> dict:
        """One structure pass over the just-written groups (memmapped)."""
        cols = _assemble_columns(self._tmp, self._chunks, self._flushed,
                                 self._has_thread, self._has_messages)
        ev = EventFrame()
        ev[TS] = cols["ts"]
        ev[ET] = Categorical(cols["et"].astype(np.int32), _ET_CATS)
        ev[NAME] = Categorical(cols["name"],
                               np.asarray(self._names,
                                          dtype=object).astype(str))
        ev[PROC] = cols["proc"]
        if self._has_thread:
            ev[THREAD] = cols["thread"]
        if self._has_messages:
            ev[MSG_SIZE] = cols["size"]
            ev[PARTNER] = cols["partner"]
            ev[TAG] = cols["tag"]
        matching, depth, parent, inc, exc = structure.derive_structure(ev)
        return {"matching": matching, "depth": depth, "parent": parent,
                "inc": inc, "exc": exc}


def write_pack(trace_or_events, path: str,
               chunk_rows: int = DEFAULT_PACK_CHUNK_ROWS,
               sidecar: bool = True) -> str:
    """Serialize an in-memory trace (or EventFrame) as one pack file.

    ``sidecar=True`` (default) stores the structure sidecar: the trace's
    already-materialized structure columns are reused when present and
    row-for-row valid; otherwise structure is derived once on the event
    frame (the same pass reopening would pay — paid here exactly once).

    Float timestamps quantize to integer ns by truncation (the convention
    every text writer in this repo follows), and the sidecar is derived
    from the stored values in that case, so reopen-and-derive equivalence
    always holds.
    """
    ev = getattr(trace_or_events, "events", trace_or_events)
    with PackWriter(path, chunk_rows=chunk_rows) as w:
        w.append(ev)
        arrays = None
        # the sidecar must equal what derive_structure would produce on the
        # *stored* (integer-ns) columns — already-materialized structure is
        # only reusable when the source timestamps are integers, so storage
        # quantization is the identity
        int_ts = np.asarray(ev[TS]).dtype.kind in "iu" if len(ev) else True
        if sidecar and len(ev) and int_ts and all(
                c in ev for c in (MATCH, DEPTH, PARENT, INC, EXC)):
            arrays = {"matching": np.asarray(ev.column(MATCH), np.int64),
                      "depth": np.asarray(ev.column(DEPTH), np.int32),
                      "parent": np.asarray(ev.column(PARENT), np.int64),
                      "inc": np.asarray(ev.column(INC), np.float64),
                      "exc": np.asarray(ev.column(EXC), np.float64)}
        return w.finish(sidecar=sidecar, _sidecar_arrays=arrays)


# ---------------------------------------------------------------------------
# integrity: verification, quarantine, trailer-scan salvage
# ---------------------------------------------------------------------------

def _group_span_ok(ch: dict, size: int) -> bool:
    end = ch["offset"] + ch["nbytes"] + ch.get("tlen", 0)
    return 0 <= ch["offset"] and end + 8 + len(CHUNK_MAGIC) <= size


def _verify_chunk(mm, ch: dict, size: int) -> bool:
    """CRC-check one v2 footer chunk record against the file bytes."""
    if not _group_span_ok(ch, size):
        return False
    end = ch["offset"] + ch["nbytes"] + ch["tlen"]
    return zlib.crc32(mm[ch["offset"]:end]) == ch["crc"]


def _reindex(chunks: List[dict]) -> List[dict]:
    """Rebase chunk row ranges to the surviving row space (salvaged packs
    drop rows; the reopened trace is the concatenation of survivors)."""
    out = []
    pos = 0
    for ch in chunks:
        n = ch["hi"] - ch["lo"]
        c = dict(ch)
        c["lo"], c["hi"] = pos, pos + n
        out.append(c)
        pos += n
    return out


def scan_chunk_groups(path: str) -> List[dict]:
    """Discover intact chunk groups by scanning for group trailers —
    the salvage path when the footer is lost or corrupt.  Returns footer
    -style chunk records (original row coordinates) plus each trailer's
    ``name_base`` / ``new_names``, sorted by sequence number; CRC-failing
    or unparseable candidates are dropped."""
    path = os.fspath(path)
    size = os.stat(path).st_size
    found: Dict[int, dict] = {}
    if size == 0:
        return []
    with open(path, "rb") as f, \
            mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ) as mm:
        pos = mm.find(CHUNK_MAGIC)
        while pos != -1:
            rec = _parse_group_at(mm, pos)
            if rec is not None and rec["seq"] not in found:
                found[rec["seq"]] = rec
            pos = mm.find(CHUNK_MAGIC, pos + 1)
    return [found[s] for s in sorted(found)]


def _parse_group_at(mm, magic_pos: int) -> Optional[dict]:
    """Validate a candidate group ending at ``magic_pos``; None unless the
    trailer parses and the CRC over (data + trailer) matches."""
    if magic_pos < 8:
        return None
    tlen, crc = struct.unpack("<II", mm[magic_pos - 8:magic_pos])
    tstart = magic_pos - 8 - tlen
    if tstart < 0:
        return None
    try:
        tr = json.loads(mm[tstart:magic_pos - 8].decode("utf-8"))
        cols = [[str(k), str(d), int(nb)] for k, d, nb in tr["cols"]]
        nbytes = sum(nb for _k, _d, nb in cols)
        dstart = tstart - nbytes
        if dstart < 0:
            return None
        if zlib.crc32(mm[dstart:magic_pos - 8]) != crc:
            return None
        return {"seq": int(tr["seq"]), "lo": int(tr["lo"]),
                "hi": int(tr["lo"]) + int(tr["rows"]),
                "ts_min": tr["ts_min"], "ts_max": tr["ts_max"],
                "procs": list(tr["procs"]), "offset": dstart,
                "nbytes": nbytes, "tlen": tlen, "crc": crc, "cols": cols,
                "name_base": int(tr["name_base"]),
                "new_names": list(tr["new_names"])}
    except (ValueError, KeyError, TypeError, UnicodeDecodeError):
        return None


def _salvage_footer(path: str) -> dict:
    """Rebuild a footer-equivalent (chunk index + name table) from the
    trailer scan.  The sidecar and content id are unrecoverable without
    the footer; chunks keep their *original* row coordinates here."""
    groups = scan_chunk_groups(path)
    if not groups:
        raise TraceReadError(
            path, "salvage found no intact chunk groups (not a v2 pack, or "
                  "every group is damaged; v1 packs carry no per-chunk "
                  "recovery records)")
    names: List[str] = []
    lost = 0
    for g in groups:
        if g["name_base"] > len(names):
            pad = g["name_base"] - len(names)
            names.extend(f"<lost-name-{len(names) + i}>" for i in range(pad))
            lost += pad
        names.extend(g["new_names"])
    if lost:
        warnings.warn(f"{path}: {lost} interned name(s) lost with "
                      f"quarantined chunks; placeholders substituted",
                      RuntimeWarning, stacklevel=3)
    missing = groups[-1]["seq"] + 1 - len(groups)
    _IO_STATS["chunks_quarantined"] += missing
    _IO_STATS["footers_rebuilt"] += 1
    if missing:
        warnings.warn(f"{path}: {missing} chunk group(s) unrecoverable "
                      f"(CRC mismatch or lost bytes); salvaging "
                      f"{len(groups)} intact group(s)",
                      RuntimeWarning, stacklevel=3)
    chunks = [{k: g[k] for k in ("lo", "hi", "ts_min", "ts_max", "procs",
                                 "offset", "nbytes", "tlen", "crc", "cols")}
              for g in groups]
    stored = {k for ch in chunks for k, _d, _n in ch["cols"]}
    return {"version": VERSION, "salvaged": True,
            "rows": sum(c["hi"] - c["lo"] for c in chunks),
            "chunk_rows": max(c["hi"] - c["lo"] for c in chunks),
            "columns": [{"key": k, "dtype": d} for k, _c, d in _EVENT_COLS
                        if k in stored],
            "names": names, "has_thread": "thread" in stored,
            "has_messages": "size" in stored, "chunks": chunks,
            "procs": sorted({int(p) for c in chunks for p in c["procs"]}),
            "sidecar": None, "sidecar_crc": None, "content_id": None}


# ---------------------------------------------------------------------------
# committed prefix — the read side of the append/commit protocol
# ---------------------------------------------------------------------------

#: incremental forward-scan cache for still-growing shards, keyed by
#: abspath: {"ino", "pos", "groups", "names", "tail"} where ``pos`` is the
#: byte just past the last accepted group and ``tail`` the 16 bytes ending
#: at ``pos`` (trailer length + CRC + group magic).  A poll over a live
#: shard then re-reads only the newly committed bytes; any rewrite under
#: the cursor (inode change, shrink, tail mismatch — e.g. a resume
#: truncated the file) forces a full rescan.
_LIVE_SCAN: Dict[str, dict] = {}
_LIVE_SCAN_MAX = 64
_TAIL_CHECK = 8 + len(CHUNK_MAGIC)


def _snapshot(chunks: List[dict], names: List[str], has_thread: bool,
              has_messages: bool, nbytes: int, finalized: bool) -> dict:
    rows = chunks[-1]["hi"] if chunks else 0
    return {
        "rows": rows, "chunks": chunks, "names": names,
        "has_thread": bool(has_thread), "has_messages": bool(has_messages),
        "procs": sorted({int(p) for c in chunks for p in c["procs"]}),
        "finalized": bool(finalized),
        "watermark": {
            "rows": rows, "groups": len(chunks),
            "ts_min": (min(c["ts_min"] for c in chunks) if chunks else None),
            "ts_max": (max(c["ts_max"] for c in chunks) if chunks else None),
            "bytes": int(nbytes), "finalized": bool(finalized)},
    }


def committed_prefix(path: str) -> dict:
    """Snapshot the committed prefix of a pack: the maximal contiguous run
    of CRC-clean chunk groups starting at the header, with no footer
    required.  This is the read side of the append/commit protocol — at
    any instant (mid-write, post-SIGKILL) the snapshot equals what a clean
    writer stopped at the same commit would have produced, byte for byte.

    Returns ``{rows, chunks, names, has_thread, has_messages, procs,
    finalized, watermark}``: ``chunks`` are footer-style records (row
    coordinates are contiguous from 0 by construction) and ``watermark``
    is ``{rows, groups, ts_min, ts_max, bytes, finalized}``.  A missing,
    empty, or header-only file yields an empty snapshot — a live shard
    that has not committed yet is data that hasn't arrived, not an error.
    Finalized packs take the footer fast path.  Repeated calls on a
    growing shard scan only the new bytes (incremental cursor cache).
    """
    path = os.fspath(path)
    apath = os.path.abspath(path)
    try:
        st = os.stat(path)
    except OSError:
        return _snapshot([], [], False, False, 0, finalized=False)
    size = st.st_size
    if size <= len(MAGIC2):
        with open(path, "rb") as f:
            head = f.read(len(MAGIC2))
        if head and not MAGIC2.startswith(head):
            raise TraceReadError(path, "not a pipitpack v2 file (append/"
                                       "live reads need the v2 header)")
        return _snapshot([], [], False, False, size, finalized=False)
    try:
        footer = read_footer(path)
    except (OSError, ValueError):
        footer = None
    if footer is not None:
        if footer["version"] != VERSION:
            raise TraceReadError(
                path, "v1 pack has no chunk groups (append/live requires "
                      "format version 2)")
        chunks = [dict(c) for c in footer["chunks"]]
        return _snapshot(chunks, list(footer["names"]),
                         footer["has_thread"], footer["has_messages"],
                         size, finalized=True)
    groups: List[dict] = []
    names: List[str] = []
    pos = len(MAGIC2)
    with open(path, "rb") as f, \
            mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ) as mm:
        if bytes(mm[:len(MAGIC2)]) != MAGIC2:
            raise TraceReadError(path, "not a pipitpack v2 file (append/"
                                       "live reads need the v2 header)")
        ent = _LIVE_SCAN.get(apath)
        if (ent is not None and ent["ino"] == st.st_ino
                and size >= ent["pos"]
                and bytes(mm[ent["pos"] - _TAIL_CHECK:ent["pos"]])
                == ent["tail"]):
            groups = list(ent["groups"])
            names = list(ent["names"])
            pos = ent["pos"]
        search = pos
        while True:
            mpos = mm.find(CHUNK_MAGIC, search)
            if mpos == -1:
                break
            rec = _parse_group_at(mm, mpos)
            if rec is None:
                # magic bytes inside column data — keep looking for the
                # real end of the group that starts at ``pos``
                search = mpos + 1
                continue
            if (rec["offset"] == pos and rec["seq"] == len(groups)
                    and rec["lo"] == (groups[-1]["hi"] if groups else 0)
                    and rec["name_base"] == len(names)):
                groups.append(rec)
                names.extend(rec["new_names"])
                pos = mpos + len(CHUNK_MAGIC)
                search = pos
                continue
            if rec["offset"] >= pos:
                # a valid group *not* starting at the cursor: the group at
                # ``pos`` is torn or uncommitted — the committed prefix
                # (strict by definition) ends here
                break
            search = mpos + 1
        if groups:
            if apath not in _LIVE_SCAN and len(_LIVE_SCAN) >= _LIVE_SCAN_MAX:
                _LIVE_SCAN.clear()
            _LIVE_SCAN[apath] = {
                "ino": st.st_ino, "pos": pos, "groups": list(groups),
                "names": list(names),
                "tail": bytes(mm[pos - _TAIL_CHECK:pos])}
    stored = {k for g in groups for k, _d, _n in g["cols"]}
    chunks = [{k: g[k] for k in ("lo", "hi", "ts_min", "ts_max", "procs",
                                 "offset", "nbytes", "tlen", "crc", "cols")}
              for g in groups]
    return _snapshot(chunks, names, "thread" in stored, "size" in stored,
                     pos, finalized=False)


def _resolve_live(path: str, upto_rows: Optional[int]
                  ) -> Tuple[dict, List[dict]]:
    """Footer-equivalent view of a (possibly still-growing) pack's
    committed prefix, truncated to ``upto_rows`` when given.  Live plans
    pin their snapshot watermark at planning time, and commits only ever
    land whole groups, so ``upto_rows`` always falls on a group boundary
    — execution never reads past what the planner saw even if the file
    grows mid-read."""
    snap = committed_prefix(path)
    chunks = snap["chunks"]
    if upto_rows is not None:
        chunks = [c for c in chunks if c["hi"] <= int(upto_rows)]
    stored = {k for ch in chunks for k, _d, _n in ch["cols"]}
    footer = {"version": VERSION, "live": True,
              "rows": chunks[-1]["hi"] if chunks else 0,
              "chunk_rows": max((c["hi"] - c["lo"] for c in chunks),
                                default=DEFAULT_PACK_CHUNK_ROWS),
              "columns": [{"key": k, "dtype": d} for k, _c, d in _EVENT_COLS
                          if k in stored],
              "names": snap["names"],
              "has_thread": snap["has_thread"],
              "has_messages": snap["has_messages"],
              "chunks": chunks, "procs": snap["procs"],
              "sidecar": None, "sidecar_crc": None, "content_id": None}
    return footer, chunks


def _resolve_chunks(path: str, on_error: str) -> Tuple[dict, List[dict], bool]:
    """Open policy front door: returns ``(footer, chunks, intact)`` where
    ``chunks`` are the surviving chunk records rebased to the surviving
    row space and ``intact`` says whether every original chunk survived
    (the sidecar is only meaningful then)."""
    check_on_error(on_error, _ON_ERROR_MODES)
    # an empty file is total data loss under every policy — salvage must
    # not dress it up as a successfully-recovered empty trace
    require_nonempty(path, os.stat(path).st_size, what="pack")
    try:
        footer = read_footer(path)
    except (OSError, ValueError) as e:
        if on_error == "strict":
            raise
        if on_error == "skip_chunk":
            raise TraceReadError(
                path, f"footer unreadable ({e}); on_error='skip_chunk' "
                      f"needs an intact footer — use on_error='salvage'")
        footer = _salvage_footer(path)
        return footer, _reindex(footer["chunks"]), False
    if footer["version"] == 1 or on_error == "strict":
        return footer, list(footer["chunks"]), True
    # v2 + verifying mode: CRC every chunk, quarantine failures.  A file
    # that already passed a full sweep is not re-swept until it changes.
    st = os.stat(path)
    key = _verify_key(path, st, len(footer["chunks"]))
    if "chunks" in _VERIFIED_CLEAN.get(key, ()):
        _IO_STATS["verify_cache_hits"] += 1
        return footer, list(footer["chunks"]), True
    size = st.st_size
    good: List[dict] = []
    bad = 0
    with open(path, "rb") as f, \
            mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ) as mm:
        for ch in footer["chunks"]:
            if _verify_chunk(mm, ch, size):
                good.append(ch)
            else:
                bad += 1
    if bad:
        _IO_STATS["chunks_quarantined"] += bad
        warnings.warn(f"{path}: quarantined {bad} chunk group(s) failing "
                      f"CRC; {len(good)} intact group(s) kept",
                      RuntimeWarning, stacklevel=3)
        return footer, _reindex(good), False
    _mark_verified(key, "chunks")
    return footer, good, True


def verify_pack(path: str) -> dict:
    """Full integrity report for a pack: per-chunk CRC verdicts plus the
    sidecar checksum (v2), or a structural-only check (v1).  Never raises
    on damage — damage lands in the report; raises only when ``path`` has
    no readable footer at all (then ``--repair`` / salvage is the tool)."""
    path = os.fspath(path)
    footer = read_footer(path)
    size = os.stat(path).st_size
    rep = {"path": path, "version": footer["version"],
           "rows": footer["rows"], "chunks_total": len(footer["chunks"]),
           "chunks_bad": [], "sidecar_ok": None, "ok": True}
    if footer["version"] == 1:
        # v1 stores no checksums: verify byte coverage only
        last = max((c["offset"] for c in footer.get("columns", [])),
                   default=0)
        rep["note"] = "v1 pack: no per-chunk CRCs (structural check only)"
        rep["ok"] = last < size
        return rep
    with open(path, "rb") as f, \
            mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ) as mm:
        for i, ch in enumerate(footer["chunks"]):
            if not _verify_chunk(mm, ch, size):
                rep["chunks_bad"].append(
                    {"index": i, "rows": [ch["lo"], ch["hi"]],
                     "offset": ch["offset"]})
        meta = footer.get("sidecar")
        if meta and footer.get("sidecar_crc") is not None:
            lo = meta[0]["offset"]
            hi = (meta[-1]["offset"]
                  + footer["rows"] * np.dtype(meta[-1]["dtype"]).itemsize)
            rep["sidecar_ok"] = (hi <= size and
                                 zlib.crc32(mm[lo:hi])
                                 == footer["sidecar_crc"])
    rep["ok"] = not rep["chunks_bad"] and rep["sidecar_ok"] is not False
    return rep


def repair_pack(src: str, dst: str,
                chunk_rows: Optional[int] = None) -> dict:
    """Rewrite a damaged pack from its salvageable chunks: salvage-open
    ``src`` (footer loss and CRC-failing groups tolerated), then write a
    fresh, fully-checksummed pack with a re-derived sidecar at ``dst``.
    Returns a report with rows recovered and groups quarantined."""
    before = dict(_IO_STATS)
    t = read_pack(src, on_error="salvage", sidecar=False)
    write_pack(t, dst, chunk_rows=chunk_rows or DEFAULT_PACK_CHUNK_ROWS)
    return {"src": os.fspath(src), "dst": os.fspath(dst),
            "rows_recovered": len(t),
            "chunks_quarantined": (_IO_STATS["chunks_quarantined"]
                                   - before["chunks_quarantined"]),
            "footer_rebuilt": bool(_IO_STATS["footers_rebuilt"]
                                   - before["footers_rebuilt"])}


# ---------------------------------------------------------------------------
# reader
# ---------------------------------------------------------------------------

def _sniff_pack(path: str, head: str) -> bool:
    return head.startswith("#pipitpack ")


def _shard_procs_pack(path: str) -> Optional[Set[int]]:
    """Footer-exact shard hint: the process set a pack shard contains (used
    by shard skipping before any byte of the column data is touched)."""
    try:
        return set(read_footer(path).get("procs", ())) or None
    except (OSError, ValueError):
        return None


def _open_columns_v1(path: str, footer: dict) -> Dict[str, np.ndarray]:
    rows = footer["rows"]
    out = {}
    for c in footer["columns"]:
        out[c["key"]] = np.memmap(path, dtype=np.dtype(c["dtype"]), mode="r",
                                  offset=c["offset"], shape=(rows,))
    return out


def _assemble_columns(path: str, chunks: List[dict], rows: int,
                      has_thread: bool, has_messages: bool
                      ) -> Dict[str, np.ndarray]:
    """Materialize whole columns from v2 chunk groups: one allocation per
    column, one memcpy per (group, column) slice — still zero-parse.
    ``chunks`` must be rebased (contiguous lo/hi over ``rows``)."""
    out: Dict[str, np.ndarray] = {
        "ts": np.empty(rows, "<i8"), "et": np.empty(rows, "<i1"),
        "name": np.empty(rows, "<i4"), "proc": np.empty(rows, "<i4")}
    if has_thread:
        out["thread"] = np.zeros(rows, "<i4")
    if has_messages:
        out["size"] = np.full(rows, np.nan, "<f8")
        out["partner"] = np.full(rows, -1, "<i4")
        out["tag"] = np.zeros(rows, "<i4")
    if not chunks:
        return out
    raw = np.memmap(path, dtype=np.uint8, mode="r")
    size = raw.shape[0]
    for ch in chunks:
        n = ch["hi"] - ch["lo"]
        off = ch["offset"]
        for key, dt, nb in ch["cols"]:
            if off + nb > size:
                raise TraceReadError(
                    path, f"chunk group column {key!r} extends past end of "
                          f"file (truncated pack?) — reopen with "
                          f"on_error='salvage'", locus=f"byte {off}")
            if key in out:
                seg = raw[off:off + nb].view(dt)
                if len(seg) != n:
                    raise TraceReadError(
                        path, f"chunk group column {key!r} has {len(seg)} "
                              f"rows, index says {n}", locus=f"byte {off}")
                out[key][ch["lo"]:ch["hi"]] = seg
            off += nb
    return out


class _GroupColumn:
    """Lazy ``[lo:hi]`` reads of one column across v2 chunk groups: a
    zero-copy memmap view when the slice lives in one group, a bounded
    copy when it crosses groups.  Slots straight into ``_frame_slice``."""

    def __init__(self, src: "_GroupColumnSource", key: str):
        self._src = src
        self._key = key

    def __getitem__(self, sl: slice) -> np.ndarray:
        return self._src.read(self._key, sl.start, sl.stop)


class _GroupColumnSource:
    def __init__(self, path: str, chunks: List[dict], has_thread: bool,
                 has_messages: bool):
        self._path = path
        self._raw = np.memmap(path, dtype=np.uint8, mode="r")
        self._spans: List[Tuple[int, int, Dict[str, Tuple[int, str, int]]]] \
            = []
        for ch in chunks:
            off = ch["offset"]
            colmap: Dict[str, Tuple[int, str, int]] = {}
            for key, dt, nb in ch["cols"]:
                colmap[key] = (off, dt, nb)
                off += nb
            self._spans.append((ch["lo"], ch["hi"], colmap))
        keys = ["ts", "et", "name", "proc"]
        if has_thread:
            keys.append("thread")
        if has_messages:
            keys += ["size", "partner", "tag"]
        self._cols = {k: _GroupColumn(self, k) for k in keys}

    def __contains__(self, key: str) -> bool:
        return key in self._cols

    def __getitem__(self, key: str) -> _GroupColumn:
        return self._cols[key]

    def read(self, key: str, lo: int, hi: int) -> np.ndarray:
        dt = np.dtype(_COL_DTYPE[key])
        parts: List[np.ndarray] = []
        size = self._raw.shape[0]
        for clo, chi, colmap in self._spans:
            if chi <= lo or clo >= hi:
                continue
            s, e = max(lo, clo), min(hi, chi)
            ent = colmap.get(key)
            if ent is None:
                arr = np.full(e - s, _COL_FILL[key], dt)
            else:
                off, cdt, nb = ent
                if off + nb > size:
                    raise TraceReadError(
                        self._path, f"chunk group column {key!r} extends "
                                    f"past end of file (truncated pack?) — "
                                    f"reopen with on_error='salvage'",
                        locus=f"byte {off}")
                arr = self._raw[off:off + nb].view(cdt)[s - clo:e - clo]
            if s == lo and e == hi:
                return arr
            parts.append(arr)
        if not parts:
            return np.empty(0, dt)
        return np.concatenate(parts).astype(dt, copy=False)


def _open_sidecar(path: str, footer: dict, on_error: str = "strict"
                  ) -> Optional[Dict[str, np.ndarray]]:
    """Memmap the structure sidecar; a corrupt/truncated sidecar degrades
    gracefully (warning + derive-on-demand) instead of failing the open."""
    meta = footer.get("sidecar")
    if not meta:
        return None
    rows = footer["rows"]
    try:
        side = {c["key"]: np.memmap(path, dtype=np.dtype(c["dtype"]),
                                    mode="r", offset=c["offset"],
                                    shape=(rows,))
                for c in meta}
    except (OSError, ValueError) as e:
        _IO_STATS["sidecars_dropped"] += 1
        warnings.warn(f"{path}: structure sidecar unreadable ({e}); falling "
                      f"back to derive_structure", RuntimeWarning,
                      stacklevel=3)
        return None
    if on_error != "strict" and footer.get("sidecar_crc") is not None:
        key = _verify_key(path, os.stat(path),
                          len(footer.get("chunks", ())))
        if "sidecar" not in _VERIFIED_CLEAN.get(key, ()):
            lo = meta[0]["offset"]
            hi = (meta[-1]["offset"]
                  + rows * np.dtype(meta[-1]["dtype"]).itemsize)
            with open(path, "rb") as f, \
                    mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ) as mm:
                ok = hi <= len(mm) and zlib.crc32(mm[lo:hi]) == \
                    footer["sidecar_crc"]
            if not ok:
                _IO_STATS["sidecars_dropped"] += 1
                warnings.warn(f"{path}: structure sidecar fails CRC; "
                              f"falling back to derive_structure",
                              RuntimeWarning, stacklevel=3)
                return None
            _mark_verified(key, "sidecar")
    # even without a CRC pass (strict mode stays zero-scan over the data
    # columns), the row-index columns feed fancy-indexing — an out-of-range
    # value from a damaged sidecar must degrade, not crash
    for key in ("matching", "parent"):
        if key in side and rows:
            idx = np.asarray(side[key], np.int64)
            if int(idx.max(initial=-1)) >= rows or \
                    int(idx.min(initial=0)) < -1:
                _IO_STATS["sidecars_dropped"] += 1
                warnings.warn(
                    f"{path}: structure sidecar has out-of-range row "
                    f"indices (corrupt?); falling back to "
                    f"derive_structure", RuntimeWarning, stacklevel=3)
                return None
    return side


def _name_table(footer: dict) -> np.ndarray:
    return np.asarray(footer["names"], dtype=object).astype(str)


def _frame_slice(cols, names: np.ndarray, lo: int, hi: int,
                 uniform: bool) -> EventFrame:
    """EventFrame over rows [lo, hi) — memmap-backed slices (v1 columns or
    v2 group views), no copies except the small int8→int32 Event Type
    widening.  ``uniform=True`` (chunked reads) synthesizes absent optional
    columns so chunks concatenate with every other chunked reader's
    output."""
    n = hi - lo
    ev = EventFrame({
        TS: cols["ts"][lo:hi],
        ET: Categorical(cols["et"][lo:hi].astype(np.int32), _ET_CATS),
        NAME: Categorical(np.asarray(cols["name"][lo:hi]), names),
        PROC: cols["proc"][lo:hi],
    })
    if "thread" in cols:
        ev[THREAD] = cols["thread"][lo:hi]
    elif uniform:
        ev[THREAD] = np.zeros(n, np.int32)
    if "size" in cols:
        ev[MSG_SIZE] = cols["size"][lo:hi]
        ev[PARTNER] = cols["partner"][lo:hi]
        ev[TAG] = cols["tag"][lo:hi]
    elif uniform:
        ev[MSG_SIZE] = np.full(n, np.nan)
        ev[PARTNER] = np.full(n, -1, np.int32)
        ev[TAG] = np.zeros(n, np.int32)
    return ev


def _localize(side: Dict[str, np.ndarray], ev: EventFrame, lo: int,
              hi: int) -> None:
    """Attach the sidecar slice [lo, hi) with row indices re-based to the
    slice (partners/parents outside it become -1 — exactly the within-chunk
    structure the streaming stitcher derives, minus the lexsort)."""
    m = np.asarray(side["matching"][lo:hi], np.int64)
    p = np.asarray(side["parent"][lo:hi], np.int64)
    inside_m = (m >= lo) & (m < hi)
    inside_p = (p >= lo) & (p < hi)
    ev[MATCH] = np.where(inside_m, m - lo, -1)
    ev[PARENT] = np.where(inside_p, p - lo, -1)
    ev[INC] = side["inc"][lo:hi]
    ev[EXC] = side["exc"][lo:hi]


@register_reader("pack", extensions=(".pack",), sniff=_sniff_pack,
                 shard_procs=_shard_procs_pack, priority=30)
def read_pack(path: str, label: Optional[str] = None,
              sidecar: bool = True, on_error: str = "strict",
              report=None, live: bool = False,
              upto_rows: Optional[int] = None) -> Trace:
    """Open a pack whole-file: column data is memmap-backed (v1) or
    assembled with one memcpy per group slice (v2) — zero parse either way.

    With ``sidecar=True`` (default) and a stored sidecar, the derived
    structure columns (matching / depth / parent / inc / exc plus the
    matching-timestamp column) attach directly and the returned Trace is
    already structured — ``derive_structure`` never runs.  A corrupt
    sidecar never fails the open: it is dropped with a warning and
    structure derives lazily.

    ``on_error``: ``"strict"`` (default) raises on structural damage with
    file/offset context; ``"skip_chunk"`` CRC-verifies and quarantines
    damaged chunk groups; ``"salvage"`` additionally rebuilds a lost
    footer by trailer scan.  See the module docstring.

    ``live=True`` reads the **committed prefix** of a (possibly still
    -growing) append-mode shard: no footer needed, no warnings for the
    expected-missing tail, empty trace when nothing has committed yet.
    ``upto_rows`` pins the read to an earlier watermark (always a group
    boundary) so concurrent growth cannot leak into the result.
    """
    from ..core.errors import IngestReport
    path = os.fspath(path)
    report = report if report is not None else IngestReport()
    quar0 = _IO_STATS["chunks_quarantined"]
    if live or upto_rows is not None:
        footer, chunks = _resolve_live(path, upto_rows)
        intact = False  # live prefixes carry no sidecar; derive lazily
    else:
        footer, chunks, intact = _resolve_chunks(path, on_error)
    names = _name_table(footer)
    rows = sum(c["hi"] - c["lo"] for c in chunks)
    report.begin(path)
    q = _IO_STATS["chunks_quarantined"] - quar0
    if q:
        report.skip(path, q, "",
                    "chunk groups quarantined (CRC/structure fault)")
    report.add_rows(path, rows)
    if footer["version"] == 1:
        cols = _open_columns_v1(path, footer)
    else:
        cols = _assemble_columns(path, chunks, rows, footer["has_thread"],
                                 footer["has_messages"])
    ev = _frame_slice(cols, names, 0, rows, uniform=False)
    t = Trace(ev, label=label or path)
    t._ingest = report
    side = (_open_sidecar(path, footer, on_error)
            if sidecar and intact else None)
    if side is not None:
        matching = np.asarray(side["matching"], np.int64)
        ev[MATCH] = matching
        ev[DEPTH] = side["depth"]
        ev[PARENT] = side["parent"]
        ev[INC] = side["inc"]
        ev[EXC] = side["exc"]
        ts = np.asarray(ev[TS], np.float64)
        ev[MATCH_TS] = np.where(matching >= 0, ts[np.maximum(matching, 0)],
                                np.nan)
        t._structured = True
    return t


def _admits_chunk(ch: dict, hints: Optional[PlanHints]) -> bool:
    """False when the footer index proves the chunk cannot contribute."""
    if hints is None:
        return True
    if hints.time_window is not None:
        t0, t1 = hints.time_window
        if ch["ts_max"] < t0 or ch["ts_min"] > t1:
            return False
    if hints.procs is not None or hints.proc_bounds is not None:
        if not any(hints.admits_proc(p) for p in ch["procs"]):
            return False
    return True


def _row_mask(ev: EventFrame, hints: Optional[PlanHints]) -> Optional[np.ndarray]:
    """Row-level pushdown mask for a surviving chunk, or None when every
    row is admitted (the common all-or-nothing case keeps the zero-copy
    slice and its sidecar fast path)."""
    if hints is None:
        return None
    mask = None
    if hints.procs is not None or hints.proc_bounds is not None:
        proc = np.asarray(ev[PROC], np.int64)
        m = np.ones(len(proc), bool)
        if hints.procs is not None:
            m &= np.isin(proc, np.fromiter(hints.procs, np.int64,
                                           len(hints.procs)))
        if hints.proc_bounds is not None:
            m &= (proc >= hints.proc_bounds[0]) & (proc <= hints.proc_bounds[1])
        mask = m
    if hints.time_window is not None:
        ts = np.asarray(ev[TS], np.float64)
        m = (ts >= hints.time_window[0]) & (ts <= hints.time_window[1])
        mask = m if mask is None else (mask & m)
    if mask is None or mask.all():
        return None
    return mask


@register_chunked("pack")
def iter_chunks_pack(path: str, chunk_rows: int,
                     hints: Optional[PlanHints] = None,
                     label: Optional[str] = None,
                     row_range: Optional[tuple] = None,
                     sidecar: bool = True,
                     on_error: str = "strict",
                     report=None, live: bool = False,
                     upto_rows: Optional[int] = None
                     ) -> Iterator[EventFrame]:
    """Stream a pack in EventFrame chunks of at most ``chunk_rows`` rows.

    Index pushdown runs first: footer chunks whose time range / process set
    cannot satisfy ``hints`` are skipped without touching their bytes
    (counted in :func:`io_stats`).  Surviving contiguous row runs are
    coalesced and re-sliced to ``chunk_rows``, so the yielded chunk size is
    independent of the pack's own chunking.  ``row_range=(lo, hi)``
    restricts the read to those rows (:class:`~repro.core.registry.RowSpan`
    parallel work units).  With a stored sidecar, unfiltered chunks carry
    row-localized structure columns the streaming stitcher consumes instead
    of re-deriving per chunk.  ``on_error`` follows :func:`read_pack`:
    verifying modes quarantine CRC-failing chunk groups before pushdown,
    and ``"salvage"`` streams a footer-less pack from its trailer scan.
    ``live`` / ``upto_rows`` follow :func:`read_pack`: stream the
    committed prefix of a still-growing shard, pinned to a watermark.
    """
    path = os.fspath(path)
    quar0 = _IO_STATS["chunks_quarantined"]
    if live or upto_rows is not None:
        footer, fchunks = _resolve_live(path, upto_rows)
        intact = False
    else:
        footer, fchunks, intact = _resolve_chunks(path, on_error)
    names = _name_table(footer)
    total = sum(c["hi"] - c["lo"] for c in fchunks)
    if report is not None and row_range is None:
        report.begin(path)
        q = _IO_STATS["chunks_quarantined"] - quar0
        if q:
            report.skip(path, q, "",
                        "chunk groups quarantined (CRC/structure fault)")
        report.add_rows(path, total)
    if footer["version"] == 1:
        cols = _open_columns_v1(path, footer)
    elif fchunks:
        cols = _GroupColumnSource(path, fchunks, footer["has_thread"],
                                  footer["has_messages"])
    else:
        cols = {}  # nothing committed yet — no bytes to map
    side = (_open_sidecar(path, footer, on_error)
            if sidecar and intact else None)
    r_lo, r_hi = (0, total) if row_range is None else (
        int(row_range[0]), int(row_range[1]))
    # pushdown at footer-chunk granularity, then coalesce surviving runs
    runs: List[List[int]] = []
    for ch in fchunks:
        lo, hi = max(ch["lo"], r_lo), min(ch["hi"], r_hi)
        if hi <= lo:
            continue
        if not _admits_chunk(ch, hints):
            _IO_STATS["chunks_skipped"] += 1
            continue
        _IO_STATS["chunks_read"] += 1
        if runs and runs[-1][1] == lo:
            runs[-1][1] = hi
        else:
            runs.append([lo, hi])
    for lo, hi in runs:
        for s in range(lo, hi, chunk_rows):
            e = min(s + chunk_rows, hi)
            ev = _frame_slice(cols, names, s, e, uniform=True)
            mask = _row_mask(ev, hints)
            if mask is None:
                if side is not None:
                    _localize(side, ev, s, e)
                yield ev
            else:
                if not np.any(mask):
                    continue
                # row filtering invalidates localized structure indices —
                # the stitcher re-derives on the filtered chunk, exactly
                # like parse-time pushdown in the text readers
                yield ev.mask(mask)


@register_units("pack")
def plan_units_pack(path: str, n_units: int) -> Optional[List[RowSpan]]:
    """Split one pack into up to ``n_units`` RowSpans aligned to footer
    chunk boundaries — the ideal ByteSpan analogue: rows are random-access,
    so no line-boundary alignment pass is ever needed and the spans
    partition the rows exactly by construction."""
    footer = read_footer(path)
    chunks = footer["chunks"]
    if n_units <= 1 or len(chunks) <= 1:
        return None
    groups = even_groups(chunks, n_units)
    return [RowSpan(path, g[0]["lo"], g[-1]["hi"]) for g in groups]
