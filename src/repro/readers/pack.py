"""pipitpack — the native columnar binary trace store (parse once, mmap ever
after).

Every other format we read is *text*: re-opening a 10M-event trace means
re-decoding hundreds of MB of JSON/CSV before the first vectorized kernel
runs, and that decode dominates cache-miss execution end to end.  A pack
file stores the uniform data model (paper Fig. 1) as little-endian
per-column arrays laid out contiguously for the whole file, so reopening is
``np.memmap`` per column — zero parse, zero copy — plus a small JSON footer
holding:

* the **column directory** (key, dtype, byte offset),
* the interned **name table** (``Name`` is stored as int32 codes),
* the **chunk index**: fixed-row chunks with each chunk's row range, time
  range and process set — chunked/streaming reads skip chunks a plan's
  time-window or process restriction provably cannot need *without touching
  their bytes* (index pushdown),
* an optional **structure sidecar**: matching / depth / parent / inc / exc
  computed once at pack time, so reopening skips ``derive_structure``
  entirely (eager opens attach the columns; streaming chunks carry
  row-localized slices the :class:`~repro.core.streaming.CallStitcher`
  consumes instead of re-deriving per chunk),
* a **content id** (SHA-256 over all column + sidecar bytes) — the
  plan-result cache (:mod:`repro.core.plancache`) keys pack sources by it,
  so copies and rewrites with identical content share cache entries.

File layout::

    #pipitpack 1\\n                      ASCII magic line (sniffable)
    <column arrays, back to back>       offsets in the footer
    <sidecar arrays, back to back>      (optional)
    <footer JSON, utf-8>
    <footer length, uint64 LE> <b"PIPITPK\\0">   last 16 bytes

Write paths: ``Trace.save_pack(path)`` / ``write_pack`` (in-memory),
``StreamingTrace.save_pack`` / :class:`PackWriter` (out-of-core append —
column data spools per column and is stitched at finish), and
``tools/pack.py`` (the CLI converter for any registered format).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import struct
import tempfile
from typing import Any, Dict, Iterator, List, Optional, Set, Tuple

import numpy as np

from ..core import structure
from ..core.constants import (DEPTH, ENTER, ET, EXC, INC, INSTANT, LEAVE,
                              MATCH, MATCH_TS, MSG_SIZE, NAME, PARENT,
                              PARTNER, PROC, TAG, THREAD, TS)
from ..core.frame import Categorical, EventFrame
from ..core.registry import (PlanHints, RowSpan, even_groups,
                             register_chunked, register_reader,
                             register_units)
from ..core.trace import Trace

__all__ = ["write_pack", "read_pack", "PackWriter", "read_footer",
           "content_id", "io_stats", "reset_io_stats",
           "DEFAULT_PACK_CHUNK_ROWS"]

MAGIC = b"#pipitpack 1\n"
TAIL_MAGIC = b"PIPITPK\x00"
VERSION = 1
DEFAULT_PACK_CHUNK_ROWS = 250_000

_ET_CODE = {ENTER: 0, LEAVE: 1, INSTANT: 2}
_ET_CATS = np.asarray([ENTER, LEAVE, INSTANT])

#: (footer key, canonical column, on-disk dtype) — event columns in file order
_EVENT_COLS = (
    ("ts", TS, "<i8"),
    ("et", ET, "<i1"),
    ("name", NAME, "<i4"),
    ("proc", PROC, "<i4"),
    ("thread", THREAD, "<i4"),
    ("size", MSG_SIZE, "<f8"),
    ("partner", PARTNER, "<i4"),
    ("tag", TAG, "<i4"),
)
#: sidecar arrays (footer key, canonical column, dtype)
_SIDECAR_COLS = (
    ("matching", MATCH, "<i8"),
    ("depth", DEPTH, "<i4"),
    ("parent", PARENT, "<i8"),
    ("inc", INC, "<f8"),
    ("exc", EXC, "<f8"),
)


# ---------------------------------------------------------------------------
# io accounting (tests / benchmarks assert pushdown actually skips chunks)
# ---------------------------------------------------------------------------

_IO_STATS = {"chunks_read": 0, "chunks_skipped": 0}


def io_stats() -> Dict[str, int]:
    """Process-local counters of footer-index chunks read vs skipped by
    pushdown since the last :func:`reset_io_stats` (advisory; parallel pool
    workers count in their own process)."""
    return dict(_IO_STATS)


def reset_io_stats() -> None:
    _IO_STATS["chunks_read"] = 0
    _IO_STATS["chunks_skipped"] = 0


# ---------------------------------------------------------------------------
# footer access
# ---------------------------------------------------------------------------

_FOOTER_CACHE: Dict[str, Tuple[Tuple[int, int], dict]] = {}


def read_footer(path: str) -> dict:
    """Parse and return the footer of ``path`` (cached per (size, mtime)).

    Raises ValueError when the file is not a pack.
    """
    path = os.fspath(path)
    st = os.stat(path)
    key = (st.st_size, st.st_mtime_ns)
    hit = _FOOTER_CACHE.get(path)
    if hit is not None and hit[0] == key:
        return hit[1]
    with open(path, "rb") as f:
        head = f.read(len(MAGIC))
        if head != MAGIC:
            raise ValueError(f"{path!r} is not a pipitpack file")
        if st.st_size < len(MAGIC) + 16:
            raise ValueError(f"{path!r}: truncated pack (no footer)")
        f.seek(-16, os.SEEK_END)
        flen, tail = struct.unpack("<Q", f.read(8))[0], f.read(8)
        if tail != TAIL_MAGIC:
            raise ValueError(f"{path!r}: bad pack trailer (truncated write?)")
        f.seek(st.st_size - 16 - flen)
        footer = json.loads(f.read(flen).decode("utf-8"))
    if footer.get("version") != VERSION:
        raise ValueError(f"{path!r}: unsupported pack version "
                         f"{footer.get('version')!r} (this reader supports "
                         f"{VERSION})")
    if len(_FOOTER_CACHE) > 256:
        _FOOTER_CACHE.clear()
    _FOOTER_CACHE[path] = (key, footer)
    return footer


def is_pack(path: str) -> bool:
    try:
        with open(path, "rb") as f:
            return f.read(len(MAGIC)) == MAGIC
    except OSError:
        return False


def content_id(path: str) -> Optional[str]:
    """The pack's stored content id (SHA-256 over column + sidecar bytes),
    or None when ``path`` is not a readable pack.  Footer-only read — the
    plan cache calls this per terminal op."""
    try:
        if not is_pack(path):
            return None
        return read_footer(path).get("content_id")
    except (OSError, ValueError):
        return None


# ---------------------------------------------------------------------------
# writer
# ---------------------------------------------------------------------------

def _int_column(arr: np.ndarray, dtype: str, what: str) -> np.ndarray:
    out = np.asarray(arr)
    info = np.iinfo(np.dtype(dtype))
    if len(out) and (out.min() < info.min or out.max() > info.max):
        raise ValueError(f"pack {what} column value out of {dtype} range "
                         f"[{info.min}, {info.max}]")
    return out.astype(dtype, copy=False)


def _et_codes(ev: EventFrame) -> np.ndarray:
    """Canonical 0/1/2 Enter/Leave/Instant codes; richer instant subtypes
    (MpiSend/...) render as plain instants, like every on-disk format."""
    col = ev.column(ET)
    if isinstance(col, Categorical):
        remap = np.asarray([_ET_CODE.get(str(c), 2) for c in col.categories],
                           np.int8)
        return remap[col.codes]
    return np.asarray([_ET_CODE.get(str(v), 2) for v in np.asarray(col)],
                      np.int8)


class PackWriter:
    """Out-of-core pack writer: append EventFrames in stream order, then
    :meth:`finish`.  Column data spools into per-column temp files (bounded
    memory) and is stitched into the final single-file layout at finish;
    the chunk index, name interner and content hash accumulate as chunks
    arrive.

    Usable as a context manager: leaving the ``with`` block without having
    called :meth:`finish` (including via an exception) aborts the write and
    removes the spools — no partial pack ever lands at ``path``.

    Timestamps are stored as integer nanoseconds; float timestamps
    quantize by truncation, exactly like every text writer in this repo
    (``write_jsonl``'s ``int(ts)``).  The structure sidecar is always
    consistent with the *stored* values.
    """

    def __init__(self, path: str, chunk_rows: int = DEFAULT_PACK_CHUNK_ROWS):
        self.path = os.fspath(path)
        self.chunk_rows = int(chunk_rows)
        if self.chunk_rows <= 0:
            raise ValueError("chunk_rows must be positive")
        d = os.path.dirname(os.path.abspath(self.path)) or "."
        self._dir = tempfile.mkdtemp(prefix=".pack_", dir=d)
        self._spool = {k: open(os.path.join(self._dir, k), "wb")
                       for k, _c, _d in _EVENT_COLS}
        self._rows = 0
        self._name_code: Dict[str, int] = {}
        self._names: List[str] = []
        self._chunks: List[dict] = []  # finalized chunk records
        self._cur: Optional[dict] = None  # partial chunk accumulator
        self._has_thread = False
        self._has_messages = False
        self._finished = False

    # -- context manager ---------------------------------------------------
    def __enter__(self) -> "PackWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self._finished:
            self.abort()

    # -- append ------------------------------------------------------------
    def append(self, frame_or_trace) -> None:
        """Append one EventFrame (or Trace) worth of events, in stream
        order.  Missing optional columns (thread / message triplet) are
        synthesized; name codes are re-interned into the file-global
        table."""
        ev = getattr(frame_or_trace, "events", frame_or_trace)
        n = len(ev)
        if n == 0:
            return
        ts = _int_column(ev[TS], "<i8", "ts")
        et = _et_codes(ev)
        name = self._intern(ev)
        proc = _int_column(ev[PROC], "<i4", "proc")
        if THREAD in ev:
            thread = _int_column(ev[THREAD], "<i4", "thread")
            self._has_thread = self._has_thread or bool(np.any(thread))
        else:
            thread = np.zeros(n, "<i4")
        if MSG_SIZE in ev:
            size = np.asarray(ev[MSG_SIZE], np.float64).astype("<f8",
                                                               copy=False)
        else:
            size = np.full(n, np.nan, "<f8")
        if PARTNER in ev:
            partner = _int_column(ev[PARTNER], "<i4", "partner")
        else:
            partner = np.full(n, -1, "<i4")
        if TAG in ev:
            tag = _int_column(ev[TAG], "<i4", "tag")
        else:
            tag = np.zeros(n, "<i4")
        self._has_messages = self._has_messages or bool(
            np.any(~np.isnan(size)) or np.any(partner >= 0))
        cols = {"ts": ts, "et": et, "name": name, "proc": proc,
                "thread": thread, "size": size, "partner": partner,
                "tag": tag}
        for k, arr in cols.items():
            self._spool[k].write(np.ascontiguousarray(arr).tobytes())
        self._index_rows(ts, proc)
        self._rows += n

    def _intern(self, ev: EventFrame) -> np.ndarray:
        cat = ev.cat(NAME)
        local = np.empty(len(cat.categories), np.int32)
        for i, c in enumerate(cat.categories):
            s = str(c)
            g = self._name_code.get(s)
            if g is None:
                g = len(self._names)
                self._name_code[s] = g
                self._names.append(s)
            local[i] = g
        return local[cat.codes].astype("<i4", copy=False)

    def _index_rows(self, ts: np.ndarray, proc: np.ndarray) -> None:
        """Fold appended rows into fixed-row chunk index records."""
        pos = 0
        n = len(ts)
        while pos < n:
            if self._cur is None:
                self._cur = {"lo": self._rows + pos, "rows": 0,
                             "ts_min": None, "ts_max": None,
                             "procs": set()}
            take = min(n - pos, self.chunk_rows - self._cur["rows"])
            sl_ts = ts[pos:pos + take]
            sl_p = proc[pos:pos + take]
            lo_t, hi_t = int(sl_ts.min()), int(sl_ts.max())
            c = self._cur
            c["ts_min"] = lo_t if c["ts_min"] is None else min(c["ts_min"],
                                                               lo_t)
            c["ts_max"] = hi_t if c["ts_max"] is None else max(c["ts_max"],
                                                               hi_t)
            c["procs"].update(np.unique(sl_p).tolist())
            c["rows"] += take
            pos += take
            if c["rows"] == self.chunk_rows:
                self._flush_chunk()

    def _flush_chunk(self) -> None:
        c = self._cur
        if c is None or c["rows"] == 0:
            self._cur = None
            return
        self._chunks.append({
            "lo": c["lo"], "hi": c["lo"] + c["rows"],
            "ts_min": c["ts_min"], "ts_max": c["ts_max"],
            "procs": sorted(int(p) for p in c["procs"]),
        })
        self._cur = None

    # -- finish ------------------------------------------------------------
    def abort(self) -> None:
        """Discard spools without writing the pack."""
        for f in self._spool.values():
            f.close()
        shutil.rmtree(self._dir, ignore_errors=True)
        self._finished = True

    def finish(self, sidecar: Any = "auto",
               _sidecar_arrays: Optional[dict] = None) -> str:
        """Stitch spools into the final pack file and write the footer.

        ``sidecar=True`` derives the structure sidecar (matching / depth /
        parent / inc / exc) from the just-written columns via a memmap
        pass — this is the only whole-trace step, and it is memmap-backed,
        so peak memory is the derived arrays, not the event text.
        ``"auto"`` means True.  ``_sidecar_arrays`` lets ``write_pack``
        hand in structure a Trace already materialized.
        """
        if self._finished:
            raise RuntimeError("PackWriter already finished")
        self._flush_chunk()
        for f in self._spool.values():
            f.close()
        want_sidecar = bool(sidecar) or _sidecar_arrays is not None
        keep = self._store_flags()
        tmp = os.path.join(self._dir, "final")
        h = hashlib.sha256()
        columns = []
        with open(tmp, "wb") as out:
            out.write(MAGIC)
            off = out.tell()
            for key, _col, dt in _EVENT_COLS:
                if not keep[key]:
                    continue
                nbytes = self._copy_spool(key, out, h)
                columns.append({"key": key, "dtype": dt, "offset": off})
                off += nbytes
            sidecar_meta = None
            if want_sidecar and self._rows:
                arrays = _sidecar_arrays
                if arrays is None:
                    out.flush()  # the memmap pass reads the written columns
                    arrays = self._derive_sidecar(tmp, columns, keep)
                sidecar_meta = []
                for key, _col, dt in _SIDECAR_COLS:
                    arr = np.ascontiguousarray(
                        np.asarray(arrays[key]).astype(dt, copy=False))
                    if len(arr) != self._rows:
                        raise ValueError(
                            f"sidecar {key!r} has {len(arr)} rows, pack has "
                            f"{self._rows}")
                    b = arr.tobytes()
                    h.update(b)
                    out.write(b)
                    sidecar_meta.append({"key": key, "dtype": dt,
                                         "offset": off})
                    off += len(b)
            footer = {
                "version": VERSION,
                "rows": self._rows,
                "chunk_rows": self.chunk_rows,
                "columns": columns,
                "names": self._names,
                "has_thread": self._has_thread,
                "has_messages": self._has_messages,
                "chunks": self._chunks,
                "procs": sorted({p for c in self._chunks
                                 for p in c["procs"]}),
                "sidecar": sidecar_meta,
                "content_id": h.hexdigest(),
            }
            blob = json.dumps(footer, separators=(",", ":")).encode("utf-8")
            out.write(blob)
            out.write(struct.pack("<Q", len(blob)))
            out.write(TAIL_MAGIC)
        os.replace(tmp, self.path)
        shutil.rmtree(self._dir, ignore_errors=True)
        self._finished = True
        _FOOTER_CACHE.pop(self.path, None)
        return self.path

    def _store_flags(self) -> Dict[str, bool]:
        """Which optional columns earn bytes in the final file."""
        keep = {k: True for k, _c, _d in _EVENT_COLS}
        keep["thread"] = self._has_thread
        if not self._has_messages:
            keep["size"] = keep["partner"] = keep["tag"] = False
        return keep

    def _copy_spool(self, key: str, out, h) -> int:
        total = 0
        with open(os.path.join(self._dir, key), "rb") as src:
            while True:
                b = src.read(1 << 22)
                if not b:
                    break
                h.update(b)
                out.write(b)
                total += len(b)
        return total

    def _derive_sidecar(self, tmp: str, columns: List[dict],
                        keep: Dict[str, bool]) -> dict:
        """One structure pass over the just-written columns (memmapped)."""
        byc = {c["key"]: c for c in columns}
        ev = EventFrame()
        for key, col, dt in _EVENT_COLS:
            if not keep[key]:
                continue
            m = np.memmap(tmp, dtype=np.dtype(dt), mode="r",
                          offset=byc[key]["offset"], shape=(self._rows,))
            if key == "et":
                ev[ET] = Categorical(m.astype(np.int32), _ET_CATS)
            elif key == "name":
                ev[NAME] = Categorical(
                    np.asarray(m),
                    np.asarray(self._names, dtype=object).astype(str))
            else:
                ev[col] = m
        matching, depth, parent, inc, exc = structure.derive_structure(ev)
        return {"matching": matching, "depth": depth, "parent": parent,
                "inc": inc, "exc": exc}


def write_pack(trace_or_events, path: str,
               chunk_rows: int = DEFAULT_PACK_CHUNK_ROWS,
               sidecar: bool = True) -> str:
    """Serialize an in-memory trace (or EventFrame) as one pack file.

    ``sidecar=True`` (default) stores the structure sidecar: the trace's
    already-materialized structure columns are reused when present and
    row-for-row valid; otherwise structure is derived once on the event
    frame (the same pass reopening would pay — paid here exactly once).

    Float timestamps quantize to integer ns by truncation (the convention
    every text writer in this repo follows), and the sidecar is derived
    from the stored values in that case, so reopen-and-derive equivalence
    always holds.
    """
    ev = getattr(trace_or_events, "events", trace_or_events)
    with PackWriter(path, chunk_rows=chunk_rows) as w:
        w.append(ev)
        arrays = None
        # the sidecar must equal what derive_structure would produce on the
        # *stored* (integer-ns) columns — already-materialized structure is
        # only reusable when the source timestamps are integers, so storage
        # quantization is the identity
        int_ts = np.asarray(ev[TS]).dtype.kind in "iu" if len(ev) else True
        if sidecar and len(ev) and int_ts and all(
                c in ev for c in (MATCH, DEPTH, PARENT, INC, EXC)):
            arrays = {"matching": np.asarray(ev.column(MATCH), np.int64),
                      "depth": np.asarray(ev.column(DEPTH), np.int32),
                      "parent": np.asarray(ev.column(PARENT), np.int64),
                      "inc": np.asarray(ev.column(INC), np.float64),
                      "exc": np.asarray(ev.column(EXC), np.float64)}
        return w.finish(sidecar=sidecar, _sidecar_arrays=arrays)


# ---------------------------------------------------------------------------
# reader
# ---------------------------------------------------------------------------

def _sniff_pack(path: str, head: str) -> bool:
    return head.startswith("#pipitpack ")


def _shard_procs_pack(path: str) -> Optional[Set[int]]:
    """Footer-exact shard hint: the process set a pack shard contains (used
    by shard skipping before any byte of the column data is touched)."""
    try:
        return set(read_footer(path).get("procs", ())) or None
    except (OSError, ValueError):
        return None


def _open_columns(path: str, footer: dict) -> Dict[str, np.ndarray]:
    rows = footer["rows"]
    out = {}
    for c in footer["columns"]:
        out[c["key"]] = np.memmap(path, dtype=np.dtype(c["dtype"]), mode="r",
                                  offset=c["offset"], shape=(rows,))
    return out


def _open_sidecar(path: str, footer: dict) -> Optional[Dict[str, np.ndarray]]:
    meta = footer.get("sidecar")
    if not meta:
        return None
    rows = footer["rows"]
    return {c["key"]: np.memmap(path, dtype=np.dtype(c["dtype"]), mode="r",
                                offset=c["offset"], shape=(rows,))
            for c in meta}


def _name_table(footer: dict) -> np.ndarray:
    return np.asarray(footer["names"], dtype=object).astype(str)


def _frame_slice(cols: Dict[str, np.ndarray], names: np.ndarray,
                 lo: int, hi: int, uniform: bool) -> EventFrame:
    """EventFrame over rows [lo, hi) — pure memmap slices, no copies except
    the small int8→int32 Event Type widening.  ``uniform=True`` (chunked
    reads) synthesizes absent optional columns so chunks concatenate with
    every other chunked reader's output."""
    n = hi - lo
    ev = EventFrame({
        TS: cols["ts"][lo:hi],
        ET: Categorical(cols["et"][lo:hi].astype(np.int32), _ET_CATS),
        NAME: Categorical(np.asarray(cols["name"][lo:hi]), names),
        PROC: cols["proc"][lo:hi],
    })
    if "thread" in cols:
        ev[THREAD] = cols["thread"][lo:hi]
    elif uniform:
        ev[THREAD] = np.zeros(n, np.int32)
    if "size" in cols:
        ev[MSG_SIZE] = cols["size"][lo:hi]
        ev[PARTNER] = cols["partner"][lo:hi]
        ev[TAG] = cols["tag"][lo:hi]
    elif uniform:
        ev[MSG_SIZE] = np.full(n, np.nan)
        ev[PARTNER] = np.full(n, -1, np.int32)
        ev[TAG] = np.zeros(n, np.int32)
    return ev


def _localize(side: Dict[str, np.ndarray], ev: EventFrame, lo: int,
              hi: int) -> None:
    """Attach the sidecar slice [lo, hi) with row indices re-based to the
    slice (partners/parents outside it become -1 — exactly the within-chunk
    structure the streaming stitcher derives, minus the lexsort)."""
    m = np.asarray(side["matching"][lo:hi], np.int64)
    p = np.asarray(side["parent"][lo:hi], np.int64)
    inside_m = (m >= lo) & (m < hi)
    inside_p = (p >= lo) & (p < hi)
    ev[MATCH] = np.where(inside_m, m - lo, -1)
    ev[PARENT] = np.where(inside_p, p - lo, -1)
    ev[INC] = side["inc"][lo:hi]
    ev[EXC] = side["exc"][lo:hi]


@register_reader("pack", extensions=(".pack",), sniff=_sniff_pack,
                 shard_procs=_shard_procs_pack, priority=30)
def read_pack(path: str, label: Optional[str] = None,
              sidecar: bool = True) -> Trace:
    """Open a pack whole-file: every event column is a zero-copy memmap.

    With ``sidecar=True`` (default) and a stored sidecar, the derived
    structure columns (matching / depth / parent / inc / exc plus the
    matching-timestamp column) attach directly and the returned Trace is
    already structured — ``derive_structure`` never runs.
    """
    path = os.fspath(path)
    footer = read_footer(path)
    cols = _open_columns(path, footer)
    names = _name_table(footer)
    rows = footer["rows"]
    ev = _frame_slice(cols, names, 0, rows, uniform=False)
    t = Trace(ev, label=label or path)
    side = _open_sidecar(path, footer) if sidecar else None
    if side is not None:
        matching = np.asarray(side["matching"], np.int64)
        ev[MATCH] = matching
        ev[DEPTH] = side["depth"]
        ev[PARENT] = side["parent"]
        ev[INC] = side["inc"]
        ev[EXC] = side["exc"]
        ts = np.asarray(ev[TS], np.float64)
        ev[MATCH_TS] = np.where(matching >= 0, ts[np.maximum(matching, 0)],
                                np.nan)
        t._structured = True
    return t


def _admits_chunk(ch: dict, hints: Optional[PlanHints]) -> bool:
    """False when the footer index proves the chunk cannot contribute."""
    if hints is None:
        return True
    if hints.time_window is not None:
        t0, t1 = hints.time_window
        if ch["ts_max"] < t0 or ch["ts_min"] > t1:
            return False
    if hints.procs is not None or hints.proc_bounds is not None:
        if not any(hints.admits_proc(p) for p in ch["procs"]):
            return False
    return True


def _row_mask(ev: EventFrame, hints: Optional[PlanHints]) -> Optional[np.ndarray]:
    """Row-level pushdown mask for a surviving chunk, or None when every
    row is admitted (the common all-or-nothing case keeps the zero-copy
    slice and its sidecar fast path)."""
    if hints is None:
        return None
    mask = None
    if hints.procs is not None or hints.proc_bounds is not None:
        proc = np.asarray(ev[PROC], np.int64)
        m = np.ones(len(proc), bool)
        if hints.procs is not None:
            m &= np.isin(proc, np.fromiter(hints.procs, np.int64,
                                           len(hints.procs)))
        if hints.proc_bounds is not None:
            m &= (proc >= hints.proc_bounds[0]) & (proc <= hints.proc_bounds[1])
        mask = m
    if hints.time_window is not None:
        ts = np.asarray(ev[TS], np.float64)
        m = (ts >= hints.time_window[0]) & (ts <= hints.time_window[1])
        mask = m if mask is None else (mask & m)
    if mask is None or mask.all():
        return None
    return mask


@register_chunked("pack")
def iter_chunks_pack(path: str, chunk_rows: int,
                     hints: Optional[PlanHints] = None,
                     label: Optional[str] = None,
                     row_range: Optional[tuple] = None,
                     sidecar: bool = True) -> Iterator[EventFrame]:
    """Stream a pack in EventFrame chunks of at most ``chunk_rows`` rows.

    Index pushdown runs first: footer chunks whose time range / process set
    cannot satisfy ``hints`` are skipped without touching their bytes
    (counted in :func:`io_stats`).  Surviving contiguous row runs are
    coalesced and re-sliced to ``chunk_rows``, so the yielded chunk size is
    independent of the pack's own chunking.  ``row_range=(lo, hi)``
    restricts the read to those rows (:class:`~repro.core.registry.RowSpan`
    parallel work units).  With a stored sidecar, unfiltered chunks carry
    row-localized structure columns the streaming stitcher consumes instead
    of re-deriving per chunk.
    """
    path = os.fspath(path)
    footer = read_footer(path)
    cols = _open_columns(path, footer)
    names = _name_table(footer)
    side = _open_sidecar(path, footer) if sidecar else None
    r_lo, r_hi = (0, footer["rows"]) if row_range is None else (
        int(row_range[0]), int(row_range[1]))
    # pushdown at footer-chunk granularity, then coalesce surviving runs
    runs: List[List[int]] = []
    for ch in footer["chunks"]:
        lo, hi = max(ch["lo"], r_lo), min(ch["hi"], r_hi)
        if hi <= lo:
            continue
        if not _admits_chunk(ch, hints):
            _IO_STATS["chunks_skipped"] += 1
            continue
        _IO_STATS["chunks_read"] += 1
        if runs and runs[-1][1] == lo:
            runs[-1][1] = hi
        else:
            runs.append([lo, hi])
    for lo, hi in runs:
        for s in range(lo, hi, chunk_rows):
            e = min(s + chunk_rows, hi)
            ev = _frame_slice(cols, names, s, e, uniform=True)
            mask = _row_mask(ev, hints)
            if mask is None:
                if side is not None:
                    _localize(side, ev, s, e)
                yield ev
            else:
                if not np.any(mask):
                    continue
                # row filtering invalidates localized structure indices —
                # the stitcher re-derives on the filtered chunk, exactly
                # like parse-time pushdown in the text readers
                yield ev.mask(mask)


@register_units("pack")
def plan_units_pack(path: str, n_units: int) -> Optional[List[RowSpan]]:
    """Split one pack into up to ``n_units`` RowSpans aligned to footer
    chunk boundaries — the ideal ByteSpan analogue: rows are random-access,
    so no line-boundary alignment pass is ever needed and the spans
    partition the rows exactly by construction."""
    footer = read_footer(path)
    chunks = footer["chunks"]
    if n_units <= 1 or len(chunks) <= 1:
        return None
    groups = even_groups(chunks, n_units)
    return [RowSpan(path, g[0]["lo"], g[-1]["hi"]) for g in groups]
