"""Host-side tracer: the framework's own executions emit Pipit-native traces.

This closes the paper's loop — the training/serving runtime is *itself* a
trace source.  Events use the uniform data model (§III-A): Enter/Leave pairs
with nanosecond timestamps per logical process.  ``to_trace()`` returns a
:class:`repro.core.Trace`; ``save_jsonl`` writes the native format the
``repro.readers.jsonl`` reader loads back.
"""

from __future__ import annotations

import contextlib
import json
import time
from typing import Dict, List, Optional

import numpy as np

from ..core.constants import (ENTER, ET, LEAVE, MPI_RECV, MPI_SEND, MSG_SIZE,
                              NAME, PARTNER, PROC, TAG, TS)
from ..core.frame import EventFrame
from ..core.trace import Trace

__all__ = ["Tracer"]


class Tracer:
    def __init__(self, process: int = 0, clock=time.perf_counter_ns):
        self.process = process
        self.clock = clock
        self._t0 = clock()
        self.ts: List[int] = []
        self.et: List[str] = []
        self.name: List[str] = []
        self.proc: List[int] = []
        self.partner: List[int] = []
        self.size: List[float] = []

    def _now(self) -> int:
        return self.clock() - self._t0

    def enter(self, name: str, proc: Optional[int] = None) -> None:
        self._push(self._now(), ENTER, name, proc)

    def leave(self, name: str, proc: Optional[int] = None) -> None:
        self._push(self._now(), LEAVE, name, proc)

    def instant(self, name: str, proc: Optional[int] = None,
                partner: int = -1, size: float = float("nan"),
                et: str = "Instant") -> None:
        self._push(self._now(), et, name, proc, partner, size)

    def message(self, kind: str, partner: int, size: float,
                proc: Optional[int] = None) -> None:
        """kind: 'send' | 'recv' — models collective traffic as messages."""
        name = MPI_SEND if kind == "send" else MPI_RECV
        self._push(self._now(), "Mpi" + kind.capitalize(), name, proc,
                   partner, size)

    def _push(self, ts, et, name, proc, partner=-1, size=float("nan")):
        self.ts.append(ts)
        self.et.append(et)
        self.name.append(name)
        self.proc.append(self.process if proc is None else proc)
        self.partner.append(partner)
        self.size.append(size)

    @contextlib.contextmanager
    def span(self, name: str, proc: Optional[int] = None):
        self.enter(name, proc)
        try:
            yield
        finally:
            self.leave(name, proc)

    # -- output ----------------------------------------------------------------
    def to_trace(self, label: Optional[str] = None) -> Trace:
        ev = EventFrame({
            TS: np.asarray(self.ts, np.float64),
            ET: np.asarray(self.et),
            NAME: np.asarray(self.name),
            PROC: np.asarray(self.proc, np.int64),
            PARTNER: np.asarray(self.partner, np.int64),
            MSG_SIZE: np.asarray(self.size, np.float64),
            TAG: np.zeros(len(self.ts), np.int64),
        })
        return Trace.from_events(ev.sort_by([PROC, TS]), label=label)

    def save_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for i in range(len(self.ts)):
                d: Dict = {"ts": int(self.ts[i]), "et": self.et[i],
                           "name": self.name[i], "proc": int(self.proc[i])}
                if self.partner[i] >= 0:
                    d["partner"] = int(self.partner[i])
                    d["size"] = float(self.size[i])
                f.write(json.dumps(d) + "\n")
