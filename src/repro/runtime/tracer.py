"""Host-side tracer: the framework's own executions emit Pipit-native traces.

This closes the paper's loop — the training/serving runtime is *itself* a
trace source.  Events use the uniform data model (§III-A): Enter/Leave pairs
with nanosecond timestamps per logical process.  ``to_trace()`` returns a
:class:`repro.core.Trace`; ``save_jsonl`` writes the native format the
``repro.readers.jsonl`` reader loads back.

**Live mode** (``sink="rank_0.pack"``): the tracer spills its buffer to an
append-mode pack shard (:meth:`repro.readers.pack.PackWriter.open_append`)
every ``flush_every`` events *and* at least every ``heartbeat_interval``
seconds, each flush ending in a durable commit plus an atomically-replaced
heartbeat record (``<sink>.hb``).  The buffer is therefore bounded — a
day-long training run cannot OOM the traced job — and a monitor process
(:class:`repro.core.liveset.LiveTraceSet`) can watch the shard directory,
query the committed prefix while the job runs, and classify this rank as
live/lagging/dead from the heartbeat.  A SIGKILLed tracer loses at most
the uncommitted tail since its last flush.

Without a sink the tracer buffers in memory exactly as before (bounded by
a one-time warning at ``max_buffer_events`` — it never drops events).
"""

from __future__ import annotations

import contextlib
import json
import os
import time
import warnings
from typing import Dict, List, Optional

import numpy as np

from ..core.constants import (ENTER, ET, LEAVE, MPI_RECV, MPI_SEND, MSG_SIZE,
                              NAME, PARTNER, PROC, TAG, TS)
from ..core.frame import EventFrame
from ..core.trace import Trace

__all__ = ["Tracer", "write_heartbeat", "read_heartbeat"]

#: wall-clock heartbeat cadence is checked every this many events, so the
#: hot _push path stays a couple of list appends
_HB_CHECK_EVERY = 256


def write_heartbeat(sink: str, rank: int, events: int, ts_max,
                    seq: int, wall: Optional[float] = None,
                    final: bool = False) -> str:
    """Atomically (tmp + rename) write the heartbeat record next to a
    shard: ``<sink>.hb`` with {rank, wall, events, ts_max, seq, pid,
    final}.  Readers classify the rank's liveness from ``wall`` age."""
    hb = {"rank": int(rank), "wall": time.time() if wall is None else wall,
          "events": int(events),
          "ts_max": None if ts_max is None else int(ts_max),
          "seq": int(seq), "pid": os.getpid(), "final": bool(final)}
    path = sink + ".hb"
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(hb, f)
    os.replace(tmp, path)
    return path


def read_heartbeat(sink: str) -> Optional[dict]:
    """The shard's heartbeat record, or None when absent/unparseable."""
    try:
        with open(sink + ".hb") as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


class Tracer:
    """Event recorder for one logical process (rank).

    ``sink=None`` (default): pure in-memory buffering, list-backed —
    ``to_trace()`` / ``save_jsonl`` consume the buffer.

    ``sink="<path>.pack"``: bounded-buffer live mode.  The buffer spills
    to an append-mode pack shard with a durable commit every
    ``flush_every`` events and at least every ``heartbeat_interval``
    seconds of wall time (checked every few hundred events), each flush
    also refreshing the ``<sink>.hb`` heartbeat.  ``close()`` flushes the
    tail and (by default) finalizes the shard into an ordinary pack.
    With a sink, ``to_trace()`` only sees the *unflushed tail* — open the
    shard itself (``Trace.open(sink, live=True)``) for the full stream.
    """

    def __init__(self, process: int = 0, clock=time.perf_counter_ns,
                 sink: Optional[str] = None, flush_every: int = 50_000,
                 heartbeat_interval: float = 1.0, fsync: bool = True,
                 max_buffer_events: int = 2_000_000,
                 chunk_rows: Optional[int] = None,
                 wall_clock=time.time):
        self.process = process
        self.clock = clock
        self._t0 = clock()
        self.sink = os.fspath(sink) if sink is not None else None
        self.flush_every = int(flush_every)
        if self.flush_every <= 0:
            raise ValueError("flush_every must be positive")
        self.heartbeat_interval = float(heartbeat_interval)
        self.max_buffer_events = int(max_buffer_events)
        self._chunk_rows = chunk_rows or self.flush_every
        self._fsync = bool(fsync)
        self._wall = wall_clock
        self._writer = None          # lazily-opened append PackWriter
        self._flushed_events = 0     # events committed to the sink
        self._flush_seq = 0
        self._last_hb = self._wall()
        self._last_ts: Optional[int] = None
        self._warned_unbounded = False
        self._closed = False
        self.ts: List[int] = []
        self.et: List[str] = []
        self.name: List[str] = []
        self.proc: List[int] = []
        self.partner: List[int] = []
        self.size: List[float] = []

    def _now(self) -> int:
        return self.clock() - self._t0

    def enter(self, name: str, proc: Optional[int] = None) -> None:
        self._push(self._now(), ENTER, name, proc)

    def leave(self, name: str, proc: Optional[int] = None) -> None:
        self._push(self._now(), LEAVE, name, proc)

    def instant(self, name: str, proc: Optional[int] = None,
                partner: int = -1, size: float = float("nan"),
                et: str = "Instant") -> None:
        self._push(self._now(), et, name, proc, partner, size)

    def message(self, kind: str, partner: int, size: float,
                proc: Optional[int] = None) -> None:
        """kind: 'send' | 'recv' — models collective traffic as messages."""
        name = MPI_SEND if kind == "send" else MPI_RECV
        self._push(self._now(), "Mpi" + kind.capitalize(), name, proc,
                   partner, size)

    def _push(self, ts, et, name, proc, partner=-1, size=float("nan")):
        self.ts.append(ts)
        self.et.append(et)
        self.name.append(name)
        self.proc.append(self.process if proc is None else proc)
        self.partner.append(partner)
        self.size.append(size)
        n = len(self.ts)
        if self.sink is not None:
            if n >= self.flush_every:
                self.flush()
            elif n % _HB_CHECK_EVERY == 0 and \
                    self._wall() - self._last_hb >= self.heartbeat_interval:
                self.flush()
        elif n > self.max_buffer_events and not self._warned_unbounded:
            self._warned_unbounded = True
            warnings.warn(
                f"Tracer buffer passed {self.max_buffer_events} events "
                f"with no sink — a long run will exhaust memory.  Pass "
                f"sink='<shard>.pack' to spill with bounded memory "
                f"(flush_every={self.flush_every}).",
                RuntimeWarning, stacklevel=3)

    @contextlib.contextmanager
    def span(self, name: str, proc: Optional[int] = None):
        self.enter(name, proc)
        try:
            yield
        finally:
            self.leave(name, proc)

    # -- live sink ---------------------------------------------------------
    def _tail_frame(self) -> EventFrame:
        return EventFrame({
            TS: np.asarray(self.ts, np.int64),
            ET: np.asarray(self.et),
            NAME: np.asarray(self.name),
            PROC: np.asarray(self.proc, np.int64),
            PARTNER: np.asarray(self.partner, np.int64),
            MSG_SIZE: np.asarray(self.size, np.float64),
            TAG: np.zeros(len(self.ts), np.int64),
        })

    def _clear(self) -> None:
        for lst in (self.ts, self.et, self.name, self.proc, self.partner,
                    self.size):
            lst.clear()

    def flush(self) -> dict:
        """Spill the buffer to the sink as one durable commit, refresh the
        heartbeat, clear the buffer.  Returns the shard watermark.  No-op
        buffer still commits (syncs) and heartbeats — an idle rank keeps
        proving it is alive."""
        if self.sink is None:
            raise RuntimeError("Tracer has no sink to flush to")
        if self._closed:
            raise RuntimeError("Tracer is closed")
        if self._writer is None:
            from ..readers.pack import PackWriter
            self._writer = PackWriter.open_append(
                self.sink, chunk_rows=self._chunk_rows, fsync=self._fsync)
        n = len(self.ts)
        if n:
            self._last_ts = int(self.ts[-1])
            self._writer.append(self._tail_frame())
            self._clear()
        wm = self._writer.commit()
        self._flushed_events += n
        self._flush_seq += 1
        self._last_hb = self._wall()
        write_heartbeat(self.sink, self.process, self._flushed_events,
                        self._last_ts, self._flush_seq, wall=self._last_hb)
        return wm

    def close(self, finalize: bool = True, sidecar: bool = False) -> None:
        """Flush the tail and stop writing.  ``finalize=True`` seals the
        shard's footer (it becomes an ordinary pack; ``sidecar=True`` also
        derives/stores the structure sidecar — one whole-shard pass).  The
        final heartbeat is marked ``final`` so monitors report a clean
        shutdown instead of a dead rank."""
        if self.sink is None or self._closed:
            self._closed = True
            return
        self.flush()
        if self._writer is not None and finalize:
            self._writer.finalize(sidecar=sidecar)
        elif self._writer is not None:
            self._writer._out.close()
        write_heartbeat(self.sink, self.process, self._flushed_events,
                        self._last_ts, self._flush_seq, final=True)
        self._writer = None
        self._closed = True

    # -- output ----------------------------------------------------------------
    def to_trace(self, label: Optional[str] = None) -> Trace:
        """The buffered events as an in-memory Trace.  With a sink this is
        only the unflushed tail — open the shard (``Trace.open(sink,
        live=True)``) for everything committed."""
        ev = EventFrame({
            TS: np.asarray(self.ts, np.float64),
            ET: np.asarray(self.et),
            NAME: np.asarray(self.name),
            PROC: np.asarray(self.proc, np.int64),
            PARTNER: np.asarray(self.partner, np.int64),
            MSG_SIZE: np.asarray(self.size, np.float64),
            TAG: np.zeros(len(self.ts), np.int64),
        })
        return Trace.from_events(ev.sort_by([PROC, TS]), label=label)

    def save_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for i in range(len(self.ts)):
                d: Dict = {"ts": int(self.ts[i]), "et": self.et[i],
                           "name": self.name[i], "proc": int(self.proc[i])}
                if self.partner[i] >= 0:
                    d["partner"] = int(self.partner[i])
                    d["size"] = float(self.size[i])
                f.write(json.dumps(d) + "\n")
