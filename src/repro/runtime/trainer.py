"""Instrumented, fault-tolerant training runtime.

Features targeted at 1000+-node operation, exercised at container scale by
the tests and examples:

* **microbatched** train step (gradient accumulation via ``lax.scan``),
* **sharded** params/optimizer via the logical-axis rules (FSDP×TP×EP),
* **checkpoint/restart**: async checkpoints every N steps; ``run`` survives
  injected faults by restoring the latest committed checkpoint and re-seeking
  the deterministic data stream,
* **straggler detection**: per-step wall-time EMA; outliers raise a
  mitigation callback (in production: re-slice / hot-spare swap; here:
  recorded in the trace so Pipit's outlier analysis can find it),
* **tracing**: every phase emits Pipit events (the paper's technique applied
  to the framework itself).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..models import build_model
from ..models.config import ModelConfig
from ..optim import adamw_init, adamw_update, cosine_schedule
from .tracer import Tracer

__all__ = ["Trainer", "TrainLoopConfig", "FaultInjector", "SimulatedFault"]


class SimulatedFault(RuntimeError):
    """Raised by FaultInjector to emulate a node loss / preemption."""


class FaultInjector:
    def __init__(self, fail_at_steps: Iterable[int] = ()):
        self.fail_at = set(fail_at_steps)
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFault(f"injected fault at step {step}")


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    microbatches: int = 1
    peak_lr: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    ckpt_every: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10
    seed: int = 0
    dtype: Any = jnp.float32


class Trainer:
    def __init__(self, model_cfg: ModelConfig, loop: TrainLoopConfig,
                 tracer: Optional[Tracer] = None,
                 mesh=None, shardings: Optional[Dict[str, Any]] = None,
                 straggler_callback: Optional[Callable[[int, float], None]] = None):
        self.cfg = model_cfg
        self.loop = loop
        self.tracer = tracer or Tracer()
        self.mesh = mesh
        self.model = build_model(model_cfg)
        self.straggler_callback = straggler_callback
        self._step_times: list = []
        self._ema: Optional[float] = None
        self.straggler_events = 0

        with self.tracer.span("init"):
            key = jax.random.PRNGKey(loop.seed)
            self.params = jax.jit(lambda k: self.model.init(k, loop.dtype))(key)
            self.opt_state = jax.jit(adamw_init)(self.params)
        self.step = 0
        self.ckpt = CheckpointManager(loop.ckpt_dir, keep=loop.ckpt_keep) \
            if loop.ckpt_every else None
        self._train_step = self._build_train_step()

    # ------------------------------------------------------------------
    def _build_train_step(self):
        model, loop = self.model, self.loop
        M = loop.microbatches

        def train_step(params, opt_state, batch):
            def micro(g_acc, mb):
                loss, g = jax.value_and_grad(model.loss)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return g_acc, loss

            if M > 1:
                mbs = jax.tree_util.tree_map(
                    lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                    batch)
                gz = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                g_acc, losses = jax.lax.scan(micro, gz, mbs)
                grads = jax.tree_util.tree_map(lambda g: g / M, g_acc)
                loss = losses.mean()
            else:
                loss, grads = jax.value_and_grad(model.loss)(params, batch)
            lr = cosine_schedule(opt_state.step, loop.peak_lr,
                                 loop.warmup_steps, loop.steps)
            params, opt_state = adamw_update(
                params, grads, opt_state, lr,
                weight_decay=loop.weight_decay, clip_norm=loop.clip_norm)
            return params, opt_state, loss

        return jax.jit(train_step, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def train_one(self, batch: Dict[str, np.ndarray], step: int,
                  fault: Optional[FaultInjector] = None) -> float:
        t0 = time.perf_counter()
        with self.tracer.span("train_step"):
            self.params, self.opt_state, loss = self._train_step(
                self.params, self.opt_state, batch)
            loss = float(loss)
        if fault is not None:
            fault.maybe_fail(step)
        dt = time.perf_counter() - t0
        self._observe_step_time(step, dt)
        return loss

    def _observe_step_time(self, step: int, dt: float) -> None:
        if self._ema is None:
            self._ema = dt
        if dt > self.loop.straggler_factor * self._ema and step > 2:
            self.straggler_events += 1
            self.tracer.instant("straggler_suspected")
            if self.straggler_callback:
                self.straggler_callback(step, dt / self._ema)
        self._ema = 0.9 * self._ema + 0.1 * dt
        self._step_times.append(dt)

    # ------------------------------------------------------------------
    def save_ckpt(self) -> None:
        if self.ckpt is None:
            return
        with self.tracer.span("checkpoint"):
            self.ckpt.save(self.step, {"params": self.params,
                                       "opt": self.opt_state},
                           extra={"model": self.cfg.name})

    def restore_latest(self) -> bool:
        if self.ckpt is None:
            return False
        self.ckpt.wait()   # an in-flight async write may hold the newest step
        step = self.ckpt.latest_step()
        if step is None:
            return False
        with self.tracer.span("restore"):
            state = self.ckpt.restore(step, {"params": self.params,
                                             "opt": self.opt_state})
            self.params = jax.tree_util.tree_map(jnp.asarray, state["params"])
            self.opt_state = jax.tree_util.tree_map(jnp.asarray, state["opt"])
            self.step = step
        return True

    # ------------------------------------------------------------------
    def run(self, stream, fault: Optional[FaultInjector] = None,
            max_restarts: int = 3) -> Dict[str, Any]:
        """Train loop with restart-on-fault.  Returns summary stats."""
        losses = []
        restarts = 0
        loop = self.loop
        with self.tracer.span("train"):
            while self.step < loop.steps:
                try:
                    with self.tracer.span("data_wait"):
                        batch = stream.batch_at(self.step)
                    loss = self.train_one(batch, self.step, fault)
                    losses.append(loss)
                    self.step += 1
                    if loop.ckpt_every and self.step % loop.ckpt_every == 0:
                        self.save_ckpt()
                except SimulatedFault:
                    restarts += 1
                    self.tracer.instant("fault")
                    if restarts > max_restarts:
                        raise
                    if not self.restore_latest():
                        self.step = 0  # cold restart
                        key = jax.random.PRNGKey(loop.seed)
                        self.params = jax.jit(
                            lambda k: self.model.init(k, loop.dtype))(key)
                        self.opt_state = jax.jit(adamw_init)(self.params)
        if self.ckpt is not None:
            self.ckpt.wait()
        return {"losses": losses, "restarts": restarts,
                "straggler_events": self.straggler_events,
                "steps": self.step,
                "mean_step_time": float(np.mean(self._step_times[1:]))
                if len(self._step_times) > 1 else None}
