from .tracer import Tracer
from .trainer import Trainer, TrainLoopConfig, FaultInjector

__all__ = ["Tracer", "Trainer", "TrainLoopConfig", "FaultInjector"]
