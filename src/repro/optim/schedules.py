"""Learning-rate schedules (pure functions of the step scalar)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["linear_warmup", "cosine_schedule"]


def linear_warmup(step, peak_lr: float, warmup_steps: int):
    s = jnp.asarray(step, jnp.float32)
    return peak_lr * jnp.minimum(1.0, (s + 1) / max(warmup_steps, 1))


def cosine_schedule(step, peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = linear_warmup(step, peak_lr, warmup_steps)
    prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                    0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup_steps, warm, peak_lr * cos)
