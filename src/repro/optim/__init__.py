from .adamw import AdamWState, adamw_init, adamw_update, global_norm
from .schedules import cosine_schedule, linear_warmup

__all__ = ["AdamWState", "adamw_init", "adamw_update", "global_norm",
           "cosine_schedule", "linear_warmup"]
