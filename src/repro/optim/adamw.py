"""Decoupled AdamW with f32 moments, global-norm clipping, ZeRO-1 sharding.

Moments mirror the parameter pytree shape-for-shape, so the parameter
PartitionSpecs apply verbatim — FSDP-sharded params get FSDP-sharded
optimizer state (ZeRO) with zero extra code.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "global_norm"]


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def abstract_adamw_state(abstract_params) -> AdamWState:
    z = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=z, v=z)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(params, grads, state: AdamWState, lr,
                 *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: Optional[float] = 1.0):
    """Returns (new_params, new_state).  ``lr`` may be a traced scalar."""
    step = state.step + 1
    if clip_norm is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-12))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
