"""Production mesh construction.

A *function*, not a module-level constant — importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any jax
device query).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods × 256 chips as (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist locally, as a 1×N (data, model) mesh — used by
    tests and the CPU examples."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
