import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_BASE_XLA", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST run before any other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture × shape) cell on the
production mesh and record memory/cost/collective statistics.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]

    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` with
``memory_analysis()``, ``cost_analysis()``, the parsed per-device collective
wire bytes, and the three-term roofline — the artifacts EXPERIMENTS.md
§Dry-run/§Roofline and ``benchmarks/roofline.py`` read.  ``--save-hlo`` also
dumps the partitioned HLO for the Pipit HLO reader.
"""

import argparse
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..analysis.hlostats import collective_stats
from ..analysis.roofline import roofline_terms
from ..configs import ARCH_NAMES, get_config
from ..models.config import SHAPES
from .mesh import make_production_mesh
from .steps import build_cell

SKIP = {
    # long_500k needs a bounded cache: pure full-attention archs are excluded
    # by the assignment (see DESIGN.md §Shape skips)
    ("whisper-medium", "long_500k"),
    ("qwen2-moe-a2.7b", "long_500k"),
    ("qwen3-moe-235b-a22b", "long_500k"),
    ("qwen1.5-110b", "long_500k"),
    ("qwen1.5-0.5b", "long_500k"),
    ("codeqwen1.5-7b", "long_500k"),
    ("phi-3-vision-4.2b", "long_500k"),
}


def _cell_costs(cfg, shape, mesh, chips):
    """Compile one program and pull (flops, bytes, wire_bytes) — all
    per-device (XLA SPMD cost analysis reports per-partition numbers)."""
    cell = build_cell(cfg, shape, mesh)
    compiled = cell.lower(mesh).compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        # jax < 0.5 returns a one-element list of per-program dicts
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_stats(hlo, default_group=chips)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll["total"]["wire_bytes"]), cell, compiled, coll, cost)


def _copies(u: int, T: int) -> int:
    """How many scan-body copies XLA's cost model sees at unroll=u, trip=T."""
    return T if T <= u else u + (T % u)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             save_hlo: bool = False, overrides=None) -> dict:
    """Three compiles per cell:

    * the *deployment* program (layer scan, unroll=1) → memory analysis,
      collective schedule, saved HLO;
    * two *cost probes* (inner scans fully unrolled; layer scan unroll 1 / 2)
      → exact per-layer FLOPs/bytes/wire-bytes, because XLA's cost model
      counts a scan body once regardless of trip count (measured; see
      EXPERIMENTS.md §Methodology).  Corrected totals:
          body = (F(u2) − F(u1)) / (copies(2,T) − 1)
          F*   = F(u1) + (T − 1) · body
    """
    import dataclasses as dc
    cfg = get_config(arch)
    if overrides:
        cfg = dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"

    t0 = time.time()
    f_main, b_main, w_main, cell, compiled, coll, cost = _cell_costs(
        cfg, shape, mesh, chips)
    t_main = time.time() - t0

    from ..models import build_model
    T = build_model(cfg).n_periods
    t0 = time.time()
    f1, b1, w1, *_ = _cell_costs(dc.replace(cfg, cost_probe=1), shape, mesh,
                                 chips)
    if T > 1:
        f2, b2, w2, *_ = _cell_costs(dc.replace(cfg, cost_probe=2), shape,
                                     mesh, chips)
        dc2 = _copies(2, T) - 1
        flops = f1 + (T - 1) * (f2 - f1) / dc2
        hbm_bytes = b1 + (T - 1) * (b2 - b1) / dc2
        wire = w1 + (T - 1) * (w2 - w1) / dc2
    else:
        flops, hbm_bytes, wire = f1, b1, w1
    t_probe = time.time() - t0

    mem = compiled.memory_analysis()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = cell.meta["active_params"]
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens

    rl = roofline_terms(flops * chips, hbm_bytes * chips, wire, chips,
                        model_flops)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "kind": shape.kind, "n_periods": T,
        "compile_s": round(t_main, 2), "probe_s": round(t_probe, 2),
        "params": cell.meta["params"], "active_params": n_active,
        "memory_analysis": {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "peak_size": (getattr(mem, "argument_size_in_bytes", 0) or 0)
                         + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "cost_analysis_raw": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))},
        "per_device": {"flops": flops, "hbm_bytes": hbm_bytes,
                       "wire_bytes": wire},
        "collectives_schedule": coll,
        "roofline": rl,
        "rules": {k: list(v) if isinstance(v, tuple) else v
                  for k, v in cell.meta["rules"].items()},
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        base = f"{arch}__{shape_name}__{mesh_name}"
        with open(os.path.join(out_dir, base + ".json"), "w") as f:
            json.dump(record, f, indent=1)
        if save_hlo:
            with gzip.open(os.path.join(out_dir, base + ".hlo.gz"), "wt") as f:
                f.write(compiled.as_text())
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_NAMES:
            if a == "pipit-lm-100m":
                continue
            for s in SHAPES:
                if (a, s) not in SKIP:
                    cells.append((a, s))
    else:
        cells = [(args.arch, args.shape)]

    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    failures = []
    for arch, shape in cells:
        base = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
        if args.skip_existing and os.path.exists(base):
            print(f"[skip] {arch} {shape} (exists)")
            continue
        try:
            r = run_cell(arch, shape, args.multi_pod, args.out, args.save_hlo)
            rl = r["roofline"]
            print(f"[ok] {arch:22s} {shape:12s} {mesh_name} "
                  f"compile={r['compile_s']:.1f}s "
                  f"compute={rl['compute_s']:.3e}s mem={rl['memory_s']:.3e}s "
                  f"coll={rl['collective_s']:.3e}s → {rl['bottleneck']}",
                  flush=True)
        except Exception as e:
            failures.append((arch, shape, repr(e)))
            print(f"[FAIL] {arch} {shape}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")
    print("dry-run complete.")


if __name__ == "__main__":
    main()
