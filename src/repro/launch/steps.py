"""Cell builder: for an (architecture × shape × mesh) cell, produce the step
function, its abstract inputs (ShapeDtypeStructs), and in/out shardings —
everything ``dryrun.py`` needs to ``.lower().compile()`` and everything
``train.py``/``serve.py`` need to run for real.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import (activation_sharding, batch_spec,
                                    logical_to_spec, rules_for, spec_tree)
from ..models import build_model, input_specs
from ..models.config import ModelConfig, ShapeConfig
from ..models.layers import abstract_tree
from ..optim import adamw_update, cosine_schedule
from ..optim.adamw import AdamWState, abstract_adamw_state

__all__ = ["Cell", "build_cell"]


@dataclasses.dataclass
class Cell:
    name: str
    fn: Callable
    abstract_args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    meta: Dict[str, Any]

    def lower(self, mesh: Mesh):
        with mesh:
            jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                             out_shardings=self.out_shardings,
                             donate_argnums=self.donate_argnums)
            return jitted.lower(*self.abstract_args)


def _named(mesh: Mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def _batch_shardings(mesh: Mesh, specs: Dict[str, jax.ShapeDtypeStruct]):
    out = {}
    for k, v in specs.items():
        if v.ndim == 0:
            out[k] = NamedSharding(mesh, P())
        else:
            bs = batch_spec(mesh, v.shape[0])
            pad = v.ndim - 1
            parts = list(bs) + [None] * pad
            out[k] = NamedSharding(mesh, P(*parts))
    return out


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               dtype=jnp.bfloat16, rules=None,
               lr_schedule: Optional[Callable] = None) -> Cell:
    msize = mesh.shape.get("model", 1)
    if (cfg.n_heads % msize == 0 and cfg.n_kv_heads % msize
            and (cfg.n_heads // cfg.n_kv_heads) % msize):
        cfg = dataclasses.replace(cfg, attn_broadcast_kv=True)
    if cfg.n_experts and shape.kind != "decode":
        # grouped MoE dispatch aligned with the data shards (§Perf iter. 2)
        dsize = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        T = shape.global_batch * shape.seq_len
        if T % dsize == 0:
            cfg = dataclasses.replace(cfg, moe_groups=dsize)
    model = build_model(cfg)
    rules = rules or rules_for(cfg, mesh,
                               long_context=shape.name == "long_500k")
    pdefs = model.param_defs()
    pspecs = spec_tree(pdefs, rules, mesh)
    pshard = _named(mesh, pspecs)
    aparams = abstract_tree(pdefs, dtype)
    inputs = input_specs(cfg, shape, dtype)
    meta = {"arch": cfg.name, "shape": shape.name, "rules": rules.as_dict(),
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count()}

    if shape.kind == "train":
        aopt = abstract_adamw_state(aparams)
        oshard = AdamWState(step=NamedSharding(mesh, P()),
                            m=_named(mesh, pspecs), v=_named(mesh, pspecs))
        bshard = _batch_shardings(mesh, inputs)

        def train_step(params, opt_state, batch):
            with activation_sharding(mesh, rules):
                loss, grads = jax.value_and_grad(model.loss)(params, batch)
            lr = (lr_schedule or (lambda s: cosine_schedule(s, 3e-4, 2000,
                                                            100_000)))(
                opt_state.step)
            params, opt_state = adamw_update(params, grads, opt_state, lr)
            return params, opt_state, loss

        return Cell(
            name=f"{cfg.name}:{shape.name}", fn=train_step,
            abstract_args=(aparams, aopt, inputs),
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, NamedSharding(mesh, P())),
            donate_argnums=(0, 1), meta=meta)

    if shape.kind == "prefill":
        bshard = _batch_shardings(mesh, inputs)
        cdefs = model.cache_defs(shape.global_batch, shape.seq_len)
        cspecs = spec_tree(cdefs, rules, mesh)

        def prefill(params, batch):
            kw = {k: v for k, v in batch.items() if k != "tokens"}
            with activation_sharding(mesh, rules):
                cache, logits, _ = model.prefill(params, batch["tokens"],
                                                 shape.seq_len, **kw)
            return cache, logits

        return Cell(
            name=f"{cfg.name}:{shape.name}", fn=prefill,
            abstract_args=(aparams, inputs),
            in_shardings=(pshard, bshard),
            out_shardings=(_named(mesh, cspecs),
                           NamedSharding(mesh, batch_spec(
                               mesh, shape.global_batch))),
            donate_argnums=(), meta=meta)

    # decode: one new token against a cache of seq_len entries
    B, S = shape.global_batch, shape.seq_len
    acache = model.init_cache(B, S, dtype, abstract=True)
    cdefs = model.cache_defs(B, S)
    cshard = _named(mesh, spec_tree(cdefs, rules, mesh))
    bshard = _batch_shardings(mesh, inputs)

    def serve_step(params, cache, batch):
        with activation_sharding(mesh, rules):
            logits, cache = model.decode_step(params, cache, batch["token"],
                                              batch["pos"], S)
        return logits, cache

    return Cell(
        name=f"{cfg.name}:{shape.name}", fn=serve_step,
        abstract_args=(aparams, acache, inputs),
        in_shardings=(pshard, cshard, bshard),
        out_shardings=(NamedSharding(mesh, batch_spec(mesh, B)), cshard),
        donate_argnums=(1,), meta=meta)


def build_compressed_dp_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                             dtype=jnp.bfloat16,
                             lr_schedule: Optional[Callable] = None) -> Cell:
    """Cross-pod data parallelism with an **int8 gradient wire format**.

    Layout: FSDP×TP *within* a pod; params/optimizer replicated *across*
    pods; each pod computes gradients for its batch shard and the cross-pod
    mean runs over a ppermute'd int8 payload
    (`distributed.compression.pairwise_compressed_mean`) — 2× less inter-pod
    (DCN) traffic than a bf16 all-reduce at 2 pods.  Built with a
    partial-auto shard_map: only ``pod`` is manual; ``data``/``model`` stay
    GSPMD-auto so every activation constraint applies unchanged.

    STATUS: experimental.  The collective itself is validated end-to-end
    (tests/test_distributed.py::test_pairwise_compressed_mean_int8_wire:
    s8 collective-permute on the wire, <2% quantization error, exact with
    error feedback).  Lowering the *full model* under partial-manual
    shard_map currently trips an XLA SPMD-partitioner CHECK
    (spmd_partitioner_util.cc:504, gather partitioning inside a
    partial-manual region; jax 0.8.2) — upstream bug, reproducer kept in
    EXPERIMENTS.md §Perf; the production path remains FSDP-over-(pod,data).
    """
    from ..distributed.compression import pairwise_compressed_mean
    from ..distributed.sharding import shard_map_compat

    assert "pod" in mesh.shape and shape.kind == "train"
    n_pods = mesh.shape["pod"]
    msize = mesh.shape.get("model", 1)
    if (cfg.n_heads % msize == 0 and cfg.n_kv_heads % msize
            and (cfg.n_heads // cfg.n_kv_heads) % msize):
        cfg = dataclasses.replace(cfg, attn_broadcast_kv=True)
    if cfg.n_experts:
        dsize = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        T = shape.global_batch * shape.seq_len
        if T % dsize == 0:
            cfg = dataclasses.replace(cfg, moe_groups=dsize)
    # params replicated across pod → FSDP over data only.  vocab/embedding
    # stays replicated along `model`: a vocab-sharded gather inside the
    # partial-manual region trips an XLA SPMD-partitioner CHECK
    # (spmd_partitioner_util.cc:504, jax 0.8.2) — documented workaround.
    rules = rules_for(cfg, mesh).override(embed=("data",),
                                          batch=("pod", "data"),
                                          vocab=None, act_vocab=None)
    model = build_model(cfg)
    pdefs = model.param_defs()
    pspecs = spec_tree(pdefs, rules, mesh)
    pshard = _named(mesh, pspecs)
    aparams = abstract_tree(pdefs, dtype)
    aopt = abstract_adamw_state(aparams)
    oshard = AdamWState(step=NamedSharding(mesh, P()),
                        m=_named(mesh, pspecs), v=_named(mesh, pspecs))
    inputs = input_specs(cfg, shape, dtype)
    bshard = _batch_shardings(mesh, inputs)

    def train_step(params, opt_state, batch):
        def per_pod(params, opt_state, batch):
            with activation_sharding(mesh, rules,
                                     manual_axes=frozenset({"pod"})):
                loss, grads = jax.value_and_grad(model.loss)(params, batch)
            flat, tree = jax.tree_util.tree_flatten(grads)
            red = [pairwise_compressed_mean(g, "pod", n_pods)[0]
                   for g in flat]
            grads = jax.tree_util.tree_unflatten(tree, red)
            lr = (lr_schedule or (lambda s: cosine_schedule(
                s, 3e-4, 2000, 100_000)))(opt_state.step)
            params, opt_state = adamw_update(params, grads, opt_state, lr)
            return params, opt_state, jax.lax.pmean(loss, "pod")

        in_specs = (jax.tree_util.tree_map(lambda s: P(), params),
                    jax.tree_util.tree_map(lambda s: P(), opt_state,
                                           is_leaf=lambda x: hasattr(x, "shape")),
                    {k: (P("pod") if getattr(v, "ndim", 0) else P())
                     for k, v in batch.items()})
        out_specs = in_specs[:2] + (P(),)
        return shard_map_compat(per_pod, mesh, in_specs, out_specs,
                                manual_axes=frozenset({"pod"})
                                )(params, opt_state, batch)

    return Cell(
        name=f"{cfg.name}:{shape.name}:int8dp", fn=train_step,
        abstract_args=(aparams, aopt, inputs),
        in_shardings=(pshard, oshard, bshard),
        out_shardings=(pshard, oshard, NamedSharding(mesh, P())),
        donate_argnums=(0, 1),
        meta={"arch": cfg.name, "shape": shape.name,
              "rules": rules.as_dict(), "params": cfg.param_count(),
              "active_params": cfg.active_param_count(),
              "grad_wire": "int8+error-feedback"})
