"""Serving launcher: batched prefill+decode over a synthetic request queue.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 8 --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from ..configs import get_config, get_smoke_config
from ..runtime import Tracer
from ..serving import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--trace", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tracer = Tracer()
    eng = ServeEngine(cfg, batch=args.batch, cache_len=args.cache_len,
                      tracer=tracer)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab,
                                    rng.integers(4, args.prompt_len + 1),
                                    dtype=np.int32).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = eng.serve_queue(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(json.dumps({
        "arch": cfg.name, "requests": len(done),
        "generated_tokens": toks, "wall_s": round(dt, 3),
        "tok_per_s": round(toks / dt, 2),
    }, indent=1))
    if args.trace:
        tracer.save_jsonl(args.trace)
        print(f"trace written to {args.trace}")


if __name__ == "__main__":
    main()
