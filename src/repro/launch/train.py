"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch pipit-lm-100m \
        --steps 200 --batch 16 --seq 256 [--smoke] [--trace out.jsonl]

On real hardware this builds the production mesh and the pjit'd cell from
``launch.steps``; on this container it runs the Trainer on the local device
(optionally with a reduced config) and emits a Pipit trace of the run.
"""

from __future__ import annotations

import argparse
import json

import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..data import SyntheticLMStream
from ..runtime import Tracer, Trainer, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pipit-lm-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--trace", default=None,
                    help="write the run's Pipit trace (jsonl) here")
    ap.add_argument("--f32", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    loop = TrainLoopConfig(
        steps=args.steps, microbatches=args.microbatches, peak_lr=args.lr,
        warmup_steps=max(args.steps // 10, 1), ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        dtype=jnp.float32 if args.f32 else jnp.bfloat16)
    tracer = Tracer()
    trainer = Trainer(cfg, loop, tracer=tracer)
    stream = SyntheticLMStream(cfg.vocab, args.batch, args.seq)
    out = trainer.run(stream)
    stream.close()
    losses = out["losses"]
    print(json.dumps({
        "arch": cfg.name, "steps": out["steps"],
        "loss_first": losses[0], "loss_last": losses[-1],
        "mean_step_time_s": out["mean_step_time"],
        "straggler_events": out["straggler_events"],
    }, indent=1))
    if args.trace:
        tracer.save_jsonl(args.trace)
        print(f"trace written to {args.trace}")


if __name__ == "__main__":
    main()
