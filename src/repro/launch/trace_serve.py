"""Trace-query service launcher.

    PYTHONPATH=src python -m repro.launch.trace_serve \
        --host 127.0.0.1 --port 8731 --max-handles 8 \
        --per-tenant 4 --tenant-quota 32

Starts the multi-tenant trace-query server
(:mod:`repro.serving.tracequery`): pooled pack-backed handles, shared
plan cache with per-tenant quotas, single-flight plan coalescing, and
admission-controlled execution on the shared scheduler's
interactive/bulk lanes.  ``--port 0`` binds a free port; ``--announce``
prints one ``SERVING {"host": ..., "port": ...}`` line once the socket
is live (the benchmark and CI smoke job parse it).  Stop with SIGINT or
``POST /shutdown`` (graceful drain).
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser(
        description="multi-tenant trace-query service")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8731,
                    help="listen port (0 = pick a free port)")
    ap.add_argument("--announce", action="store_true",
                    help='print "SERVING {json}" once bound')
    ap.add_argument("--max-handles", type=int, default=8,
                    help="open trace handles kept warm (LRU)")
    ap.add_argument("--max-active", type=int, default=32,
                    help="queries admitted at once, all tenants")
    ap.add_argument("--per-tenant", type=int, default=4,
                    help="concurrent queries per tenant")
    ap.add_argument("--tenant-quota", type=int, default=None,
                    help="plan-cache entries per tenant (default: no cap)")
    ap.add_argument("--cache-entries", type=int, default=None,
                    help="global plan-cache LRU bound")
    ap.add_argument("--workers", type=int, default=None,
                    help="total execution threads (default: CPU count)")
    ap.add_argument("--interactive-workers", type=int, default=None,
                    help="threads reserved for the interactive lane")
    args = ap.parse_args()

    from ..core.scheduler import Scheduler, set_scheduler
    from ..serving.tracequery import serve

    if args.workers is not None or args.interactive_workers is not None:
        set_scheduler(Scheduler(workers=args.workers,
                                interactive_workers=args.interactive_workers))

    try:
        serve(host=args.host, port=args.port, announce=args.announce,
              max_handles=args.max_handles, max_active=args.max_active,
              per_tenant=args.per_tenant, tenant_quota=args.tenant_quota,
              cache_entries=args.cache_entries)
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main()
