from .hlostats import collective_stats, shape_bytes, DTYPE_BYTES
from .roofline import roofline_terms, HW

__all__ = ["collective_stats", "shape_bytes", "DTYPE_BYTES",
           "roofline_terms", "HW"]
