"""HLO dot inventory: enumerate every ``dot`` in an HLO module with resolved
operand shapes and FLOPs — the profile substitute the perf loop reads (no
real-TPU timings exist in this container; the lowered IR *is* the profile).

Two passes:
1. collect every instruction definition ``%name = type[dims]{...} ...`` and
   every computation's body, plus while-loop trip counts (parsed from the
   loop condition's comparison constant);
2. for each ``dot``, resolve operand shapes by name, read the contracting
   dims, and compute FLOPs = 2 × prod(result) × prod(contracting).

``summarize_dots`` aggregates by (computation × shape signature) and applies
trip-count multipliers so scanned-body dots are weighted honestly.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

__all__ = ["dot_inventory", "summarize_dots", "while_trip_counts"]

_DEF = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_DOT_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w\.\-]+\s*=\s*\w+\[([\d,]*)\][^=]*?\bdot\("
    r"\s*%([\w\.\-]+)\s*,\s*%([\w\.\-]+)\s*\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_WHILE = re.compile(r"while\(.*?\)\s*,\s*condition=%?([\w\.\-]+)\s*,\s*body=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def _parse_module(hlo: str):
    """Returns (shapes by (comp, name), comp of each line, comp bodies,
    while edges [(caller_comp, cond, body)])."""
    shapes: Dict[str, Tuple[int, ...]] = {}
    comp = "?"
    comp_lines: Dict[str, List[str]] = defaultdict(list)
    whiles: List[Tuple[str, str, str]] = []
    for line in hlo.splitlines():
        hdr = _COMP_HDR.match(line.strip()) if line and not line.startswith(" ") else None
        if hdr and "{" in line:
            comp = hdr.group(1)
        m = _DEF.match(line)
        if m:
            name, _, dims = m.groups()
            shapes[name] = tuple(int(x) for x in dims.split(",") if x)
        w = _WHILE.search(line)
        if w:
            whiles.append((comp, w.group(1), w.group(2)))
        comp_lines[comp].append(line)
    return shapes, comp_lines, whiles


def while_trip_counts(hlo: str) -> Dict[str, int]:
    """body-computation name → trip count (best effort: the largest integer
    constant in the condition computation)."""
    shapes, comp_lines, whiles = _parse_module(hlo)
    out = {}
    for _, cond, body in whiles:
        consts = []
        for line in comp_lines.get(cond, []):
            consts += [int(x) for x in _CONST_INT.findall(line)]
        out[body] = max(consts) if consts else 1
    return out


def dot_inventory(hlo: str) -> List[Dict]:
    shapes, comp_lines, whiles = _parse_module(hlo)
    trips = while_trip_counts(hlo)
    # computations transitively inside a while body inherit its trip count
    body_mult: Dict[str, int] = defaultdict(lambda: 1)
    for body, t in trips.items():
        body_mult[body] = max(body_mult[body], t)
    out = []
    for comp, lines in comp_lines.items():
        mult = body_mult[comp]
        for line in lines:
            m = _DOT_LINE.match(line)
            if not m:
                continue
            res_dims = tuple(int(x) for x in m.group(1).split(",") if x)
            lhs = shapes.get(m.group(2), ())
            c = _CONTRACT.search(line)
            cdims = [int(x) for x in c.group(1).split(",") if x] if c else []
            k = 1
            for ci in cdims:
                if ci < len(lhs):
                    k *= lhs[ci]
            res = 1
            for d in res_dims:
                res *= d
            out.append({
                "computation": comp, "trip_mult": mult,
                "result": "x".join(map(str, res_dims)) or "scalar",
                "lhs": "x".join(map(str, lhs)),
                "flops": 2.0 * res * k,
                "flops_weighted": 2.0 * res * k * mult,
            })
    return out


def summarize_dots(hlo: str, top: int = 20) -> List[Tuple[str, float, int]]:
    agg: Dict[str, List[float]] = defaultdict(lambda: [0.0, 0])
    for d in dot_inventory(hlo):
        key = (f"[{d['lhs']}]·→[{d['result']}] ×{d['trip_mult']} "
               f"@{d['computation'][:28]}")
        agg[key][0] += d["flops_weighted"]
        agg[key][1] += 1
    rows = sorted(((k, v[0], v[1]) for k, v in agg.items()),
                  key=lambda r: -r[1])
    return rows[:top]
