"""HLO text analysis: collective-traffic accounting for the roofline.

``cost_analysis()`` reports FLOPs and HBM bytes but not collective traffic, so
we parse the (SPMD-partitioned) HLO text and sum operand sizes of every
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute``, converting to *per-device bytes moved on the wire*
with the standard ring-algorithm factors:

    all-gather        (g-1)/g × result_bytes
    all-reduce      2·(g-1)/g × operand_bytes
    reduce-scatter    (g-1)/g × operand_bytes
    all-to-all        (g-1)/g × operand_bytes
    collective-permute          operand_bytes

where g is the replica-group size parsed from the op's ``replica_groups``.
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

__all__ = ["collective_stats", "shape_bytes", "DTYPE_BYTES", "iter_collectives"]

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# e.g.:  %ag = bf16[16,512]{1,0} all-gather(bf16[16,32]{1,0} %x), ...
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[\w\[\],{}]+)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(shape_str: str) -> int:
    """'bf16[16,512]' → bytes."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dt, 4)


def _line_shapes(line: str) -> List[str]:
    return [f"{m.group(1)}[{m.group(2)}]" for m in _SHAPE_RE.finditer(line)
            if m.group(1) in DTYPE_BYTES]


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return default


def iter_collectives(hlo_text: str, default_group: int = 1):
    """Yields (kind, result_bytes, operand_bytes, group_size, line)."""
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in line:    # async pair: count the -start only
            continue
        shapes = _line_shapes(line)
        if not shapes:
            continue
        result_b = shape_bytes(shapes[0])
        # operands: shapes appearing inside the call parens; approximate as
        # all shapes after the result
        operand_b = sum(shape_bytes(s) for s in shapes[1:]) or result_b
        g = _group_size(line, default_group)
        yield kind, result_b, operand_b, g, line


def collective_stats(hlo_text: str, default_group: int = 1
                     ) -> Dict[str, Dict[str, float]]:
    """Per-kind totals + 'total' row with per-device wire bytes."""
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "operand_bytes": 0.0, "wire_bytes": 0.0}
        for k in _COLL_KINDS}
    for kind, res_b, op_b, g, _ in iter_collectives(hlo_text, default_group):
        fac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-gather":
            wire = fac * res_b
        elif kind == "all-reduce":
            wire = 2.0 * fac * op_b
        elif kind in ("reduce-scatter", "all-to-all"):
            wire = fac * op_b
        else:  # collective-permute
            wire = float(op_b)
        d = out[kind]
        d["count"] += 1
        d["operand_bytes"] += op_b
        d["wire_bytes"] += wire
    out["total"] = {
        "count": sum(out[k]["count"] for k in _COLL_KINDS),
        "operand_bytes": sum(out[k]["operand_bytes"] for k in _COLL_KINDS),
        "wire_bytes": sum(out[k]["wire_bytes"] for k in _COLL_KINDS),
    }
    return out
