"""Three-term roofline from a compiled dry-run artifact.

Target hardware: TPU v5e —
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

    compute term    = HLO_FLOPs  / (chips × peak)      [s]
    memory term     = HLO_bytes  / (chips × HBM bw)    [s]
    collective term = wire_bytes /  link bw            [s]  (wire bytes are
                      already per-device from the ring model)

``flops``/``bytes`` come from ``compiled.cost_analysis()`` which reports
*whole-program* numbers on the CPU backend (sum over the 256/512 partitions);
dividing by chip count gives per-chip work.  The dominant term names the
bottleneck; MODEL_FLOPS/HLO_FLOPs exposes remat/capacity/attention waste.
"""

from __future__ import annotations

from typing import Dict, Optional

__all__ = ["HW", "roofline_terms"]

HW = {
    "peak_flops": 197e12,      # bf16 / chip
    "hbm_bw": 819e9,           # bytes/s / chip
    "ici_bw": 50e9,            # bytes/s / link
}


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float,
                   chips: int, model_flops: Optional[float] = None,
                   hw: Dict[str, float] = HW) -> Dict[str, float]:
    t_compute = flops / chips / hw["peak_flops"]
    t_memory = hbm_bytes / chips / hw["hbm_bw"]
    t_collective = wire_bytes / hw["ici_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dom = max(terms, key=terms.get)
    out = dict(terms)
    out["bottleneck"] = dom.replace("_s", "")
    out["step_time_s"] = max(terms.values())        # roofline lower bound
    out["chips"] = chips
    if model_flops:
        out["model_flops"] = model_flops
        out["useful_flop_frac"] = model_flops / max(flops, 1.0)
        out["mfu_bound"] = (model_flops / chips / hw["peak_flops"]
                            / max(out["step_time_s"], 1e-30))
    return out
