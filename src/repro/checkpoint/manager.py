"""Checkpointing with manifests, integrity hashes, async writes, and elastic
restore.

Layout per step:
    <dir>/step_<N>/manifest.json     {step, leaf index, shapes, dtypes, sha256}
    <dir>/step_<N>/arrays.npz        one entry per pytree leaf (flat key path)
    <dir>/step_<N>/COMMITTED         written last — a crash mid-write leaves no
                                     COMMITTED marker, so restore skips it

Arrays are saved *unsharded* (gathered); restore re-shards onto whatever mesh
the restoring job runs — that is the elastic-rescale path: a 512-chip job's
checkpoint restores onto 256 or 1024 chips unchanged.  The async writer
snapshots to host memory synchronously (cheap) and does file I/O on a
background thread so the train loop never blocks on disk.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_elem(p) for p in path)
        out.append((key, leaf))
    return out


def _path_elem(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        """Snapshot ``tree`` (host copy, synchronous) and write it (async)."""
        self.wait()   # one write in flight at a time
        host = {k: np.asarray(v) for k, v in _flatten(tree)}

        def write():
            try:
                self._write(step, host, extra or {})
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
            self._raise_pending()

    def _write(self, step: int, host: Dict[str, np.ndarray], extra: Dict):
        path = os.path.join(self.dir, f"step_{step:08d}")
        tmp = path + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "extra": extra,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                           "sha256": hashlib.sha256(v.tobytes()).hexdigest()}
                       for k, v in host.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(path):
            shutil.rmtree(path)
        os.replace(tmp, path)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_")
                    and os.path.exists(os.path.join(full, "COMMITTED"))):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                device_put: Optional[Callable[[str, np.ndarray], Any]] = None,
                verify: bool = True) -> Any:
        """Restore into the structure of ``like``.  ``device_put(key, arr)``
        lets the caller apply per-leaf shardings (elastic reshard)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        keys = [k for k, _ in _flatten(like)]
        missing = [k for k in keys if k not in data]
        if missing:
            raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
        leaves = []
        for k in keys:
            arr = data[k]
            if verify:
                want = manifest["leaves"][k]["sha256"]
                got = hashlib.sha256(arr.tobytes()).hexdigest()
                if want != got:
                    raise IOError(f"checksum mismatch for {k}")
            leaves.append(device_put(k, arr) if device_put else arr)
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def manifest(self, step: int) -> Dict:
        with open(os.path.join(self.dir, f"step_{step:08d}",
                               "manifest.json")) as f:
            return json.load(f)
