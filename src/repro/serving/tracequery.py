"""Multi-tenant trace-query service: an asyncio server over pack-backed
trace handles.

The library's scripting model is one process per analyst: every notebook
re-opens the trace, re-pays reader startup, and keeps its own plan cache.
This module turns that into a shared service — one long-lived process
holds a pool of open :class:`~repro.core.trace.Trace` /
:class:`~repro.core.streaming.StreamingTrace` handles (pack mmaps stay
warm), executes client-submitted plans against them, and returns columnar
results over a stdlib-only JSON/HTTP protocol
(:mod:`repro.serving.protocol`).  Three mechanisms make it multi-tenant
rather than just remote:

* **handle pool** — handles are keyed by *content identity* (the pack
  content id where available, ``(path, size, mtime, inode)`` otherwise)
  plus open parameters, LRU-bounded, and revalidated per request: rewrite
  a pack on disk and the next query transparently reopens it.  Every
  session over the same pack shares one mmap and one set of structure
  sidecars.
* **single-flight coalescing** — identical in-flight plans (same source
  identity, steps, op, arguments) are executed **once**; concurrent
  duplicates await the same future.  The key is the plan-cache digest of
  the wire request, so coalescing composes with the shared
  :mod:`~repro.core.plancache`: first request executes, concurrent ones
  coalesce, later ones hit the cache.
* **admission control** — a bounded number of requests may be admitted at
  once, each tenant has a concurrency limit and a plan-cache quota
  (:func:`repro.core.plancache.configure`), and execution threads come
  from the shared :class:`~repro.core.scheduler.Scheduler` lanes:
  interactive (windowed) queries run on reserved threads a bulk full scan
  can never occupy.  Saturation is an immediate HTTP 429, not an
  unbounded queue.

The HTTP surface is deliberately tiny (``asyncio.start_server`` + manual
HTTP/1.1, keep-alive): ``POST /query`` and ``POST /setquery`` execute
plans, ``POST /live`` polls a watermarked live session over still-growing
shards (min-watermark-advance backpressure via 429 ``watermark_stalled``;
degraded rank coverage via 206 partial responses naming the missing
ranks), ``GET /stats`` exposes service/cache/scheduler counters, ``GET
/ops`` lists the registered terminal ops, ``GET /health`` answers
liveness, and ``POST /shutdown`` drains gracefully (in-flight work
finishes; new queries get 503).  :mod:`repro.serving.client` wraps the
protocol in the library's own query-chain API.
"""

from __future__ import annotations

import asyncio
import hashlib
import json
import threading
import time
import traceback
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

from ..core import plancache, registry
from ..core.cancellation import CancelToken, cancel_scope
from ..core.scheduler import Scheduler, get_scheduler
from . import protocol
from .protocol import ProtocolError, canonical_json

__all__ = ["ServiceError", "HandlePool", "TraceService", "TraceServer",
           "serve"]

_JSON_HEADERS = "Content-Type: application/json\r\n"


class ServiceError(Exception):
    """A request the service refuses; carries the HTTP status and a stable
    machine-readable code clients can branch on.  ``extra`` (optional
    dict) is merged into the wire error body — e.g. ``retry_after_ms`` on
    a live-session stall."""

    def __init__(self, status: int, code: str, message: str,
                 extra: Optional[dict] = None):
        super().__init__(message)
        self.status = status
        self.code = code
        self.extra = extra or {}


# ---------------------------------------------------------------------------
# handle pool
# ---------------------------------------------------------------------------

class _Handle:
    """One open trace source: the handle object plus the bookkeeping the
    pool and the executor need (identity for staleness checks, a lock for
    sources whose lazy materialization mutates shared state)."""

    def __init__(self, key: str, kind: str, obj, ident: tuple):
        self.key = key
        self.kind = kind    # "trace" | "stream" | "set" | "live" | "liveset"
        self.obj = obj
        self.ident = ident          # _paths_token at open time
        self.lock = threading.Lock()
        self.opened_at = time.time()
        self.uses = 0

    def query(self):
        return self.obj.query()

    @property
    def serialized(self) -> bool:
        """Whether executions on this handle must hold :attr:`lock`.

        Eager traces materialize derived structure *in place* on first
        use, and set preparation does the same per member — concurrent
        runs would race those writes.  Streaming handles only carry
        idempotent caches (chunk stats, work-unit plans), so concurrent
        plans over one pack handle are safe — that is what lets the
        interactive lane make progress while bulk scans hammer the same
        pack.  Live handles serialize too: ``refresh()`` moves the pinned
        snapshot and the incremental fold mutates a running aggregate.
        """
        return self.kind != "stream"


def _normalize_open(spec: Any) -> dict:
    """Validate and normalize a wire ``open`` spec into canonical form."""
    if isinstance(spec, str):
        spec = {"path": spec}
    if not isinstance(spec, dict):
        raise ProtocolError(f"open spec must be a path or object, "
                            f"got {type(spec).__name__}")
    paths = spec.get("paths")
    if paths is None:
        p = spec.get("path")
        if p is None:
            raise ProtocolError('open spec needs "path" or "paths"')
        paths = [p]
    if (not isinstance(paths, (list, tuple)) or not paths
            or not all(isinstance(p, str) for p in paths)):
        raise ProtocolError(f'open spec "paths" must be a non-empty list '
                            f'of strings, got {paths!r}')
    mode = spec.get("mode", "trace")
    if mode not in ("trace", "set", "live", "liveset"):
        raise ProtocolError(f'open mode must be "trace", "set", "live" or '
                            f'"liveset", got {mode!r}')
    if mode == "liveset" and len(paths) != 1:
        raise ProtocolError('mode "liveset" takes exactly one path: the '
                            'shard directory')
    labels = spec.get("labels")
    if labels is not None and (not isinstance(labels, (list, tuple))
                               or len(labels) != len(paths)):
        raise ProtocolError('"labels" must match "paths" in length')
    out = {
        "mode": mode,
        "paths": [str(p) for p in paths],
        "format": str(spec.get("format", "auto")),
        "streaming": bool(spec.get("streaming", False)),
        "chunk_rows": (int(spec["chunk_rows"])
                       if spec.get("chunk_rows") is not None else None),
        "processes": (int(spec["processes"])
                      if spec.get("processes") is not None else None),
        "executor": str(spec.get("executor", "auto")),
        "labels": [str(x) for x in labels] if labels is not None else None,
    }
    if mode == "liveset":
        out["pattern"] = str(spec.get("pattern", "rank_*.pack"))
        out["lag_timeout"] = float(spec.get("lag_timeout", 2.0))
        out["dead_timeout"] = float(spec.get("dead_timeout", 10.0))
    return out


class HandlePool:
    """LRU pool of open trace handles keyed by open spec + content
    identity.

    ``get()`` revalidates the stored identity (pack content id / stat
    token) on every call — a handle whose backing files changed on disk
    is silently reopened, so long-lived services never serve stale mmaps.
    Opens run under the pool lock (they mutate the LRU); callers should
    invoke ``get()`` off the event loop for sources with slow opens.
    """

    def __init__(self, max_handles: int = 8, breaker_threshold: int = 3,
                 breaker_cooldown: float = 30.0):
        self.max_handles = max(int(max_handles), 1)
        self.breaker_threshold = max(int(breaker_threshold), 1)
        self.breaker_cooldown = float(breaker_cooldown)
        self._lock = threading.Lock()
        self._handles: "OrderedDict[str, _Handle]" = OrderedDict()
        self._fails: Dict[str, dict] = {}  # key -> consecutive open failures
        self.opens = 0
        self.reopens = 0
        self.evictions = 0
        self.breaker_trips = 0
        self.breaker_fastfails = 0

    def _ident(self, paths: List[str]) -> tuple:
        from ..core.plancache import _paths_token
        return _paths_token(paths)

    def _open(self, spec: dict):
        from ..core.diff import TraceSet
        from ..core.trace import Trace
        if spec["mode"] == "live":
            from ..core.streaming import DEFAULT_CHUNK_ROWS, LiveTrace
            return "live", LiveTrace(
                spec["paths"], format=spec["format"],
                chunk_rows=spec["chunk_rows"] or DEFAULT_CHUNK_ROWS,
                processes=spec["processes"], executor=spec["executor"])
        if spec["mode"] == "liveset":
            from ..core.liveset import LiveTraceSet
            return "liveset", LiveTraceSet(
                spec["paths"][0], pattern=spec["pattern"],
                lag_timeout=spec["lag_timeout"],
                dead_timeout=spec["dead_timeout"],
                chunk_rows=spec["chunk_rows"],
                processes=spec["processes"], executor=spec["executor"])
        if spec["mode"] == "set":
            return "set", TraceSet.open(
                spec["paths"], format=spec["format"],
                processes=spec["processes"], labels=spec["labels"],
                streaming=spec["streaming"], chunk_rows=spec["chunk_rows"])
        if spec["streaming"]:
            src = (spec["paths"][0] if len(spec["paths"]) == 1
                   else spec["paths"])
            return "stream", Trace.open(
                src, format=spec["format"], streaming=True,
                chunk_rows=spec["chunk_rows"], processes=spec["processes"],
                executor=spec["executor"])
        if len(spec["paths"]) > 1:
            return "trace", Trace.open(spec["paths"],
                                       format=spec["format"],
                                       processes=spec["processes"])
        return "trace", Trace.open(spec["paths"][0], format=spec["format"])

    def _salvage_hint(self, spec: dict) -> str:
        p = spec["paths"][0] if spec["paths"] else "<path>"
        return (f"if the source is a damaged pack, inspect it with "
                f"`python tools/pack.py --verify {p}` and recover with "
                f"`--repair`, or reopen with on_error=\"salvage\"")

    def get(self, spec: dict) -> _Handle:
        """The live handle for ``spec`` (opening or reopening as needed).

        Repeatedly-failing opens trip a per-spec circuit breaker: after
        ``breaker_threshold`` consecutive failures, requests fast-fail
        with 422 ``source_corrupt`` (and a salvage hint) for
        ``breaker_cooldown`` seconds instead of re-burning a lane thread
        on a source that cannot open.  One probe is admitted when the
        cooldown lapses; a successful open resets the breaker."""
        key = hashlib.sha256(canonical_json(spec).encode()).hexdigest()
        try:
            ident = self._ident(spec["paths"])
        except OSError as e:
            if spec.get("mode") in ("live", "liveset"):
                # a live shard that hasn't appeared yet reads as empty —
                # not an error; identity settles once data arrives
                ident = ("live-pending",) + tuple(spec["paths"])
            else:
                raise ServiceError(404, "no_such_trace",
                                   f"cannot stat trace source: {e}") \
                    from None
        with self._lock:
            b = self._fails.get(key)
            if (b is not None and b["fails"] >= self.breaker_threshold
                    and time.time() < b["until"]):
                self.breaker_fastfails += 1
                raise ServiceError(
                    422, "source_corrupt",
                    f"open failed {b['fails']} consecutive times "
                    f"(last: {b['last']}); circuit open for another "
                    f"{b['until'] - time.time():.1f}s — "
                    + self._salvage_hint(spec))
            h = self._handles.get(key)
            if h is not None and (h.ident == ident
                                  or h.kind in ("live", "liveset")):
                # live handles are never reopened on identity drift — the
                # backing shards *grow by design*; the live() path calls
                # obj.refresh() to advance the pinned snapshot in place,
                # which preserves the incremental aggregate state a
                # reopen would discard
                self._handles.move_to_end(key)
                h.uses += 1
                h.ident = ident
                self._fails.pop(key, None)
                return h
            stale = h is not None
            try:
                kind, obj = self._open(spec)
            except (OSError, ValueError) as e:
                b = self._fails.setdefault(
                    key, {"fails": 0, "until": 0.0, "last": ""})
                b["fails"] += 1
                b["last"] = f"{type(e).__name__}: {e}"
                b["until"] = time.time() + self.breaker_cooldown
                if b["fails"] == self.breaker_threshold:
                    self.breaker_trips += 1
                if b["fails"] >= self.breaker_threshold:
                    raise ServiceError(
                        422, "source_corrupt",
                        f"open failed {b['fails']} consecutive times "
                        f"(last: {b['last']}) — "
                        + self._salvage_hint(spec)) from None
                raise ServiceError(404, "open_failed",
                                   f"cannot open trace source: {e}") from None
            self._fails.pop(key, None)
            h = _Handle(key, kind, obj, ident)
            h.uses = 1
            self._handles[key] = h
            self._handles.move_to_end(key)
            self.opens += 1
            if stale:
                self.reopens += 1
            while len(self._handles) > self.max_handles:
                self._handles.popitem(last=False)
                self.evictions += 1
            return h

    def stats(self) -> dict:
        with self._lock:
            now = time.time()
            return {"open": len(self._handles),
                    "max_handles": self.max_handles,
                    "opens": self.opens, "reopens": self.reopens,
                    "evictions": self.evictions,
                    "breaker_trips": self.breaker_trips,
                    "breaker_fastfails": self.breaker_fastfails,
                    "breaker_open": sum(
                        1 for b in self._fails.values()
                        if b["fails"] >= self.breaker_threshold
                        and now < b["until"]),
                    "handles": [{"kind": h.kind, "uses": h.uses,
                                 "key": h.key[:12]}
                                for h in self._handles.values()]}

    def clear(self) -> None:
        with self._lock:
            self._handles.clear()
            self._fails.clear()


# ---------------------------------------------------------------------------
# the service (transport-independent core)
# ---------------------------------------------------------------------------

class _Flight:
    """One in-flight execution other requests can coalesce onto."""

    def __init__(self, future: "asyncio.Future"):
        self.future = future
        self.waiters = 0


class TraceService:
    """Decodes wire requests, admits them, and executes plans over pooled
    handles.  Transport-independent: :class:`TraceServer` feeds it parsed
    JSON bodies; tests can call :meth:`query` directly."""

    def __init__(self, *, scheduler: Optional[Scheduler] = None,
                 max_handles: int = 8, max_active: int = 32,
                 per_tenant: int = 4, tenant_quota: Optional[int] = None,
                 cache_entries: Optional[int] = None,
                 default_tenant: str = "public",
                 default_deadline: Optional[float] = None,
                 breaker_threshold: int = 3,
                 breaker_cooldown: float = 30.0):
        self.scheduler = scheduler or get_scheduler()
        self.handles = HandlePool(max_handles=max_handles,
                                  breaker_threshold=breaker_threshold,
                                  breaker_cooldown=breaker_cooldown)
        #: seconds allowed per request when the client sends no
        #: ``deadline_ms``; None = unbounded (the historical behavior)
        self.default_deadline = default_deadline
        self.max_active = max(int(max_active), 1)
        self.per_tenant = max(int(per_tenant), 1)
        self.default_tenant = default_tenant
        if tenant_quota is not None or cache_entries is not None:
            plancache.configure(max_entries=cache_entries,
                                tenant_quota=tenant_quota)
        self.draining = False
        self._active = 0
        self._idle = asyncio.Event()
        self._idle.set()
        self._flights: Dict[str, _Flight] = {}
        self._tenant_sems: Dict[str, asyncio.Semaphore] = {}
        self._tenant_waiting: Dict[str, int] = {}
        #: live polling sessions: (tenant, handle key, session id) →
        #: {rows, served_at, polls, stalls} — the watermark each session
        #: last saw, for min-advance admission / backpressure
        self._live_sessions: Dict[tuple, dict] = {}
        self.counters: Dict[str, int] = {
            "requests": 0, "executed": 0, "coalesced": 0, "cache_hits": 0,
            "rejected": 0, "errors": 0, "interactive": 0, "bulk": 0,
            "live_polls": 0, "live_stalled": 0, "live_partial": 0}
        self.tenant_counters: Dict[str, Dict[str, int]] = {}

    # -- bookkeeping -------------------------------------------------------
    def _tenant(self, payload: dict) -> str:
        t = payload.get("tenant")
        if t is not None and not isinstance(t, str):
            raise ProtocolError(f"tenant must be a string, got {t!r}")
        return t or self.default_tenant

    def _count(self, tenant: str, field: str) -> None:
        self.counters[field] = self.counters.get(field, 0) + 1
        st = self.tenant_counters.setdefault(
            tenant, {"requests": 0, "executed": 0, "coalesced": 0,
                     "cache_hits": 0, "rejected": 0, "errors": 0})
        st[field] = st.get(field, 0) + 1

    def _sem(self, tenant: str) -> asyncio.Semaphore:
        sem = self._tenant_sems.get(tenant)
        if sem is None:
            sem = self._tenant_sems[tenant] = asyncio.Semaphore(
                self.per_tenant)
        return sem

    # -- request decoding --------------------------------------------------
    def _decode(self, payload: dict, set_scope: bool):
        if not isinstance(payload, dict):
            raise ProtocolError("request body must be a JSON object")
        open_spec = _normalize_open(payload.get("open"))
        if set_scope:
            open_spec["mode"] = "set"
        elif open_spec["mode"] == "set":
            raise ProtocolError('mode "set" plans go to /setquery')
        elif open_spec["mode"] in ("live", "liveset"):
            raise ProtocolError(
                f'mode {open_spec["mode"]!r} plans go to /live')
        op = payload.get("op")
        if not isinstance(op, str):
            raise ProtocolError('request needs an "op" name')
        spec = registry.get_op(op)
        if spec is None:
            raise ProtocolError(f"unknown analysis op {op!r}; registered: "
                                f"{registry.list_ops()}")
        if spec.scope == "set" and open_spec["mode"] != "set":
            raise ProtocolError(
                f"{op!r} is a multi-trace comparison op; submit it to "
                f"/setquery with a set open spec")
        steps = protocol.decode_steps(payload.get("steps") or [])
        args = tuple(protocol.decode_value(x)
                     for x in (payload.get("args") or []))
        kwargs_wire = payload.get("kwargs") or {}
        if not isinstance(kwargs_wire, dict):
            raise ProtocolError('"kwargs" must be an object')
        kwargs = {str(k): protocol.decode_value(v)
                  for k, v in kwargs_wire.items()}
        cache_flag = payload.get("cache")
        if cache_flag is not None and not isinstance(cache_flag, bool):
            raise ProtocolError('"cache" must be true/false/null')
        lane = payload.get("lane")
        if lane is None:
            # heuristic: windowed plans are interactive, full scans bulk
            lane = ("interactive"
                    if any(s.get("k") in ("slice_time", "restrict_processes")
                           for s in steps) else "bulk")
        if lane not in ("interactive", "bulk"):
            raise ProtocolError(f'lane must be "interactive" or "bulk", '
                                f'got {lane!r}')
        digest_only = bool(payload.get("digest_only", False))
        return open_spec, op, spec, steps, args, kwargs, cache_flag, \
            lane, digest_only

    def _wire_key(self, open_spec: dict, steps, op: str, payload: dict,
                  digest_only: bool) -> Optional[str]:
        """Single-flight + service-cache key: a digest of the request plus
        the *content identity* of its sources.  None when the sources
        cannot be identified (key construction already raised 404 in
        ``handles.get`` for missing files; this is only for exotic
        failures) — such requests execute uncoalesced and uncached."""
        try:
            ident = self.handles._ident(open_spec["paths"])
        except OSError:
            return None
        body = canonical_json({"open": open_spec, "ident": repr(ident),
                               "steps": steps, "op": op,
                               "args": payload.get("args") or [],
                               "kwargs": payload.get("kwargs") or {},
                               "digest_only": digest_only})
        return "serve:" + hashlib.sha256(body.encode()).hexdigest()

    # -- execution ---------------------------------------------------------
    def _execute(self, handle: _Handle, op: str, steps, args, kwargs,
                 cache_flag, digest_only: bool) -> dict:
        """Runs on a scheduler lane thread: build the plan over the pooled
        handle, execute, encode."""
        q = protocol.apply_steps(handle.query(), steps)
        kw = dict(kwargs)
        if handle.kind != "set" and cache_flag is not None:
            # forward the client's cache choice to the library-level plan
            # cache (streaming sources participate by default)
            kw["cache"] = cache_flag
        t0 = time.perf_counter()
        if handle.serialized:
            with handle.lock:
                value = q.run(op, *args, **kw)
        else:
            value = q.run(op, *args, **kw)
        elapsed = time.perf_counter() - t0
        out = {"ok": True, "digest": protocol.result_digest(value),
               "elapsed_ms": round(elapsed * 1e3, 3)}
        if not digest_only:
            out["result"] = protocol.encode_value(value)
        return out

    async def query(self, payload: dict, set_scope: bool = False) -> dict:
        """Execute one wire request; returns the JSON-able response body.
        Raises :class:`ServiceError` for refusals and
        :class:`ProtocolError` for malformed requests."""
        tenant = self._tenant(payload if isinstance(payload, dict) else {})
        self._count(tenant, "requests")
        if self.draining:
            self._count(tenant, "rejected")
            raise ServiceError(503, "draining",
                               "service is draining; no new queries")
        (open_spec, op, spec, steps, args, kwargs, cache_flag, lane,
         digest_only) = self._decode(payload, set_scope)
        deadline = payload.get("deadline_ms")
        if deadline is not None:
            if not isinstance(deadline, (int, float)) or deadline <= 0:
                raise ProtocolError(
                    f'"deadline_ms" must be a positive number, '
                    f'got {deadline!r}')
            deadline = float(deadline) / 1e3
        else:
            deadline = self.default_deadline
        self.counters[lane] += 1
        key = self._wire_key(open_spec, steps, op, payload, digest_only)

        # 1. shared plan cache (service layer: keyed by content identity)
        if key is not None and cache_flag is not False:
            hit, value = plancache.lookup(key, tenant=tenant)
            if hit:
                self._count(tenant, "cache_hits")
                return dict(value, cached=True, tenant=tenant)

        # 2. single-flight: identical in-flight plan → await its future
        if key is not None:
            flight = self._flights.get(key)
            if flight is not None:
                flight.waiters += 1
                self._count(tenant, "coalesced")
                result = await asyncio.shield(flight.future)
                return dict(result, coalesced=True, tenant=tenant)

        # 3. admission: global bound, then per-tenant concurrency
        if self._active >= self.max_active:
            self._count(tenant, "rejected")
            raise ServiceError(429, "saturated",
                               f"service at max_active={self.max_active}; "
                               f"retry later")
        waiting = self._tenant_waiting.get(tenant, 0)
        if waiting >= self.per_tenant * 4:
            self._count(tenant, "rejected")
            raise ServiceError(429, "tenant_saturated",
                               f"tenant {tenant!r} has {waiting} queued "
                               f"requests (limit {self.per_tenant * 4})")
        self._tenant_waiting[tenant] = waiting + 1
        try:
            await self._sem(tenant).acquire()
        finally:
            self._tenant_waiting[tenant] -= 1

        # the semaphore may have parked this task: an identical plan could
        # have taken off in the meantime — re-check before executing
        if key is not None:
            flight = self._flights.get(key)
            if flight is not None:
                self._sem(tenant).release()
                flight.waiters += 1
                self._count(tenant, "coalesced")
                result = await asyncio.shield(flight.future)
                return dict(result, coalesced=True, tenant=tenant)

        loop = asyncio.get_running_loop()
        future: "asyncio.Future" = loop.create_future()
        if key is not None:
            self._flights[key] = _Flight(future)
        self._active += 1
        self._idle.clear()
        self._count(tenant, "executed")
        token = CancelToken("request deadline exceeded")
        t_start = time.monotonic()

        async def _bounded(fn):
            """Run ``fn`` on the lane thread within the remaining deadline
            budget.  On expiry the 504 goes out immediately; the lane
            thread sees the cancelled token at its next chunk boundary
            and frees itself cooperatively."""
            aw = loop.run_in_executor(self.scheduler.lane(lane), fn)
            if deadline is None:
                return await aw
            remaining = deadline - (time.monotonic() - t_start)
            try:
                if remaining <= 0:
                    raise asyncio.TimeoutError
                return await asyncio.wait_for(aw, remaining)
            except asyncio.TimeoutError:
                token.cancel()
                aw.cancel()  # drop the abandoned wrapper (thread exits at
                # its next token check; its late result/exception is
                # discarded instead of logged)
                self.counters["deadline_exceeded"] = \
                    self.counters.get("deadline_exceeded", 0) + 1
                raise ServiceError(
                    504, "deadline_exceeded",
                    f"deadline of {deadline * 1e3:.0f} ms exceeded; "
                    f"execution cancelled at the next chunk boundary"
                ) from None

        def _exec(handle):
            with cancel_scope(token):
                return self._execute(handle, op, steps, args, kwargs,
                                     cache_flag, digest_only)

        try:
            handle = await _bounded(lambda: self.handles.get(open_spec))
            result = await _bounded(lambda: _exec(handle))
            if key is not None and cache_flag is not False:
                plancache.store(key, result, tenant=tenant)
            future.set_result(result)
            return dict(result, tenant=tenant)
        except BaseException as e:
            self._count(tenant, "errors")
            if not future.done():
                future.set_exception(e)
            # a coalesced waiter consuming the exception keeps it from
            # being flagged "never retrieved"
            future.exception()
            raise
        finally:
            if key is not None:
                self._flights.pop(key, None)
            self._sem(tenant).release()
            self._active -= 1
            if self._active == 0:
                self._idle.set()

    # -- live sessions -----------------------------------------------------
    def _decode_live(self, payload: dict):
        if not isinstance(payload, dict):
            raise ProtocolError("request body must be a JSON object")
        open_spec = _normalize_open(payload.get("open"))
        if open_spec["mode"] == "trace":
            open_spec["mode"] = "live"   # bare path on /live means live
        if open_spec["mode"] not in ("live", "liveset"):
            raise ProtocolError('/live takes mode "live" or "liveset"; '
                                'finalized sources go to /query')
        op = payload.get("op")
        if not isinstance(op, str):
            raise ProtocolError('request needs an "op" name')
        spec = registry.get_op(op)
        if spec is None:
            raise ProtocolError(f"unknown analysis op {op!r}; registered: "
                                f"{registry.list_ops()}")
        if spec.scope == "set":
            raise ProtocolError(
                f"{op!r} is a set-scoped op; live sessions execute "
                f"single-scope ops over the (combined) committed prefix")
        steps = protocol.decode_steps(payload.get("steps") or [])
        args = tuple(protocol.decode_value(x)
                     for x in (payload.get("args") or []))
        kwargs_wire = payload.get("kwargs") or {}
        if not isinstance(kwargs_wire, dict):
            raise ProtocolError('"kwargs" must be an object')
        kwargs = {str(k): protocol.decode_value(v)
                  for k, v in kwargs_wire.items()}
        min_advance = payload.get("min_advance_rows", 1)
        if not isinstance(min_advance, int) or min_advance < 0:
            raise ProtocolError('"min_advance_rows" must be a '
                                'non-negative integer')
        session = str(payload.get("session", "default"))
        digest_only = bool(payload.get("digest_only", False))
        return open_spec, op, steps, args, kwargs, min_advance, session, \
            digest_only

    def _poll_live(self, open_spec: dict, op: str, steps, args, kwargs,
                   min_advance: int, skey: tuple,
                   digest_only: bool) -> dict:
        """Lane-thread body of one /live poll: refresh the pinned snapshot,
        admit by watermark advance, execute over the committed prefix."""
        handle = self.handles.get(open_spec)
        with handle.lock:
            if handle.kind == "liveset":
                cov = handle.obj.refresh()
                wm = handle.obj.watermark
                if wm is None:
                    raise ServiceError(
                        503, "no_survivors",
                        f"every rank under {open_spec['paths'][0]!r} is "
                        f"dead or absent — refusing to serve an empty "
                        f"result as healthy",
                        extra={"coverage": cov.as_dict()})
                lt = handle.obj.trace()
            else:
                cov = None
                wm = handle.obj.refresh()
                lt = handle.obj
            sess = self._live_sessions.get(skey)
            prev_rows = sess["rows"] if sess is not None else None
            advanced = wm.rows - (prev_rows or 0)
            if (sess is not None and min_advance > 0
                    and wm.rows - sess["rows"] < min_advance
                    and not wm.finalized):
                # tenant polls faster than the writers commit: push back
                # instead of re-serving (and re-encoding) the same prefix
                sess["polls"] += 1
                sess["stalls"] += 1
                raise ServiceError(
                    429, "watermark_stalled",
                    f"watermark advanced {wm.rows - sess['rows']} row(s) "
                    f"since this session's last poll "
                    f"(min_advance_rows={min_advance}); poll slower",
                    extra={"retry_after_ms": 250,
                           "watermark": wm.as_dict()})
            q = protocol.apply_steps(lt.query(), steps)
            t0 = time.perf_counter()
            value = q.run(op, *args, **kwargs)
            elapsed = time.perf_counter() - t0
            if sess is None:
                sess = self._live_sessions[skey] = {
                    "rows": 0, "polls": 0, "stalls": 0, "served_at": 0.0}
            sess["rows"] = wm.rows
            sess["polls"] += 1
            sess["served_at"] = time.time()
            out = {"ok": True, "watermark": wm.as_dict(),
                   "advanced_rows": advanced, "session": skey[2],
                   "partial": False,
                   "digest": protocol.result_digest(value),
                   "elapsed_ms": round(elapsed * 1e3, 3)}
            if cov is not None:
                out["coverage"] = cov.as_dict()
                if cov.degraded:
                    # 206-style partial result: the missing ranks are
                    # named in the response, never silently dropped
                    out["partial"] = True
                    out["missing_ranks"] = list(cov.missing)
            if not digest_only:
                out["result"] = protocol.encode_value(value)
            return out

    async def live(self, payload: dict) -> dict:
        """One poll of a live session: refresh the committed prefix,
        enforce min-watermark-advance backpressure, execute the op over
        the survivors, and annotate the result with watermark + coverage.
        Degraded liveset coverage comes back ``partial: True`` (wire
        status 206)."""
        tenant = self._tenant(payload if isinstance(payload, dict) else {})
        self._count(tenant, "requests")
        if self.draining:
            self._count(tenant, "rejected")
            raise ServiceError(503, "draining",
                               "service is draining; no new queries")
        (open_spec, op, steps, args, kwargs, min_advance, session,
         digest_only) = self._decode_live(payload)
        if self._active >= self.max_active:
            self._count(tenant, "rejected")
            raise ServiceError(429, "saturated",
                               f"service at max_active={self.max_active}; "
                               f"retry later")
        waiting = self._tenant_waiting.get(tenant, 0)
        if waiting >= self.per_tenant * 4:
            self._count(tenant, "rejected")
            raise ServiceError(429, "tenant_saturated",
                               f"tenant {tenant!r} has {waiting} queued "
                               f"requests (limit {self.per_tenant * 4})")
        self._tenant_waiting[tenant] = waiting + 1
        try:
            await self._sem(tenant).acquire()
        finally:
            self._tenant_waiting[tenant] -= 1
        self._active += 1
        self._idle.clear()
        self._count(tenant, "live_polls")
        # the session key pins continuity to the open spec, not the pool
        # object: a pool eviction must not reset a tenant's watermark
        skey = (tenant,
                hashlib.sha256(canonical_json(open_spec).encode())
                .hexdigest(), session)
        loop = asyncio.get_running_loop()
        try:
            result = await loop.run_in_executor(
                self.scheduler.lane("interactive"),
                lambda: self._poll_live(open_spec, op, steps, args, kwargs,
                                        min_advance, skey, digest_only))
            self._count(tenant, "executed")
            if result.get("partial"):
                self._count(tenant, "live_partial")
            return dict(result, tenant=tenant)
        except ServiceError as e:
            if e.code == "watermark_stalled":
                self._count(tenant, "live_stalled")
            else:
                self._count(tenant, "errors")
            raise
        except BaseException:
            self._count(tenant, "errors")
            raise
        finally:
            self._sem(tenant).release()
            self._active -= 1
            if self._active == 0:
                self._idle.set()

    # -- introspection / lifecycle ----------------------------------------
    def ops(self) -> dict:
        out = []
        for name in registry.list_ops():
            s = registry.get_op(name)
            out.append({"name": name, "scope": s.scope,
                        "streaming": s.streaming is not None,
                        "needs_structure": bool(s.needs_structure),
                        "needs_messages": bool(s.needs_messages),
                        "backends": registry.list_backends(name)})
        return {"ok": True, "ops": out}

    def stats(self) -> dict:
        return {"ok": True,
                "service": dict(self.counters, active=self._active,
                                draining=self.draining,
                                max_active=self.max_active,
                                per_tenant=self.per_tenant,
                                in_flight_plans=len(self._flights),
                                live_sessions=len(self._live_sessions)),
                "tenants": {t: dict(c)
                            for t, c in self.tenant_counters.items()},
                "plancache": plancache.stats(),
                "scheduler": self.scheduler.stats(),
                "handles": self.handles.stats()}

    async def drain(self, timeout: Optional[float] = None) -> bool:
        """Refuse new queries and wait for in-flight ones to finish.
        Returns True when the service went idle within ``timeout``."""
        self.draining = True
        try:
            await asyncio.wait_for(self._idle.wait(), timeout)
            return True
        except asyncio.TimeoutError:
            return False


# ---------------------------------------------------------------------------
# HTTP transport
# ---------------------------------------------------------------------------

_MAX_BODY = 64 * 1024 * 1024


async def _read_request(reader: asyncio.StreamReader):
    """(method, path, headers, body) for one HTTP/1.1 request, or None on
    clean EOF."""
    try:
        line = await reader.readline()
    except (ConnectionError, asyncio.LimitOverrunError):
        return None
    if not line:
        return None
    try:
        method, path, _version = line.decode("latin-1").split(None, 2)
    except ValueError:
        raise ServiceError(400, "bad_request", "malformed request line")
    headers: Dict[str, str] = {}
    while True:
        line = await reader.readline()
        if line in (b"\r\n", b"\n", b""):
            break
        if b":" in line:
            k, v = line.decode("latin-1").split(":", 1)
            headers[k.strip().lower()] = v.strip()
    length = int(headers.get("content-length", "0") or "0")
    if length > _MAX_BODY:
        raise ServiceError(413, "too_large",
                           f"body of {length} bytes exceeds {_MAX_BODY}")
    body = await reader.readexactly(length) if length else b""
    return method.upper(), path, headers, body


def _response(status: int, body: dict) -> bytes:
    payload = json.dumps(body).encode()
    reason = {200: "OK", 206: "Partial Content", 400: "Bad Request",
              404: "Not Found",
              405: "Method Not Allowed", 413: "Payload Too Large",
              422: "Unprocessable Entity", 429: "Too Many Requests",
              500: "Internal Server Error", 503: "Service Unavailable",
              504: "Gateway Timeout"}.get(status, "Error")
    head = (f"HTTP/1.1 {status} {reason}\r\n{_JSON_HEADERS}"
            f"Content-Length: {len(payload)}\r\n"
            f"Connection: keep-alive\r\n\r\n")
    return head.encode("latin-1") + payload


class TraceServer:
    """The asyncio HTTP server around a :class:`TraceService`.

    ``await start()`` binds (port 0 picks a free port; see :attr:`port`),
    ``await shutdown()`` drains gracefully, ``serve_forever()`` blocks
    until shutdown.  All handler work runs on the event loop except plan
    execution, which the service pushes onto scheduler lane threads.
    """

    def __init__(self, service: Optional[TraceService] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 drain_timeout: float = 30.0):
        self.service = service or TraceService()
        self.host = host
        self._port = port
        self.drain_timeout = drain_timeout
        self._server: Optional[asyncio.AbstractServer] = None
        self._stopped = asyncio.Event()
        self._shutdown_task: Optional["asyncio.Task"] = None

    @property
    def port(self) -> int:
        if self._server is not None:
            return self._server.sockets[0].getsockname()[1]
        return self._port

    async def start(self) -> "TraceServer":
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self._port)
        return self

    async def _route(self, method: str, path: str, body: bytes) -> \
            Tuple[int, dict]:
        svc = self.service
        if method == "GET":
            if path == "/health":
                return 200, {"ok": True, "draining": svc.draining}
            if path == "/ops":
                return 200, svc.ops()
            if path == "/stats":
                return 200, svc.stats()
            return 404, {"ok": False, "error": {"code": "not_found",
                                                "message": path}}
        if method != "POST":
            return 405, {"ok": False, "error": {"code": "method",
                                                "message": method}}
        if path == "/shutdown":
            try:
                payload = json.loads(body or b"{}")
            except ValueError:
                payload = {}
            self._shutdown_task = asyncio.get_running_loop().create_task(
                self.shutdown(float(payload.get(
                    "grace", self.drain_timeout))))
            return 200, {"ok": True, "draining": True}
        if path not in ("/query", "/setquery", "/diagnose", "/live"):
            return 404, {"ok": False, "error": {"code": "not_found",
                                                "message": path}}
        try:
            payload = json.loads(body.decode("utf-8"))
        except (ValueError, UnicodeDecodeError) as e:
            return 400, {"ok": False, "error": {"code": "bad_json",
                                                "message": str(e)}}
        if path == "/diagnose":
            # sugar over /query: force the diagnose terminal so clients can
            # POST just {"paths": ..., "detectors": [...]}.  The request
            # funnels through svc.query, so it participates in single-flight
            # coalescing and the plan cache like any other plan.
            payload = dict(payload)
            payload["op"] = "diagnose"
            detectors = payload.pop("detectors", None)
            if detectors is not None:
                kwargs = dict(payload.get("kwargs") or {})
                kwargs["detectors"] = detectors
                payload["kwargs"] = kwargs
        try:
            if path == "/live":
                result = await svc.live(payload)
                # a degraded-coverage result is correct but incomplete:
                # 206 tells the client which ranks are missing
                return (206 if result.get("partial") else 200), result
            result = await svc.query(payload, set_scope=(path == "/setquery"))
            return 200, result
        except ProtocolError as e:
            return 400, {"ok": False, "error": {"code": "protocol",
                                                "message": str(e)}}
        except ServiceError as e:
            err = {"code": e.code, "message": str(e)}
            err.update(e.extra)
            return e.status, {"ok": False, "error": err}
        except Exception as e:  # op raised: report, keep serving
            return 500, {"ok": False, "error": {
                "code": "op_failed", "message": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc(limit=8)}}

    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                try:
                    req = await _read_request(reader)
                except ServiceError as e:
                    writer.write(_response(e.status, {
                        "ok": False,
                        "error": {"code": e.code, "message": str(e)}}))
                    await writer.drain()
                    break
                except asyncio.IncompleteReadError:
                    break
                if req is None:
                    break
                method, path, headers, body = req
                status, out = await self._route(method, path, body)
                writer.write(_response(status, out))
                await writer.drain()
                if headers.get("connection", "").lower() == "close":
                    break
        except (ConnectionError, asyncio.CancelledError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def shutdown(self, grace: Optional[float] = None) -> None:
        """Graceful stop: drain the service (in-flight queries finish; new
        ones get 503), then close the listener."""
        await self.service.drain(grace if grace is not None
                                 else self.drain_timeout)
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        self._stopped.set()

    async def serve_forever(self) -> None:
        if self._server is None:
            await self.start()
        await self._stopped.wait()


def serve(host: str = "127.0.0.1", port: int = 0,
          announce: bool = False, **service_kwargs) -> None:
    """Blocking entry point: build a service, bind, serve until drained.

    ``announce=True`` prints one ``SERVING {json}`` line with the bound
    host/port once the socket is live — the benchmark and CI smoke job
    parse it to find a port-0 server.
    """

    async def _main():
        server = TraceServer(TraceService(**service_kwargs),
                             host=host, port=port)
        await server.start()
        if announce:
            print("SERVING " + json.dumps(
                {"host": host, "port": server.port}), flush=True)
        await server.serve_forever()

    asyncio.run(_main())
