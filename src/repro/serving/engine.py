"""Batched serving engine: prefill + decode with a slot-based continuous
batcher.

``ServeEngine`` keeps B decode slots.  Requests are prefilled (one jit'd
prefill per admission wave — all current waiters padded to one length) and
then decoded together; finished slots are refilled from the queue.  Greedy
sampling by default (temperature optional).  Every phase emits Pipit events.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import build_model
from ..models.config import ModelConfig
from ..runtime.tracer import Tracer

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [S] int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, batch: int, cache_len: int,
                 params=None, tracer: Optional[Tracer] = None,
                 dtype=jnp.float32, temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.batch = batch
        self.cache_len = cache_len
        self.tracer = tracer or Tracer()
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        if params is None:
            with self.tracer.span("init"):
                params = jax.jit(lambda k: self.model.init(k, dtype))(
                    jax.random.PRNGKey(seed))
        self.params = params

        self._prefill = jax.jit(
            lambda p, t, **kw: self.model.prefill(p, t, cache_len, **kw),
            static_argnames=())
        self._decode = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(p, c, t, pos,
                                                        cache_len))

    # ------------------------------------------------------------------
    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits[..., :self.cfg.vocab], -1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            sub, logits[..., :self.cfg.vocab] / self.temperature))

    def generate(self, requests: List[Request], **extras) -> List[Request]:
        """Serve a wave of ≤batch requests (padded to one prompt length)."""
        assert len(requests) <= self.batch
        reqs = list(requests)
        B = len(reqs)
        S = max(len(r.prompt) for r in reqs)
        prompts = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, S - len(r.prompt):] = r.prompt  # left-pad
        with self.tracer.span("prefill"):
            cache, logits, pos = self._prefill(self.params,
                                               jnp.asarray(prompts), **extras)
        tok = self._sample(logits)
        for r, t in zip(reqs, tok):
            r.out_tokens = [int(t)]
        steps = max(r.max_new_tokens for r in reqs) - 1
        with self.tracer.span("decode"):
            cur = jnp.asarray(tok[:, None].astype(np.int32))
            p = pos
            for _ in range(steps):
                with self.tracer.span("decode_step"):
                    logits, cache = self._decode(self.params, cache, cur, p)
                tok = self._sample(logits)
                for r, t in zip(reqs, tok):
                    if len(r.out_tokens) < r.max_new_tokens:
                        r.out_tokens.append(int(t))
                cur = jnp.asarray(tok[:, None].astype(np.int32))
                p = p + 1
        return reqs

    def serve_queue(self, queue: List[Request], **extras) -> List[Request]:
        """Slot-based batching: admit up to `batch` requests per wave."""
        done: List[Request] = []
        i = 0
        while i < len(queue):
            wave = queue[i:i + self.batch]
            with self.tracer.span("wave"):
                done.extend(self.generate(wave, **extras))
            i += self.batch
        return done
