"""Wire protocol for the trace-query service: JSON codecs + digests.

Everything the service speaks is JSON, but analysis results are columnar
numeric data — so arrays travel **Arrow-ish**: raw little-endian column
bytes, base64-encoded, alongside their dtype and shape.  That keeps the
envelope a single self-describing JSON document (stdlib-only clients)
while making decode a zero-copy ``np.frombuffer`` per column and, more
importantly, making the round trip **bit-exact**: a result decoded from
the wire digests identically to the library-call result it came from,
which is what the conformance tests and the CI smoke job assert.

Three codec families live here:

* **plans** — :func:`encode_filter` / :func:`encode_steps` serialize the
  client's ``Filter`` trees and plan steps; :func:`apply_steps` replays
  them onto a server-side ``TraceQuery``/``SetQuery`` through the normal
  builder methods, so the service executes exactly the plan a local
  script would (mask fusion, pushdown, plan-cache keys included).
* **values** — :func:`encode_value` / :func:`decode_value` cover every
  type a registered op returns (``EventFrame``, ``Categorical``, numeric
  and string ndarrays, tuples/lists/dicts, scalars) plus everything a
  JSON request can carry as op arguments.
* **digests** — :func:`result_digest` is a canonical SHA-256 over a
  result value (wire-representation independent), and
  :func:`canonical_json` keys the service's single-flight table for
  requests the plan cache cannot digest.

User ``Filter`` *subclasses* and callable arguments do not travel — the
codec raises :class:`ProtocolError` instead of guessing at semantics.
"""

from __future__ import annotations

import base64
import hashlib
import json
from typing import Any, Dict, List, Sequence

import numpy as np

from ..core.filters import Filter, _And, _Not, _Or
from ..core.frame import Categorical, EventFrame

__all__ = ["ProtocolError", "encode_filter", "decode_filter",
           "encode_steps", "decode_steps", "apply_steps", "encode_value",
           "decode_value", "result_digest", "canonical_json"]


class ProtocolError(ValueError):
    """A request or value cannot be represented on (or decoded from) the
    wire.  The service maps this to HTTP 400."""


# ---------------------------------------------------------------------------
# filters and plan steps
# ---------------------------------------------------------------------------

def encode_filter(f: Filter) -> dict:
    if isinstance(f, _And):
        return {"k": "and", "a": encode_filter(f.a), "b": encode_filter(f.b)}
    if isinstance(f, _Or):
        return {"k": "or", "a": encode_filter(f.a), "b": encode_filter(f.b)}
    if isinstance(f, _Not):
        return {"k": "not", "a": encode_filter(f.a)}
    if type(f) is not Filter:
        raise ProtocolError(
            f"custom Filter subclass {type(f).__name__!r} cannot travel "
            f"over the wire; express the predicate with Filter leaves")
    return {"k": "leaf", "field": f.field, "op": f.operator,
            "value": encode_value(f.value),
            "trim": getattr(f, "_trim", None)}


def decode_filter(d: dict) -> Filter:
    try:
        kind = d["k"]
        if kind == "and":
            return _And(decode_filter(d["a"]), decode_filter(d["b"]))
        if kind == "or":
            return _Or(decode_filter(d["a"]), decode_filter(d["b"]))
        if kind == "not":
            return _Not(decode_filter(d["a"]))
        if kind == "leaf":
            f = Filter(d["field"], d["op"], decode_value(d["value"]))
            if d.get("trim") is not None:
                f._trim = d["trim"]
            return f
    except ProtocolError:
        raise
    except (KeyError, TypeError, ValueError) as e:
        raise ProtocolError(f"malformed filter {d!r}: {e}") from None
    raise ProtocolError(f"unknown filter kind {kind!r}")


def encode_steps(steps: Sequence) -> List[dict]:
    """Serialize plan steps (the real ``query.Step`` objects a local
    TraceQuery carries)."""
    from ..core.query import FilterStep, ProcessStep, SliceTimeStep
    out = []
    for step in steps:
        if type(step) is FilterStep:
            out.append({"k": "filter", "filter": encode_filter(step.filter)})
        elif type(step) is SliceTimeStep:
            out.append({"k": "slice_time", "start": float(step.start),
                        "end": float(step.end), "trim": step.trim})
        elif type(step) is ProcessStep:
            out.append({"k": "restrict_processes",
                        "procs": [int(p) for p in step.procs]})
        else:
            raise ProtocolError(
                f"plan step {type(step).__name__!r} cannot travel over "
                f"the wire")
    return out


def decode_steps(steps: Sequence[dict]) -> List[dict]:
    """Validate a wire step list (shape only); returns it unchanged.
    :func:`apply_steps` does the real decoding onto a query object."""
    for s in steps:
        if not isinstance(s, dict) or s.get("k") not in (
                "filter", "slice_time", "restrict_processes"):
            raise ProtocolError(f"malformed plan step {s!r}")
    return list(steps)


def apply_steps(query, steps: Sequence[dict]):
    """Replay wire steps onto a ``TraceQuery``/``SetQuery`` via its builder
    methods — the server-side plan is then byte-for-byte the plan a local
    chain would build (same fusion, same plan-cache key)."""
    for s in decode_steps(steps):
        try:
            if s["k"] == "filter":
                query = query.filter(decode_filter(s["filter"]))
            elif s["k"] == "slice_time":
                query = query.slice_time(float(s["start"]), float(s["end"]),
                                         trim=s.get("trim", "overlap"))
            else:
                query = query.restrict_processes(
                    [int(p) for p in s["procs"]])
        except ProtocolError:
            raise
        except (KeyError, TypeError, ValueError) as e:
            raise ProtocolError(f"malformed plan step {s!r}: {e}") from None
    return query


# ---------------------------------------------------------------------------
# values (op arguments and results)
# ---------------------------------------------------------------------------

_MARK = "__pipit__"


def _b64(arr: np.ndarray) -> str:
    return base64.b64encode(
        np.ascontiguousarray(arr).tobytes()).decode("ascii")


def encode_value(obj: Any) -> Any:
    """JSON-able encoding of one op argument or result value."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, (np.bool_, np.integer, np.floating)):
        return {_MARK: "scalar", "dtype": np.asarray(obj).dtype.str,
                "b64": _b64(np.asarray(obj))}
    if isinstance(obj, EventFrame):
        return {_MARK: "frame",
                "columns": [[name, encode_value(obj.column(name))]
                            for name in obj.columns]}
    if isinstance(obj, Categorical):
        return {_MARK: "categorical", "codes": encode_value(obj.codes),
                "categories": [str(c) for c in obj.categories]}
    if isinstance(obj, np.ndarray):
        if obj.dtype.kind in "UOS":
            return {_MARK: "strarray", "shape": list(obj.shape),
                    "items": [str(x) for x in obj.ravel()]}
        return {_MARK: "ndarray", "dtype": obj.dtype.str,
                "shape": list(obj.shape), "b64": _b64(obj)}
    if isinstance(obj, tuple):
        return {_MARK: "tuple", "items": [encode_value(x) for x in obj]}
    if isinstance(obj, (list,)):
        return [encode_value(x) for x in obj]
    if isinstance(obj, (set, frozenset)):
        return {_MARK: "tuple",
                "items": sorted((encode_value(x) for x in obj), key=repr)}
    if isinstance(obj, range):
        return {_MARK: "tuple", "items": [int(x) for x in obj]}
    if isinstance(obj, dict):
        items = []
        for k, v in obj.items():
            if not isinstance(k, (str, int, float, bool)) and k is not None:
                raise ProtocolError(f"dict key {k!r} cannot travel as JSON")
            items.append([k, encode_value(v)])
        return {_MARK: "dict", "items": items}
    raise ProtocolError(
        f"value of type {type(obj).__name__!r} cannot travel over the wire")


def decode_value(obj: Any) -> Any:
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, list):
        return [decode_value(x) for x in obj]
    if not isinstance(obj, dict):
        raise ProtocolError(f"undecodable wire value {obj!r}")
    kind = obj.get(_MARK)
    try:
        if kind is None:
            raise ProtocolError(f"plain JSON objects must use the "
                                f"{{{_MARK!r}: 'dict'}} envelope: {obj!r}")
        if kind == "scalar":
            raw = base64.b64decode(obj["b64"])
            return np.frombuffer(raw, dtype=np.dtype(obj["dtype"]))[0]
        if kind == "ndarray":
            raw = base64.b64decode(obj["b64"])
            arr = np.frombuffer(raw, dtype=np.dtype(obj["dtype"]))
            return arr.reshape(obj["shape"]).copy()
        if kind == "strarray":
            arr = np.asarray([str(x) for x in obj["items"]], dtype=object)
            return arr.reshape(obj["shape"])
        if kind == "categorical":
            return Categorical.from_codes(
                np.asarray(decode_value(obj["codes"]), np.int32),
                np.asarray([str(c) for c in obj["categories"]],
                           dtype=object))
        if kind == "frame":
            out = EventFrame()
            for name, enc in obj["columns"]:
                out[str(name)] = decode_value(enc)
            return out
        if kind == "tuple":
            return tuple(decode_value(x) for x in obj["items"])
        if kind == "dict":
            return {k: decode_value(v) for k, v in obj["items"]}
    except ProtocolError:
        raise
    except (KeyError, TypeError, ValueError) as e:
        raise ProtocolError(f"undecodable wire value "
                            f"({kind!r}): {e}") from None
    raise ProtocolError(f"unknown wire value kind {kind!r}")


# ---------------------------------------------------------------------------
# digests
# ---------------------------------------------------------------------------

def _digest_into(h, obj: Any) -> None:
    if obj is None:
        h.update(b"\x00N")
    elif isinstance(obj, (bool, np.bool_)):
        h.update(b"\x00b" + (b"1" if obj else b"0"))
    elif isinstance(obj, (int, np.integer)):
        h.update(b"\x00i" + repr(int(obj)).encode())
    elif isinstance(obj, (float, np.floating)):
        h.update(b"\x00f" + repr(float(obj)).encode())
    elif isinstance(obj, str):
        h.update(b"\x00s" + obj.encode())
    elif isinstance(obj, EventFrame):
        h.update(b"\x00F")
        for name in obj.columns:
            _digest_into(h, name)
            _digest_into(h, obj.column(name))
    elif isinstance(obj, Categorical):
        # digest by decoded content, not representation: a Categorical and
        # the equivalent string array digest identically
        _digest_into(h, obj.to_strings())
    elif isinstance(obj, np.ndarray):
        if obj.dtype.kind in "UOS":
            h.update(b"\x00S" + repr(list(obj.shape)).encode())
            for x in obj.ravel():
                _digest_into(h, str(x))
        else:
            h.update(b"\x00A" + obj.dtype.str.encode()
                     + repr(list(obj.shape)).encode())
            h.update(np.ascontiguousarray(obj).tobytes())
    elif isinstance(obj, (list, tuple)):
        # lists and tuples digest identically: wire transport must not
        # change a result's digest
        h.update(b"\x00L" + repr(len(obj)).encode())
        for x in obj:
            _digest_into(h, x)
    elif isinstance(obj, dict):
        h.update(b"\x00D" + repr(len(obj)).encode())
        for k in sorted(obj, key=repr):
            _digest_into(h, k)
            _digest_into(h, obj[k])
    else:
        raise ProtocolError(
            f"cannot digest value of type {type(obj).__name__!r}")


def result_digest(value: Any) -> str:
    """Canonical SHA-256 of a result value.  Representation-independent
    where the wire is: tuples/lists collapse, ``Categorical`` digests as
    its decoded strings — so ``digest(decode(encode(x))) == digest(x)``
    always, and the service-vs-library equality checks are one string
    compare."""
    h = hashlib.sha256()
    _digest_into(h, value)
    return h.hexdigest()


def canonical_json(obj: Any) -> str:
    """Deterministic JSON (sorted keys, tight separators) — the service's
    fallback single-flight key for requests outside the plan cache's
    digestible domain."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=str)
