"""Client for the trace-query service: the library's query API, remote.

A script written against the library —

    trace = Trace.open("run.pipitpack", streaming=True)
    prof = trace.query().slice_time(t0, t1).flat_profile()

— points at a running :mod:`~repro.serving.tracequery` server with a
one-line change::

    client = ServiceClient("127.0.0.1", 8731, tenant="alice")
    trace = client.open("run.pipitpack", streaming=True)
    prof = trace.query().slice_time(t0, t1).flat_profile()

:class:`RemoteQuery` mirrors the ``TraceQuery`` builder (``filter`` /
``slice_time`` / ``restrict_processes`` and every registered terminal op,
resolved through the same :mod:`~repro.core.registry`), but nothing runs
locally: the plan is serialized with :mod:`~repro.serving.protocol`,
executed server-side against the pooled handle, and the columnar result
decoded back into the same ``EventFrame``/ndarray types a library call
returns.  Per-call ``cache=`` / ``lane=`` / ``digest_only=`` kwargs map
onto the service's cache, admission lanes, and digest-only responses.

Transport is stdlib ``http.client`` with a persistent keep-alive
connection; the client is thread-compatible (a lock serializes requests
on the shared connection).
"""

from __future__ import annotations

import http.client
import json
import random
import threading
import time
from typing import Any, Dict, List, Optional, Sequence

from ..core import registry
from ..core.filters import Filter
from . import protocol

__all__ = ["RemoteError", "ServiceClient", "RemoteTrace", "RemoteTraceSet",
           "RemoteLiveTrace", "RemoteQuery"]


class RemoteError(RuntimeError):
    """A non-2xx service response; carries the HTTP status, the service's
    machine-readable error code, and any extra error fields (``extra``)
    the service attached — e.g. ``retry_after_ms`` on a live-session
    stall."""

    def __init__(self, status: int, code: str, message: str,
                 extra: Optional[dict] = None):
        super().__init__(f"[{status} {code}] {message}")
        self.status = status
        self.code = code
        self.extra = extra or {}


#: request targets whose handlers are idempotent: re-sending after a
#: connection fault cannot change service state beyond what one send
#: does.  GETs always qualify; the plan-execution POSTs qualify because
#: a replayed plan coalesces/caches onto the same digest-keyed result.
_IDEMPOTENT_POSTS = ("/query", "/setquery", "/diagnose", "/live")


class ServiceClient:
    """One connection to a trace-query server (see module docstring).

    Transport faults on **idempotent** requests (every GET, plus the
    plan-execution POSTs — replaying a plan is digest-idempotent) are
    retried up to ``retries`` times with jittered exponential backoff
    (``backoff * 2^attempt``, capped at ``backoff_max``, each delay
    uniformly jittered to 50–100%), covering both connection resets at
    send time and resets *mid-response*.  Non-idempotent requests
    (``/shutdown``) keep only the classic single stale-keep-alive retry:
    they are replayed only when the failure hit a **reused** connection,
    where the overwhelmingly likely cause is the server having closed an
    idle socket before the request arrived.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 8731,
                 tenant: Optional[str] = None, timeout: float = 120.0,
                 retries: int = 2, backoff: float = 0.05,
                 backoff_max: float = 2.0,
                 deadline_ms: Optional[float] = None):
        self.host = host
        self.port = int(port)
        self.tenant = tenant
        self.timeout = timeout
        self.retries = max(int(retries), 0)
        self.backoff = float(backoff)
        self.backoff_max = float(backoff_max)
        #: default per-request server-side deadline (ms) attached to every
        #: plan execution; per-call ``deadline_ms`` overrides
        self.deadline_ms = deadline_ms
        self._lock = threading.Lock()
        self._conn: Optional[http.client.HTTPConnection] = None
        #: response metadata of the most recent query (digest, cached,
        #: coalesced, elapsed_ms) — handy in tests and benchmarks
        self.last_meta: Dict[str, Any] = {}
        #: transport retries performed over this client's lifetime
        self.retry_count = 0

    # -- transport ---------------------------------------------------------
    def _request(self, method: str, path: str,
                 payload: Optional[dict] = None) -> dict:
        body = json.dumps(payload).encode() if payload is not None else None
        idempotent = (method == "GET" or path in _IDEMPOTENT_POSTS)
        attempts = (self.retries + 1) if idempotent else 2
        with self._lock:
            for attempt in range(attempts):
                reused = self._conn is not None
                if self._conn is None:
                    self._conn = http.client.HTTPConnection(
                        self.host, self.port, timeout=self.timeout)
                try:
                    self._conn.request(
                        method, path, body=body,
                        headers={"Content-Type": "application/json"})
                    resp = self._conn.getresponse()
                    data = resp.read()
                    break
                except (http.client.HTTPException, ConnectionError,
                        BrokenPipeError, OSError):
                    self._close_locked()
                    if not idempotent and not reused:
                        # fresh connection: the server may have received
                        # and acted on the request — never replay
                        raise
                    if attempt + 1 >= attempts:
                        raise
                    self.retry_count += 1
                    if idempotent:
                        delay = min(self.backoff * (2 ** attempt),
                                    self.backoff_max)
                        time.sleep(delay * (0.5 + random.random() * 0.5))
        try:
            out = json.loads(data.decode("utf-8"))
        except ValueError:
            raise RemoteError(resp.status, "bad_response",
                              f"non-JSON response ({len(data)} bytes)")
        if resp.status >= 400 or not out.get("ok", False):
            err = out.get("error") or {}
            extra = {k: v for k, v in err.items()
                     if k not in ("code", "message")}
            raise RemoteError(resp.status, err.get("code", "error"),
                              err.get("message", "request failed"),
                              extra=extra)
        return out

    def _close_locked(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            except OSError:
                pass
            self._conn = None

    def close(self) -> None:
        with self._lock:
            self._close_locked()

    def __enter__(self) -> "ServiceClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- service surface ---------------------------------------------------
    def health(self) -> dict:
        return self._request("GET", "/health")

    def stats(self) -> dict:
        return self._request("GET", "/stats")

    def ops(self) -> List[dict]:
        return self._request("GET", "/ops")["ops"]

    def shutdown(self, grace: Optional[float] = None) -> dict:
        payload = {} if grace is None else {"grace": grace}
        return self._request("POST", "/shutdown", payload)

    def open(self, path, format: str = "auto", streaming: bool = False,
             chunk_rows: Optional[int] = None,
             processes: Optional[int] = None,
             executor: str = "auto") -> "RemoteTrace":
        """A remote handle over ``path`` — the signature of
        ``Trace.open``, minus reader kwargs.  Nothing opens until the
        first query; the server pools the actual handle."""
        paths = ([str(p) for p in path]
                 if isinstance(path, (list, tuple)) else [str(path)])
        spec = {"mode": "trace", "paths": paths, "format": format,
                "streaming": streaming, "chunk_rows": chunk_rows,
                "processes": processes, "executor": executor}
        return RemoteTrace(self, spec)

    def open_live(self, path, chunk_rows: Optional[int] = None,
                  processes: Optional[int] = None,
                  executor: str = "auto") -> "RemoteLiveTrace":
        """A remote live handle over still-growing pack shard(s): polls go
        to ``/live`` and come back watermarked (see
        :meth:`RemoteLiveTrace.poll`)."""
        paths = ([str(p) for p in path]
                 if isinstance(path, (list, tuple)) else [str(path)])
        spec = {"mode": "live", "paths": paths, "format": "auto",
                "streaming": False, "chunk_rows": chunk_rows,
                "processes": processes, "executor": executor}
        return RemoteLiveTrace(self, spec)

    def open_liveset(self, root: str, pattern: str = "rank_*.pack",
                     lag_timeout: float = 2.0, dead_timeout: float = 10.0,
                     chunk_rows: Optional[int] = None,
                     processes: Optional[int] = None,
                     executor: str = "auto") -> "RemoteLiveTrace":
        """A remote rank-failure-tolerant live handle over an N-rank shard
        directory: results carry a coverage report, and degraded coverage
        comes back as a 206 partial response naming the missing ranks."""
        spec = {"mode": "liveset", "paths": [str(root)],
                "pattern": pattern, "lag_timeout": float(lag_timeout),
                "dead_timeout": float(dead_timeout), "format": "auto",
                "streaming": False, "chunk_rows": chunk_rows,
                "processes": processes, "executor": executor}
        return RemoteLiveTrace(self, spec)

    def open_set(self, paths: Sequence, format: str = "auto",
                 processes: Optional[int] = None,
                 labels: Optional[Sequence[str]] = None,
                 streaming: bool = False,
                 chunk_rows: Optional[int] = None) -> "RemoteTraceSet":
        """A remote ``TraceSet`` over per-run paths (for the diff /
        regression comparison ops)."""
        spec = {"mode": "set", "paths": [str(p) for p in paths],
                "format": format, "processes": processes,
                "labels": list(labels) if labels is not None else None,
                "streaming": streaming, "chunk_rows": chunk_rows}
        return RemoteTraceSet(self, spec)

    # -- execution ---------------------------------------------------------
    def _run(self, open_spec: dict, steps: List[dict], op: str, args,
             kwargs, *, cache: Optional[bool], lane: Optional[str],
             digest_only: bool,
             deadline_ms: Optional[float] = None) -> Any:
        payload = {
            "open": open_spec,
            "steps": steps,
            "op": op,
            "args": [protocol.encode_value(a) for a in args],
            "kwargs": {str(k): protocol.encode_value(v)
                       for k, v in kwargs.items()},
        }
        if self.tenant is not None:
            payload["tenant"] = self.tenant
        if cache is not None:
            payload["cache"] = cache
        if lane is not None:
            payload["lane"] = lane
        if digest_only:
            payload["digest_only"] = True
        if deadline_ms is None:
            deadline_ms = self.deadline_ms
        if deadline_ms is not None:
            payload["deadline_ms"] = float(deadline_ms)
        endpoint = "/setquery" if open_spec["mode"] == "set" else "/query"
        out = self._request("POST", endpoint, payload)
        self.last_meta = {k: out.get(k) for k in
                          ("digest", "cached", "coalesced", "elapsed_ms",
                           "tenant")}
        if digest_only:
            return out["digest"]
        return protocol.decode_value(out["result"])

    def live_poll(self, open_spec: dict, op: str, args=(), kwargs=None,
                  *, steps: Optional[List[dict]] = None,
                  session: str = "default", min_advance_rows: int = 1,
                  digest_only: bool = False) -> dict:
        """One ``/live`` poll.  Returns the response dict with ``result``
        decoded in place: ``{value, watermark, coverage?, partial,
        missing_ranks?, advanced_rows, digest, session}``.  A stalled
        watermark raises :class:`RemoteError` with ``code
        "watermark_stalled"`` and ``extra["retry_after_ms"]``; a degraded
        liveset answer arrives as a 206 with ``partial: True`` — a
        *successful* response here, not an error."""
        payload: Dict[str, Any] = {
            "open": open_spec, "op": op,
            "steps": list(steps or []),
            "args": [protocol.encode_value(a) for a in args],
            "kwargs": {str(k): protocol.encode_value(v)
                       for k, v in (kwargs or {}).items()},
            "session": session, "min_advance_rows": int(min_advance_rows),
        }
        if self.tenant is not None:
            payload["tenant"] = self.tenant
        if digest_only:
            payload["digest_only"] = True
        out = self._request("POST", "/live", payload)
        self.last_meta = {k: out.get(k) for k in
                          ("digest", "elapsed_ms", "tenant", "partial",
                           "advanced_rows")}
        res = dict(out)
        res["value"] = (protocol.decode_value(out["result"])
                        if "result" in out else None)
        return res


class RemoteQuery:
    """A lazy plan executed server-side — same builder surface as
    ``TraceQuery`` (and ``SetQuery`` when built from a remote set)."""

    def __init__(self, client: ServiceClient, open_spec: dict,
                 steps: Optional[List[dict]] = None):
        self._client = client
        self._open = open_spec
        self._steps: List[dict] = list(steps or [])

    def _with(self, step: dict) -> "RemoteQuery":
        return RemoteQuery(self._client, self._open, self._steps + [step])

    def filter(self, f: Filter) -> "RemoteQuery":
        return self._with({"k": "filter", "filter": protocol.encode_filter(f)})

    def slice_time(self, start: float, end: float,
                   trim: str = "overlap") -> "RemoteQuery":
        return self._with({"k": "slice_time", "start": float(start),
                           "end": float(end), "trim": trim})

    def restrict_processes(self, procs: Sequence[int]) -> "RemoteQuery":
        return self._with({"k": "restrict_processes",
                           "procs": [int(p) for p in procs]})

    filter_processes = restrict_processes

    def run(self, op_name: str, *args: Any, cache: Optional[bool] = None,
            lane: Optional[str] = None, digest_only: bool = False,
            deadline_ms: Optional[float] = None, **kwargs: Any) -> Any:
        """Execute a registered terminal op server-side; returns the
        decoded result (or its digest with ``digest_only=True``).
        ``deadline_ms`` bounds server-side execution for this call
        (overriding the client default); past it the service answers 504
        and cancels the plan at the next chunk boundary."""
        return self._client._run(self._open, self._steps, op_name, args,
                                 kwargs, cache=cache, lane=lane,
                                 digest_only=digest_only,
                                 deadline_ms=deadline_ms)

    def __getattr__(self, name: str):
        return registry.terminal_op(name, self.run, "RemoteQuery")

    def __repr__(self) -> str:  # pragma: no cover
        return (f"RemoteQuery({self._open['mode']}, "
                f"{len(self._steps)} step(s))")


class RemoteTrace:
    """Remote stand-in for an opened ``Trace``/``StreamingTrace``."""

    def __init__(self, client: ServiceClient, open_spec: dict):
        self._client = client
        self._open = open_spec

    def query(self) -> RemoteQuery:
        return RemoteQuery(self._client, self._open)

    def diagnose(self, detectors: Optional[Sequence[str]] = None,
                 cache: Optional[bool] = None) -> Any:
        """Run the automated diagnostics suite server-side via the
        dedicated ``/diagnose`` endpoint; returns the decoded, ranked
        Findings frame (identical to ``query().diagnose(...)``, which
        routes through ``/query`` — both coalesce and cache as one plan).
        """
        payload: Dict[str, Any] = {"open": self._open, "steps": []}
        if detectors is not None:
            payload["detectors"] = [str(d) for d in detectors]
        if self._client.tenant is not None:
            payload["tenant"] = self._client.tenant
        if cache is not None:
            payload["cache"] = cache
        out = self._client._request("POST", "/diagnose", payload)
        self._client.last_meta = {k: out.get(k) for k in
                                  ("digest", "cached", "coalesced",
                                   "elapsed_ms", "tenant")}
        return protocol.decode_value(out["result"])

    def __repr__(self) -> str:  # pragma: no cover
        return f"RemoteTrace({self._open['paths']!r})"


class RemoteLiveTrace:
    """Remote stand-in for a live (still-growing) trace or rank fleet.

    ``poll("flat_profile")`` executes over the committed prefix and
    returns the watermarked (and, for lisets, coverage-annotated)
    response.  Build windowed polls with the same step builders as
    :class:`RemoteQuery` via ``query()`` then ``poll_query``."""

    def __init__(self, client: ServiceClient, open_spec: dict):
        self._client = client
        self._open = open_spec

    def poll(self, op_name: str, *args: Any, session: str = "default",
             min_advance_rows: int = 1, digest_only: bool = False,
             steps: Optional[List[dict]] = None, **kwargs: Any) -> dict:
        return self._client.live_poll(
            self._open, op_name, args, kwargs, steps=steps,
            session=session, min_advance_rows=min_advance_rows,
            digest_only=digest_only)

    def __repr__(self) -> str:  # pragma: no cover
        return f"RemoteLiveTrace({self._open['paths']!r})"


class RemoteTraceSet:
    """Remote stand-in for a ``TraceSet`` (comparison/diff ops)."""

    def __init__(self, client: ServiceClient, open_spec: dict):
        self._client = client
        self._open = open_spec

    def query(self) -> RemoteQuery:
        return RemoteQuery(self._client, self._open)

    def __repr__(self) -> str:  # pragma: no cover
        return f"RemoteTraceSet({self._open['paths']!r})"
