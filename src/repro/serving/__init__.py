"""Serving layer: the jax batch engine and the trace-query service.

Attribute access is lazy: ``repro.serving.engine`` needs jax, while the
trace-query service (:mod:`~repro.serving.tracequery`,
:mod:`~repro.serving.client`, :mod:`~repro.serving.protocol`) is
stdlib+numpy only — importing one must not drag in the other's
dependencies.
"""

__all__ = ["Request", "ServeEngine", "TraceService", "TraceServer",
           "ServiceClient"]


def __getattr__(name):
    if name in ("Request", "ServeEngine"):
        from . import engine
        return getattr(engine, name)
    if name in ("TraceService", "TraceServer"):
        from . import tracequery
        return getattr(tracequery, name)
    if name == "ServiceClient":
        from .client import ServiceClient
        return ServiceClient
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
