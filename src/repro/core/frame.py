"""Columnar event store — the pandas-DataFrame analogue Pipit is built on.

The paper (§III-A) argues that storing each event attribute as a contiguous
column lets trace analysis vectorize.  pandas is not available in this
environment, so ``EventFrame`` implements that insight directly on NumPy:

* every column is a single contiguous ``np.ndarray`` (column-major layout),
* string-valued columns (``Name``, ``Event Type``) are dictionary-encoded as
  ``Categorical`` (int32 codes + a small category table), matching pandas'
  categorical dtype that Pipit relies on for memory/performance,
* row selection (boolean mask / index take) is zero-copy per column where
  NumPy allows it, and all aggregation paths (``groupby_agg``) are pure
  vectorized NumPy (``np.lexsort`` + ``np.add.reduceat``).
"""

from __future__ import annotations

import io
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Union

import numpy as np

__all__ = ["Categorical", "EventFrame", "concat", "optimize_dtypes"]


class Categorical:
    """Dictionary-encoded string column: int32 codes into a category table."""

    __slots__ = ("codes", "categories", "_lookup")

    def __init__(self, codes: np.ndarray, categories: np.ndarray):
        self.codes = np.asarray(codes, dtype=np.int32)
        self.categories = np.asarray(categories)
        self._lookup: Optional[Dict[str, int]] = None

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_values(cls, values: Iterable[Any]) -> "Categorical":
        arr = np.asarray(list(values) if not isinstance(values, np.ndarray) else values)
        if arr.dtype.kind in ("U", "S", "O"):
            cats, codes = np.unique(arr.astype(str), return_inverse=True)
            return cls(codes.astype(np.int32), cats)
        raise TypeError(f"Categorical.from_values expects strings, got {arr.dtype}")

    @classmethod
    def from_codes(cls, codes: np.ndarray, categories: Sequence[str]) -> "Categorical":
        return cls(np.asarray(codes, np.int32), np.asarray(categories, dtype=object).astype(str))

    # -- core --------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.codes)

    def to_strings(self) -> np.ndarray:
        return self.categories[self.codes]

    def lookup(self, name: str) -> int:
        """Code of ``name`` or -1 if absent."""
        if self._lookup is None:
            self._lookup = {str(c): i for i, c in enumerate(self.categories)}
        return self._lookup.get(name, -1)

    def mask_isin(self, names: Iterable[str]) -> np.ndarray:
        codes = [self.lookup(n) for n in names]
        codes = [c for c in codes if c >= 0]
        if not codes:
            return np.zeros(len(self.codes), dtype=bool)
        return np.isin(self.codes, np.asarray(codes, np.int32))

    def mask_eq(self, name: str) -> np.ndarray:
        c = self.lookup(name)
        if c < 0:
            return np.zeros(len(self.codes), dtype=bool)
        return self.codes == c

    def take(self, idx: np.ndarray) -> "Categorical":
        return Categorical(self.codes[idx], self.categories)

    def append(self, other: "Categorical") -> "Categorical":
        if len(self.categories) == len(other.categories) and np.array_equal(
            self.categories, other.categories
        ):
            return Categorical(np.concatenate([self.codes, other.codes]), self.categories)
        # remap other's codes into a merged table
        merged, inv = np.unique(
            np.concatenate([self.categories.astype(str), other.categories.astype(str)]),
            return_inverse=True,
        )
        self_map = inv[: len(self.categories)]
        other_map = inv[len(self.categories):]
        codes = np.concatenate(
            [self_map[self.codes].astype(np.int32), other_map[other.codes].astype(np.int32)]
        )
        return Categorical(codes, merged)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Categorical(n={len(self)}, k={len(self.categories)})"


ColumnLike = Union[np.ndarray, Categorical]


def _as_column(values: Any) -> ColumnLike:
    if isinstance(values, Categorical):
        return values
    arr = np.asarray(values)
    if arr.dtype.kind in ("U", "S", "O"):
        try:
            return Categorical.from_values(arr)
        except TypeError:
            return arr  # heterogeneous objects stay as an object column
    return arr


class EventFrame:
    """A minimal, fast, columnar DataFrame for trace events."""

    def __init__(self, columns: Optional[Mapping[str, Any]] = None):
        self._cols: Dict[str, ColumnLike] = {}
        self._n = 0
        if columns:
            for k, v in columns.items():
                self[k] = v

    # -- basic protocol ----------------------------------------------------
    def __len__(self) -> int:
        return self._n

    @property
    def columns(self) -> List[str]:
        return list(self._cols.keys())

    def __contains__(self, name: str) -> bool:
        return name in self._cols

    def column(self, name: str) -> ColumnLike:
        """Raw column (Categorical stays Categorical)."""
        return self._cols[name]

    def __getitem__(self, key):
        if isinstance(key, str):
            col = self._cols[key]
            return col.to_strings() if isinstance(col, Categorical) else col
        if isinstance(key, np.ndarray):
            if key.dtype == bool:
                return self.take(np.nonzero(key)[0])
            return self.take(key)
        if isinstance(key, (list, tuple)) and all(isinstance(k, str) for k in key):
            return EventFrame({k: self._cols[k] for k in key})
        if isinstance(key, slice):
            return self.take(np.arange(self._n)[key])
        raise KeyError(key)

    def __setitem__(self, name: str, values: Any) -> None:
        col = _as_column(values)
        n = len(col.codes) if isinstance(col, Categorical) else (
            len(col) if col.ndim > 0 else 0
        )
        if self._cols and n != self._n:
            raise ValueError(f"column {name!r} has length {n}, frame has {self._n}")
        if not self._cols:
            self._n = n
        self._cols[name] = col

    def cat(self, name: str) -> Categorical:
        col = self._cols[name]
        if not isinstance(col, Categorical):
            col = Categorical.from_values(col)
            self._cols[name] = col
        return col

    def codes(self, name: str) -> np.ndarray:
        return self.cat(name).codes

    # -- selection ---------------------------------------------------------
    def take(self, idx: np.ndarray) -> "EventFrame":
        idx = np.asarray(idx)
        out = EventFrame()
        out._n = len(idx)
        for k, c in self._cols.items():
            out._cols[k] = c.take(idx) if isinstance(c, Categorical) else c[idx]
        return out

    def mask(self, m: np.ndarray) -> "EventFrame":
        return self.take(np.nonzero(np.asarray(m, bool))[0])

    def head(self, n: int = 5) -> "EventFrame":
        return self.take(np.arange(min(n, self._n)))

    def copy(self) -> "EventFrame":
        out = EventFrame()
        out._n = self._n
        for k, c in self._cols.items():
            out._cols[k] = (
                Categorical(c.codes.copy(), c.categories) if isinstance(c, Categorical) else c.copy()
            )
        return out

    def drop(self, *names: str) -> "EventFrame":
        out = EventFrame()
        out._n = self._n
        for k, c in self._cols.items():
            if k not in names:
                out._cols[k] = c
        return out

    def rename(self, mapping: Mapping[str, str]) -> "EventFrame":
        out = EventFrame()
        out._n = self._n
        for k, c in self._cols.items():
            out._cols[mapping.get(k, k)] = c
        return out

    # -- ordering ----------------------------------------------------------
    def argsort(self, by: Sequence[str], kind: str = "stable") -> np.ndarray:
        keys = []
        for name in reversed(list(by)):
            col = self._cols[name]
            keys.append(col.codes if isinstance(col, Categorical) else col)
        return np.lexsort(keys) if len(keys) > 1 else np.argsort(keys[0], kind=kind)

    def sort_by(self, by: Union[str, Sequence[str]]) -> "EventFrame":
        if isinstance(by, str):
            by = [by]
        return self.take(self.argsort(by))

    # -- aggregation -------------------------------------------------------
    def groupby_agg(
        self,
        by: Union[str, Sequence[str]],
        aggs: Mapping[str, Union[str, Callable[[np.ndarray], Any]]],
        count_name: Optional[str] = None,
    ) -> "EventFrame":
        """Vectorized groupby: lexsort on keys then reduceat per segment.

        ``aggs`` maps column name -> one of {"sum","mean","min","max","std",
        "median","first","last"} or a callable applied per group (slow path).
        """
        if isinstance(by, str):
            by = [by]
        if self._n == 0:
            out = EventFrame()
            for b in by:
                out[b] = np.asarray([])
            for c in aggs:
                out[c] = np.asarray([])
            return out
        order = self.argsort(by)
        key_codes = []
        for name in by:
            col = self._cols[name]
            key_codes.append((col.codes if isinstance(col, Categorical) else col)[order])
        # group boundary where any key changes
        changed = np.zeros(len(order), dtype=bool)
        changed[0] = True
        for kc in key_codes:
            changed[1:] |= kc[1:] != kc[:-1]
        starts = np.nonzero(changed)[0]
        out = EventFrame()
        for name, kc in zip(by, key_codes):
            col = self._cols[name]
            vals = kc[starts]
            if isinstance(col, Categorical):
                out[name] = Categorical(vals, col.categories)
            else:
                out[name] = vals
        counts = np.diff(np.append(starts, len(order)))
        if count_name:
            out[count_name] = counts
        for cname, how in aggs.items():
            col = self._cols[cname]
            vals = (col.codes if isinstance(col, Categorical) else col)[order]
            if callable(how):
                ends = np.append(starts[1:], len(order))
                out[cname] = np.asarray([how(vals[s:e]) for s, e in zip(starts, ends)])
                continue
            if how == "sum":
                res = np.add.reduceat(vals, starts)
            elif how == "mean":
                res = np.add.reduceat(vals.astype(np.float64), starts) / counts
            elif how == "min":
                res = np.minimum.reduceat(vals, starts)
            elif how == "max":
                res = np.maximum.reduceat(vals, starts)
            elif how == "first":
                res = vals[starts]
            elif how == "last":
                res = vals[np.append(starts[1:], len(order)) - 1]
            elif how == "std":
                s1 = np.add.reduceat(vals.astype(np.float64), starts)
                s2 = np.add.reduceat(vals.astype(np.float64) ** 2, starts)
                res = np.sqrt(np.maximum(s2 / counts - (s1 / counts) ** 2, 0.0))
            elif how == "median":
                ends = np.append(starts[1:], len(order))
                res = np.asarray([np.median(vals[s:e]) for s, e in zip(starts, ends)])
            else:
                raise ValueError(f"unknown agg {how!r}")
            out[cname] = res
        return out

    # -- io / display ------------------------------------------------------
    def to_dict(self) -> Dict[str, np.ndarray]:
        return {k: self[k] for k in self.columns}

    def to_csv(self, path_or_buf=None) -> Optional[str]:
        buf = io.StringIO() if path_or_buf is None else path_or_buf
        close = False
        if isinstance(buf, str):
            buf = open(buf, "w")
            close = True
        cols = self.columns
        buf.write(",".join(cols) + "\n")
        mats = [self[c] for c in cols]
        for i in range(self._n):
            buf.write(",".join(str(m[i]) for m in mats) + "\n")
        if close:
            buf.close()
            return None
        if path_or_buf is None:
            return buf.getvalue()
        return None

    def __repr__(self) -> str:
        n_show = min(self._n, 10)
        cols = self.columns
        if not cols:
            return "EventFrame(empty)"
        widths = {}
        cells = {}
        for c in cols:
            vals = self[c][:n_show]
            text = [_fmt(v) for v in vals]
            widths[c] = max(len(c), max((len(t) for t in text), default=0))
            cells[c] = text
        header = "  ".join(c.rjust(widths[c]) for c in cols)
        lines = [header]
        for i in range(n_show):
            lines.append("  ".join(cells[c][i].rjust(widths[c]) for c in cols))
        if self._n > n_show:
            lines.append(f"... ({self._n} rows x {len(cols)} cols)")
        return "\n".join(lines)


def _fmt(v: Any) -> str:
    if isinstance(v, (float, np.floating)):
        return f"{v:.6g}"
    return str(v)


_DOWNCASTS = (np.int8, np.int16, np.int32)


def optimize_dtypes(frame: EventFrame) -> EventFrame:
    """Downcast integer columns in place to the narrowest dtype that holds
    their values (ingest-side memory optimization).

    Every consumer converts through ``np.asarray(col, np.int64/float64)``
    before arithmetic, so narrowing the *storage* dtype is lossless; for
    trace data it typically shrinks process/thread/partner/tag columns 4-8×
    and (for short traces) timestamps 2×.  String columns are already
    dictionary-encoded by ``Categorical``.  Returns the same frame.
    """
    for name in frame.columns:
        col = frame.column(name)
        if isinstance(col, Categorical) or not isinstance(col, np.ndarray):
            continue
        if col.dtype.kind != "i" or col.dtype.itemsize <= 4 or len(col) == 0:
            continue
        lo, hi = int(col.min()), int(col.max())
        for dt in _DOWNCASTS:
            info = np.iinfo(dt)
            if info.min <= lo and hi <= info.max:
                frame._cols[name] = col.astype(dt)
                break
    return frame


def concat(frames: Sequence[EventFrame]) -> EventFrame:
    frames = [f for f in frames if len(f) > 0]
    if not frames:
        return EventFrame()
    cols = frames[0].columns
    out = EventFrame()
    for c in cols:
        first = frames[0].column(c)
        if isinstance(first, Categorical):
            acc = first
            for f in frames[1:]:
                nxt = f.column(c)
                if not isinstance(nxt, Categorical):
                    nxt = Categorical.from_values(np.asarray(nxt).astype(str))
                acc = acc.append(nxt)
            out[c] = acc
        else:
            out[c] = np.concatenate([np.asarray(f.column(c)) for f in frames])
    return out
