"""Canonical column names of the uniform trace data model (paper Fig. 1)."""

TS = "Timestamp (ns)"
ET = "Event Type"
NAME = "Name"
PROC = "Process"
THREAD = "Thread"

# Event Type categories
ENTER = "Enter"
LEAVE = "Leave"
INSTANT = "Instant"

# normalized message columns (NaN / -1 where not applicable)
MSG_SIZE = "_msg_size"
PARTNER = "_partner"
TAG = "_tag"

# normalized message instant names (OTF2 nomenclature)
MPI_SEND = "MpiSend"
MPI_RECV = "MpiRecv"

# derived columns
MATCH = "_matching_event"
MATCH_TS = "_matching_timestamp"
DEPTH = "_depth"
PARENT = "_parent"
INC = "time.inc"
EXC = "time.exc"
CCT_NODE = "_cct_node"

# every column invalidated by row selection (single source of truth for the
# strip/remap paths in trace.py and query.py)
DERIVED_COLUMNS = (MATCH, MATCH_TS, DEPTH, PARENT, INC, EXC, CCT_NODE)

# default predicates
DEFAULT_COMM_PREFIXES = (
    "MPI_", "mpi_", "nccl", "Nccl", "all-gather", "all-reduce", "reduce-scatter",
    "all-to-all", "collective-permute", "send", "recv", "Isend", "Irecv",
)
DEFAULT_IDLE_NAMES = ("MPI_Wait", "MPI_Waitall", "MPI_Recv", "Idle", "MPI_Barrier")
