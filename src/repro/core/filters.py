"""Composable Filter DSL (paper §IV-E).

    f = Filter("Name", "in", ["MPI_Send", "MPI_Recv"]) & Filter("Process", "<", 8)
    small = trace.filter(f)

Operators: ==, !=, <, <=, >, >=, in, not-in, between.  Filters compose with
``&``, ``|``, ``~``.  Time-range filters keep events whose *call interval*
overlaps the window when ``trim="overlap"`` (default for "between" on the
timestamp column), or strictly inside with ``trim="within"``.
"""

from __future__ import annotations

from typing import Any, Sequence

import numpy as np

from .constants import TS
from .frame import Categorical, EventFrame

_OPS = ("==", "!=", "<", "<=", ">", ">=", "in", "not-in", "between")


class Filter:
    def __init__(self, field: str = None, operator: str = None, value: Any = None):
        if operator is not None and operator not in _OPS:
            raise ValueError(f"operator must be one of {_OPS}, got {operator!r}")
        self.field, self.operator, self.value = field, operator, value

    # -- composition -------------------------------------------------------
    def __and__(self, other: "Filter") -> "Filter":
        return _And(self, other)

    def __or__(self, other: "Filter") -> "Filter":
        return _Or(self, other)

    def __invert__(self) -> "Filter":
        return _Not(self)

    # -- evaluation --------------------------------------------------------
    def mask(self, events: EventFrame) -> np.ndarray:
        col = events.column(self.field)
        op, val = self.operator, self.value
        if isinstance(col, Categorical):
            if op == "==":
                return col.mask_eq(str(val))
            if op == "!=":
                return ~col.mask_eq(str(val))
            if op == "in":
                return col.mask_isin([str(v) for v in val])
            if op == "not-in":
                return ~col.mask_isin([str(v) for v in val])
            col = col.to_strings()
        arr = np.asarray(col)
        if op == "==":
            return arr == val
        if op == "!=":
            return arr != val
        if op == "<":
            return arr < val
        if op == "<=":
            return arr <= val
        if op == ">":
            return arr > val
        if op == ">=":
            return arr >= val
        if op == "in":
            return np.isin(arr, np.asarray(list(val)))
        if op == "not-in":
            return ~np.isin(arr, np.asarray(list(val)))
        if op == "between":
            lo, hi = val
            return (arr >= lo) & (arr <= hi)
        raise ValueError(op)

    def __repr__(self) -> str:
        return f"Filter({self.field!r} {self.operator} {self.value!r})"


class _And(Filter):
    def __init__(self, a, b):
        super().__init__()
        self.a, self.b = a, b

    def mask(self, events):
        return self.a.mask(events) & self.b.mask(events)

    def __repr__(self):
        return f"({self.a!r} & {self.b!r})"


class _Or(Filter):
    def __init__(self, a, b):
        super().__init__()
        self.a, self.b = a, b

    def mask(self, events):
        return self.a.mask(events) | self.b.mask(events)

    def __repr__(self):
        return f"({self.a!r} | {self.b!r})"


class _Not(Filter):
    def __init__(self, a):
        super().__init__()
        self.a = a

    def mask(self, events):
        return ~self.a.mask(events)

    def __repr__(self):
        return f"~{self.a!r}"


def time_window_filter(start: float, end: float, trim: str = "overlap") -> Filter:
    """Convenience: filter to a time window.

    ``overlap`` keeps every event with timestamp in [start, end]; callers who
    need call-interval overlap semantics should first ensure matching columns
    and use Trace.slice_time which extends the window per matched pair.
    """
    f = Filter(TS, "between", (start, end))
    f._trim = trim
    return f
