"""Composable Filter DSL (paper §IV-E).

    f = Filter("Name", "in", ["MPI_Send", "MPI_Recv"]) & Filter("Process", "<", 8)
    small = trace.filter(f)

Operators: ==, !=, <, <=, >, >=, in, not-in, between.  Filters compose with
``&``, ``|``, ``~``.  Time-range filters keep events whose *call interval*
overlaps the window when ``trim="overlap"`` (default for "between" on the
timestamp column), or strictly inside with ``trim="within"``.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Set, Tuple

import numpy as np

from .constants import PROC, TS
from .frame import Categorical, EventFrame

_OPS = ("==", "!=", "<", "<=", ">", ">=", "in", "not-in", "between")

# inclusive [lo, hi] bound on an integer column; None = unconstrained
Bounds = Optional[Tuple[float, float]]


class Filter:
    def __init__(self, field: str = None, operator: str = None, value: Any = None):
        if operator is not None and operator not in _OPS:
            raise ValueError(f"operator must be one of {_OPS}, got {operator!r}")
        self.field, self.operator, self.value = field, operator, value

    # -- composition -------------------------------------------------------
    def __and__(self, other: "Filter") -> "Filter":
        return _And(self, other)

    def __or__(self, other: "Filter") -> "Filter":
        return _Or(self, other)

    def __invert__(self) -> "Filter":
        return _Not(self)

    # -- introspection (used by the query planner) -------------------------
    def columns(self) -> Set[str]:
        """Column names this filter reads — lets the planner decide whether a
        selection can touch derived structure columns."""
        return {self.field} if self.field is not None else set()

    def process_bounds(self) -> Bounds:
        """Inclusive [lo, hi] bound on the Process values that can pass, or
        None when unconstrained.  Conservative: anything this filter cannot
        prove stays None.  The parallel reader uses it to skip whole shards
        before parsing (predicate pushdown, paper §VI)."""
        if self.field != PROC:
            return None
        op, val = self.operator, self.value
        try:
            if op == "==":
                v = float(val)
                return (v, v)
            if op == "in":
                vs = [float(v) for v in val]
                return (min(vs), max(vs)) if vs else (1.0, 0.0)
            if op == "between":
                lo, hi = val
                return (float(lo), float(hi))
            if op == "<":
                v = float(val)
                # process ids are integers: the largest passing id
                return (-np.inf, v - 1 if v.is_integer() else np.floor(v))
            if op == "<=":
                return (-np.inf, float(val))
            if op == ">":
                v = float(val)
                return (v + 1 if v.is_integer() else np.ceil(v), np.inf)
            if op == ">=":
                return (float(val), np.inf)
        except (TypeError, ValueError):
            return None
        return None  # !=, not-in: exclusions don't bound the domain

    @property
    def trim(self) -> Optional[str]:
        """Trim semantics for time-window filters (see time_window_filter):
        "overlap" keeps events whose whole call interval overlaps the window
        (needs matching columns), "within" keeps events whose own timestamp
        falls inside.  None for non-window filters."""
        t = getattr(self, "_trim", None)
        if t is not None and self.operator == "between" and self.field == TS:
            return t
        return None

    def window(self) -> Optional[Tuple[float, float]]:
        """(start, end) when this is a time-window filter, else None."""
        if self.operator == "between" and self.field == TS:
            lo, hi = self.value
            return float(lo), float(hi)
        return None

    # -- evaluation --------------------------------------------------------
    def mask(self, events: EventFrame) -> np.ndarray:
        col = events.column(self.field)
        op, val = self.operator, self.value
        if isinstance(col, Categorical):
            if op == "==":
                return col.mask_eq(str(val))
            if op == "!=":
                return ~col.mask_eq(str(val))
            if op == "in":
                return col.mask_isin([str(v) for v in val])
            if op == "not-in":
                return ~col.mask_isin([str(v) for v in val])
            col = col.to_strings()
        arr = np.asarray(col)
        if op == "==":
            return arr == val
        if op == "!=":
            return arr != val
        if op == "<":
            return arr < val
        if op == "<=":
            return arr <= val
        if op == ">":
            return arr > val
        if op == ">=":
            return arr >= val
        if op == "in":
            return np.isin(arr, np.asarray(list(val)))
        if op == "not-in":
            return ~np.isin(arr, np.asarray(list(val)))
        if op == "between":
            lo, hi = val
            return (arr >= lo) & (arr <= hi)
        raise ValueError(op)

    def __repr__(self) -> str:
        return f"Filter({self.field!r} {self.operator} {self.value!r})"


class _And(Filter):
    def __init__(self, a, b):
        super().__init__()
        self.a, self.b = a, b

    def mask(self, events):
        return self.a.mask(events) & self.b.mask(events)

    def columns(self):
        return self.a.columns() | self.b.columns()

    def process_bounds(self):
        ba, bb = self.a.process_bounds(), self.b.process_bounds()
        if ba is None:
            return bb
        if bb is None:
            return ba
        return (max(ba[0], bb[0]), min(ba[1], bb[1]))

    def __repr__(self):
        return f"({self.a!r} & {self.b!r})"


class _Or(Filter):
    def __init__(self, a, b):
        super().__init__()
        self.a, self.b = a, b

    def mask(self, events):
        return self.a.mask(events) | self.b.mask(events)

    def columns(self):
        return self.a.columns() | self.b.columns()

    def process_bounds(self):
        ba, bb = self.a.process_bounds(), self.b.process_bounds()
        if ba is None or bb is None:
            return None
        return (min(ba[0], bb[0]), max(ba[1], bb[1]))

    def __repr__(self):
        return f"({self.a!r} | {self.b!r})"


class _Not(Filter):
    def __init__(self, a):
        super().__init__()
        self.a = a

    def mask(self, events):
        return ~self.a.mask(events)

    def columns(self):
        return self.a.columns()

    def process_bounds(self):
        return None  # complement of a bound is unbounded

    def __repr__(self):
        return f"~{self.a!r}"


def time_window_filter(start: float, end: float, trim: str = "overlap") -> Filter:
    """Convenience: filter to a time window.

    ``trim="overlap"`` (default) keeps every event whose *call interval*
    overlaps [start, end] — Trace.filter and the query planner materialize
    enter/leave matching to extend the window per matched pair, exactly like
    ``Trace.slice_time``.  ``trim="within"`` keeps only events whose own
    timestamp falls inside the window.
    """
    if trim not in ("overlap", "within"):
        raise ValueError(f'trim must be "overlap" or "within", got {trim!r}')
    f = Filter(TS, "between", (start, end))
    f._trim = trim
    return f
