"""TraceDiff: multi-trace comparison on the lazy query plan (paper §IV-D).

The paper's core critique of GUI trace tools is that they "do not support
automated comparisons of two or more datasets".  This module is that
comparison engine:

* :class:`TraceSet` — N traces opened through the same reader registry
  ``Trace.open`` uses (any registered format, optionally in parallel), held
  as one analyzable unit;
* :class:`SetQuery` — **one** lazy :class:`~repro.core.query.TraceQuery`
  plan executed across every member.  The plan's steps (mask fusion,
  structure remap, predicate pushdown) are shared; each member trace's
  derived structure is materialized at most once per set, then reused by
  every terminal op.  ``processes=N`` fans the per-member work (collect +
  matching) over a process pool;
* **set-scoped registry ops** — comparison analyses registered with
  ``scope="set"`` in :mod:`repro.core.registry`
  (``diff_flat_profile``, ``diff_time_profile``, ``scaling_analysis``,
  ``diff_load_imbalance``, ``regression_report``) terminate a set query the
  same way §IV ops terminate a single-trace query, and users can register
  their own.

Example::

    before, after = tracegen.regression_pair("tortuga", func="computeRhs")
    ts = TraceSet([before, after])
    report = (ts.query()
                .filter(Filter("Name", "not-in", ["MPI_Wait"]))
                .regression_report())          # one plan, both traces
"""

from __future__ import annotations

import weakref

import numpy as np

from typing import Any, Dict, List, Optional, Sequence, Tuple

from . import ops_summary, registry
from .constants import ENTER, ET, EXC, NAME, TS
from .filters import Filter
from .frame import EventFrame
from .query import (ProcessStep, SliceTimeStep, TraceQuery, _decompose_filter,
                    _TraceSource)
from .streaming import StreamAgg, StreamingTrace

__all__ = ["TraceSet", "SetQuery", "align_flat_profiles", "diff_flat_profile",
           "diff_time_profile", "scaling_analysis", "diff_load_imbalance",
           "regression_report"]


# ---------------------------------------------------------------------------
# labels and name alignment
# ---------------------------------------------------------------------------

def run_labels(traces: Sequence) -> List[str]:
    """Display label per run: ``trace.label`` or ``run<i>``, deduplicated
    (a repeated label gets ``#<i>`` appended so derived column names stay
    unique)."""
    labels: List[str] = []
    seen: Dict[str, int] = {}
    for i, t in enumerate(traces):
        lbl = getattr(t, "label", None) or f"run{i}"
        if lbl in seen:
            lbl = f"{lbl}#{i}"
        seen[lbl] = i
        labels.append(lbl)
    return labels


def align_flat_profiles(traces: Sequence, metric: str = EXC,
                        top_n: Optional[int] = None
                        ) -> Tuple[List[str], List[str], np.ndarray, np.ndarray]:
    """Name-aligned flat profiles across runs.

    Computes each run's :func:`~repro.core.ops_summary.flat_profile` and
    joins them on function name — the alignment every comparison op builds
    on.  Functions present in only some runs get 0.0 in the others; the
    ``present`` matrix records true membership so callers can distinguish
    "zero time" from "does not appear".

    Args:
        traces: sequence of Traces with structure materialized (callers
            going through ``TraceSet`` get this automatically).
        metric: metric column to aggregate — ``time.exc`` (default; time
            spent in the function itself, excluding callees) or ``time.inc``
            (including callees).  Values are ns, summed over all calls and
            processes of a run.
        top_n: keep each run's top-N functions by the metric before taking
            the union (None = all functions).

    Returns:
        ``(labels, names, matrix, present)``: per-run labels, the union of
        function names ordered by total metric across runs (descending),
        a ``(n_runs, n_names)`` float matrix of per-run totals, and a same-
        shape bool matrix marking real membership.
    """
    _ensure_structured(traces)
    profs = [_flat_profile_cached(t, metric) for t in traces]
    labels = run_labels(traces)
    weights: Dict[str, float] = {}
    for p in profs:
        names = p[NAME]
        vals = np.asarray(p[metric], np.float64)
        stop = top_n if top_n is not None else len(names)
        for nm, v in zip(names[:stop], vals[:stop]):
            weights[str(nm)] = weights.get(str(nm), 0.0) + float(v)
    cols = [nm for nm, _ in sorted(weights.items(), key=lambda kv: -kv[1])]
    idx = {nm: j for j, nm in enumerate(cols)}
    mat = np.zeros((len(traces), len(cols)))
    present = np.zeros((len(traces), len(cols)), dtype=bool)
    for i, p in enumerate(profs):
        for nm, v in zip(p[NAME], np.asarray(p[metric], np.float64)):
            j = idx.get(str(nm))
            if j is not None:
                mat[i, j] = float(v)
                present[i, j] = True
    return labels, cols, mat, present


def _ensure_structured(traces: Sequence) -> None:
    """Defensive prerequisite materialization for direct (non-query) calls;
    no-op per member when the SetQuery engine already ensured it.  Streaming
    members have no whole-trace structure — their per-op aggregates stitch
    it chunk by chunk instead."""
    for t in traces:
        if not isinstance(t, StreamingTrace):
            t._ensure_structure()


def _member_op(t, op_name: str, *args, **kwargs):
    """Run a single-trace op on one set member: in-memory members call the
    registered fn directly (prerequisites already ensured); streaming
    members execute the op's combinable form out of core."""
    if isinstance(t, StreamingTrace):
        return t.run(op_name, *args, **kwargs)
    return registry.get_op(op_name).fn(t, *args, **kwargs)


class _MetricTotalAgg(StreamAgg):
    """Streaming total of a call metric over the whole selection — the
    per-row semantics of the eager scaling_analysis total (each completed
    call contributes; an unmatched Enter contributes 0), *not* the
    flat-profile group semantics (where one unmatched Enter zeroes its
    whole function)."""

    needs_calls = True
    supports_parallel = True

    def __init__(self, metric: str = EXC):
        if metric not in ("time.inc", EXC):
            from .streaming import StreamingUnsupported
            raise StreamingUnsupported(
                f"streaming scaling_analysis supports metrics "
                f"('time.inc', {EXC!r}), got {metric!r}; open the members "
                f"with streaming=False for custom metric columns")
        self.metric = metric
        self.total = 0.0

    def update(self, chunk) -> None:
        calls = chunk.calls
        if calls is None or len(calls.name) == 0:
            return
        vals = calls.inc if self.metric == "time.inc" else calls.exc
        self.total += float(np.nan_to_num(vals).sum())

    def merge_from(self, other, code_map) -> None:
        self.total += other.total

    def result(self, ctx) -> float:
        return self.total


def _stream_metric_total(t: StreamingTrace, metric: str) -> float:
    from .streaming import execute_streaming
    spec = registry.OpSpec("_metric_total", fn=None,
                           streaming=_MetricTotalAgg)
    return execute_streaming(t, t._steps, spec, (), {"metric": metric})


# flat profiles keyed per trace object — the shared-plan workflow chains
# several comparison ops over the same prepared members, and each aligns
# profiles; without this every op would redo a full aggregation pass per
# member.  Weak keys: entries die with their traces.  The event count guards
# against in-place frame mutation between ops.
_PROFILE_CACHE = weakref.WeakKeyDictionary()


def _flat_profile_cached(t, metric: str):
    if isinstance(t, StreamingTrace):
        # handles are immutable (paths + plan steps), so no staleness guard
        try:
            entry = _PROFILE_CACHE.setdefault(t, {})
        except TypeError:  # pragma: no cover - defensive
            entry = {}
        if metric not in entry:
            entry[metric] = t.run("flat_profile", metrics=[metric])
        return entry[metric]
    try:
        entry = _PROFILE_CACHE.get(t)
    except TypeError:       # non-weakrefable trace subclass: just compute
        return ops_summary.flat_profile(t, metrics=[metric])
    n = len(t.events)
    if entry is not None and entry.get("_n") == n and metric in entry:
        return entry[metric]
    prof = ops_summary.flat_profile(t, metrics=[metric])
    if entry is None or entry.get("_n") != n:
        entry = {"_n": n}
        _PROFILE_CACHE[t] = entry
    entry[metric] = prof
    return prof


def _name_order_key(cols: Sequence[str]) -> np.ndarray:
    """Deterministic integer tie-break key for a list of unique names."""
    _, codes = np.unique(np.asarray(cols, dtype=object).astype(str),
                         return_inverse=True)
    return codes


def _require_runs(traces: Sequence, n: int, op: str) -> None:
    if len(traces) < n:
        raise ValueError(f"{op} needs at least {n} traces, got {len(traces)}")


def _resolve_run(i: int, n: int) -> int:
    """Normalize a (possibly negative) run index; loud on out-of-range —
    silent wrapping would quietly compare a run against itself."""
    j = n + i if i < 0 else i
    if not 0 <= j < n:
        raise IndexError(f"run index {i} out of range for {n} traces")
    return j


# ---------------------------------------------------------------------------
# set-scoped comparison ops (registered like every §IV single-trace op)
# ---------------------------------------------------------------------------

@registry.register_op("diff_flat_profile", needs_structure=True, scope="set")
def diff_flat_profile(traces: Sequence, metric: str = EXC,
                      mode: str = "absolute", baseline: int = 0,
                      top_n: Optional[int] = None) -> EventFrame:
    """Per-function deltas between runs' flat profiles (§IV-D).

    Profiles are name-aligned across all runs (functions missing from a run
    count as 0), then every non-baseline run is compared against the
    baseline run.  ``diff_flat_profile([a, b])`` is antisymmetric in
    absolute/normalized mode: swapping the runs negates every delta.

    Args:
        traces: 2+ traces; ``baseline`` is an index into this sequence
            (negative indices allowed).
        metric: ``time.exc`` (default, ns of self time) or ``time.inc``
            (ns including callees).
        mode: ``"absolute"`` — delta in metric units (ns);
            ``"relative"`` — delta / baseline value (+inf where a function
            is new in a run, 0 where absent from both);
            ``"normalized"`` — each run's profile is first scaled to
            fractions of its own total, so runs of different overall length
            compare shape-to-shape (delta is a fraction).
        top_n: restrict alignment to each run's top-N functions.

    Returns:
        EventFrame with ``Name``, one ``<metric>|<label>`` column per run
        (post-normalization values for ``mode="normalized"``), and one
        ``delta|<label>`` column per non-baseline run, sorted by the largest
        absolute delta (ties broken by name, so orderings are reproducible).
    """
    _require_runs(traces, 2, "diff_flat_profile")
    if mode not in ("absolute", "relative", "normalized"):
        raise ValueError(f'mode must be "absolute", "relative" or '
                         f'"normalized", got {mode!r}')
    labels, cols, mat, present = align_flat_profiles(traces, metric=metric,
                                                     top_n=top_n)
    base_i = _resolve_run(baseline, len(traces))
    vals = mat
    if mode == "normalized":
        totals = mat.sum(axis=1, keepdims=True)
        vals = mat / np.maximum(totals, 1e-30)
    base = vals[base_i]
    deltas = []
    for i in range(len(traces)):
        if i == base_i:
            continue
        d = vals[i] - base
        if mode == "relative":
            with np.errstate(divide="ignore", invalid="ignore"):
                d = np.where(base > 0, d / np.maximum(base, 1e-30),
                             np.where(vals[i] > 0, np.inf, 0.0))
        deltas.append((labels[i], d))
    key = np.max(np.abs(np.asarray([d for _, d in deltas])), axis=0)
    finite = np.where(np.isfinite(key), key, np.nanmax(key[np.isfinite(key)],
                                                       initial=0.0) + 1.0)
    order = np.lexsort((_name_order_key(cols), -finite))
    out = EventFrame({NAME: np.asarray(cols, dtype=object)[order]})
    for i, lbl in enumerate(labels):
        out[f"{metric}|{lbl}"] = vals[i][order]
    for lbl, d in deltas:
        out[f"delta|{lbl}"] = d[order]
    return out


@registry.register_op("diff_time_profile", needs_structure=True, scope="set")
def diff_time_profile(traces: Sequence, num_bins: int = 32, metric: str = EXC,
                      baseline: int = 0, target: int = -1,
                      normalized: bool = False) -> EventFrame:
    """Binned time-profile delta between two runs (§IV-B applied to §IV-D).

    Each run's :func:`~repro.core.ops_summary.time_profile` spreads every
    call's metric over its [enter, leave) span and bins it.  Runs of
    different duration are *resampled* onto a common axis: each run's own
    [t_min, t_max] is divided into the same ``num_bins`` bins, so bin *i*
    means "the i-th fraction of that run" and the delta compares matching
    program phases, not absolute wall-clock instants.

    Args:
        traces: 2+ traces; ``baseline``/``target`` index into the sequence
            (defaults: first vs last).
        num_bins: bins per run.
        metric: ``time.exc`` (ns, default) or ``time.inc``.
        normalized: normalize each bin to fractions of that bin's total
            before differencing (compares shape, not magnitude).

    Returns:
        EventFrame with ``bin`` (index) and ``bin_frac`` (bin center as a
        fraction of run duration), plus one column per function present in
        either run holding ``target − baseline`` per bin, columns ordered
        by total absolute delta (descending).
    """
    _require_runs(traces, 2, "diff_time_profile")
    _ensure_structured(traces)
    n = len(traces)
    base_i, tgt_i = _resolve_run(baseline, n), _resolve_run(target, n)
    profs = {}
    for i in (base_i, tgt_i):
        p = _member_op(traces[i], "time_profile", num_bins=num_bins,
                       metric=metric, normalized=normalized)
        funcs = [c for c in p.columns if c not in ("bin_start", "bin_end")]
        profs[i] = {f: np.asarray(p[f], np.float64) for f in funcs}
    union = sorted(set(profs[base_i]) | set(profs[tgt_i]))
    zeros = np.zeros(num_bins)
    deltas = {f: profs[tgt_i].get(f, zeros) - profs[base_i].get(f, zeros)
              for f in union}
    order = sorted(union, key=lambda f: (-float(np.abs(deltas[f]).sum()), f))
    out = EventFrame({
        "bin": np.arange(num_bins, dtype=np.int64),
        "bin_frac": (np.arange(num_bins) + 0.5) / num_bins,
    })
    for f in order:
        out[f] = deltas[f]
    return out


@registry.register_op("scaling_analysis", needs_structure=True, scope="set")
def scaling_analysis(traces: Sequence, metric: str = EXC,
                     mode: str = "strong", top_n: Optional[int] = 8
                     ) -> EventFrame:
    """Scaling series over a set of runs at different process counts (§IV-D,
    Fig. 12 — the paper's Tortuga scaling study).

    Runs are ordered by process count.  Wall-clock time (last − first event
    timestamp, ns) gives speedup/efficiency; the aligned per-function totals
    show *which* functions stop scaling.

    Args:
        traces: 2+ runs of the same application at different ``nprocs``.
        metric: per-function aggregate — ``time.exc`` (ns, default) or
            ``time.inc``.
        mode: ``"strong"`` — fixed total problem: efficiency =
            (T_base / T_p) / (p / p_base); ``"weak"`` — problem grows with
            p: efficiency = T_base / T_p.
        top_n: per-function columns for the top-N functions by total metric
            across runs (None = all).

    Returns:
        EventFrame sorted by process count with ``Run``, ``num_processes``,
        ``duration`` (wall ns), ``speedup``, ``efficiency``,
        ``<metric>.total`` (sum over all functions and processes, ns), and
        one ``<metric>`` column per top function.
    """
    _require_runs(traces, 2, "scaling_analysis")
    if mode not in ("strong", "weak"):
        raise ValueError(f'mode must be "strong" or "weak", got {mode!r}')
    order = sorted(range(len(traces)), key=lambda i: traces[i].num_processes)
    runs = [traces[i] for i in order]
    labels, cols, mat, _ = align_flat_profiles(runs, metric=metric,
                                               top_n=top_n)
    nprocs = np.asarray([t.num_processes for t in runs], np.float64)
    dur = np.empty(len(runs))
    tot = np.empty(len(runs))
    for i, t in enumerate(runs):
        if isinstance(t, StreamingTrace):
            # whole-stream facts: span from the one-pass stats, total from
            # a dedicated per-call aggregate (matches the eager per-row
            # nan_to_num sum exactly, including unbalanced traces)
            st = t.stats()
            dur[i] = (st.ts_max - st.ts_min) if st.n_events else 0.0
            tot[i] = _stream_metric_total(t, metric)
            continue
        ev = t.events
        ts = np.asarray(ev[TS], np.float64)
        dur[i] = float(ts.max() - ts.min()) if len(ts) else 0.0
        # total over ALL functions (the aligned matrix is top_n-truncated)
        ent = ev.cat(ET).mask_eq(ENTER)
        tot[i] = float(np.nan_to_num(
            np.asarray(ev.column(metric), np.float64)[ent]).sum())
    speedup = np.where(dur > 0, dur[0] / np.maximum(dur, 1e-30), 0.0)
    ideal = nprocs / max(nprocs[0], 1.0)
    eff = speedup / ideal if mode == "strong" else speedup
    out = EventFrame({
        "Run": np.asarray(labels, dtype=object),
        "num_processes": nprocs.astype(np.int64),
        "duration": dur,
        "speedup": speedup,
        "efficiency": eff,
        f"{metric}.total": tot,
    })
    for j, c in enumerate(cols):
        out[c] = mat[:, j]
    return out


@registry.register_op("diff_load_imbalance", needs_structure=True, scope="set")
def diff_load_imbalance(traces: Sequence, metric: str = EXC, baseline: int = 0,
                        target: int = -1, num_processes: int = 5) -> EventFrame:
    """Per-function load-imbalance delta between two runs (§IV-D).

    Imbalance per function is max-over-processes / mean-over-processes of
    the metric (1.0 = perfectly balanced), from
    :func:`~repro.core.ops_summary.load_imbalance`; the delta shows which
    functions got *more* skewed between the runs.

    Args:
        traces: 2+ traces; ``baseline``/``target`` index into the sequence
            (defaults: first vs last).
        metric: ``time.exc`` (default) or ``time.inc``.
        num_processes: forwarded to the per-run op (size of its top-process
            list; does not affect the ratio).

    Returns:
        EventFrame with ``Name``, ``imbalance|<label>`` for both runs (0
        where the function is absent), and ``delta`` (target − baseline),
        sorted by delta descending (functions that got worse first, ties
        broken by name).
    """
    _require_runs(traces, 2, "diff_load_imbalance")
    _ensure_structured(traces)
    n = len(traces)
    base_i, tgt_i = _resolve_run(baseline, n), _resolve_run(target, n)
    labels = run_labels(traces)
    col = f"{metric}.imbalance"
    imb: Dict[int, Dict[str, float]] = {}
    for i in (base_i, tgt_i):
        li = _member_op(traces[i], "load_imbalance", metric=metric,
                        num_processes=num_processes)
        imb[i] = {str(nm): float(v)
                  for nm, v in zip(li[NAME], np.asarray(li[col], np.float64))}
    union = sorted(set(imb[base_i]) | set(imb[tgt_i]))
    b = np.asarray([imb[base_i].get(f, 0.0) for f in union])
    t = np.asarray([imb[tgt_i].get(f, 0.0) for f in union])
    d = t - b
    order = np.lexsort((_name_order_key(union), -d))
    return EventFrame({
        NAME: np.asarray(union, dtype=object)[order],
        f"imbalance|{labels[base_i]}": b[order],
        f"imbalance|{labels[tgt_i]}": t[order],
        "delta": d[order],
    })


@registry.register_op("regression_report", needs_structure=True, scope="set")
def regression_report(traces: Sequence, metric: str = EXC, baseline: int = 0,
                      target: int = -1, threshold: float = 0.05,
                      top_n: Optional[int] = None) -> EventFrame:
    """Ranked per-function regression report between two runs (§IV-D) — the
    automated "what got slower?" pass GUI tools cannot script.

    Functions are aligned by name across the baseline and target runs and
    ranked by absolute delta of the metric, regressions first.  Functions
    appearing in only one run are flagged rather than silently zero-filled.

    Args:
        traces: 2+ traces; ``baseline``/``target`` index into the sequence
            (defaults: first vs last, i.e. before vs after).
        metric: ``time.exc`` (ns of self time, default) or ``time.inc``.
        threshold: relative-change cutoff separating ``regressed`` /
            ``improved`` from ``stable`` (0.05 = 5%).
        top_n: truncate the report to the N largest deltas (None = all).

    Returns:
        EventFrame sorted by delta descending (worst regression first, ties
        broken by name) with ``Name``, ``<metric>|<label>`` for both runs,
        ``delta`` (target − baseline, ns), ``delta_rel`` (delta / baseline;
        +inf for new functions), and ``status`` ∈ {``regressed``,
        ``improved``, ``stable``, ``new``, ``vanished``}.
    """
    _require_runs(traces, 2, "regression_report")
    n = len(traces)
    base_i, tgt_i = _resolve_run(baseline, n), _resolve_run(target, n)
    labels, cols, mat, present = align_flat_profiles(traces, metric=metric)
    base, tgt = mat[base_i], mat[tgt_i]
    in_base, in_tgt = present[base_i], present[tgt_i]
    delta = tgt - base
    with np.errstate(divide="ignore", invalid="ignore"):
        rel = np.where(base > 0, delta / np.maximum(base, 1e-30),
                       np.where(tgt > 0, np.inf, 0.0))
    status = np.where(~in_base & in_tgt, "new",
                      np.where(in_base & ~in_tgt, "vanished",
                               np.where(rel > threshold, "regressed",
                                        np.where(rel < -threshold, "improved",
                                                 "stable")))).astype(object)
    keep = in_base | in_tgt  # drop rows contributed only by other runs
    sel = np.nonzero(keep)[0]
    order = sel[np.lexsort((_name_order_key(cols)[sel], -delta[sel]))]
    if top_n is not None:
        by_mag = np.argsort(-np.abs(delta[order]), kind="stable")[:top_n]
        order = order[np.sort(by_mag)]
    return EventFrame({
        NAME: np.asarray(cols, dtype=object)[order],
        f"{metric}|{labels[base_i]}": base[order],
        f"{metric}|{labels[tgt_i]}": tgt[order],
        "delta": delta[order],
        "delta_rel": rel[order],
        "status": status[order],
    })


# ---------------------------------------------------------------------------
# process-parallel member preparation
# ---------------------------------------------------------------------------

def _prepare_member(args) -> tuple:
    """Pool worker: execute one member's plan and materialize prerequisites.

    Runs in a spawned interpreter — receives the member's events plus its
    cached derivation state, rebuilds the Trace, collects the shared plan,
    and returns the materialized pieces for the parent to reassemble
    without recomputing anything.
    """
    (events, structured, msg_match, definitions, label, steps,
     needs_structure, needs_messages) = args
    from .trace import Trace
    t = Trace(events, definitions=definitions, label=label)
    t._structured = structured
    t._msg_match = msg_match
    q = TraceQuery(_TraceSource(t), steps)
    out = q.collect()
    if needs_structure:
        out._ensure_structure()
    if needs_messages:
        out._ensure_messages()
    return (out.events, out._structured, out._msg_match, out.label,
            out.definitions)


class SetQuery:
    """One immutable lazy plan over every member of a :class:`TraceSet`.

    Builder methods mirror :class:`~repro.core.query.TraceQuery` and return
    a new query sharing the step tuple; nothing executes until a terminal
    op.  The first terminal op materializes each member once (selection
    applied, prerequisites ensured) and caches the result on this query, so
    chaining several comparison ops over the same plan — the common diff
    workflow — pays ingest, mask application, and event matching exactly
    once per member.
    """

    def __init__(self, traces: Sequence, steps: Sequence = ()):
        self._traces = list(traces)
        self._steps = tuple(steps)
        self._collected: Optional[List] = None

    # -- construction ------------------------------------------------------
    def _with(self, step) -> "SetQuery":
        return SetQuery(self._traces, self._steps + (step,))

    def filter(self, f: Filter) -> "SetQuery":
        q = self
        for step in _decompose_filter(f):
            q = q._with(step)
        return q

    def slice_time(self, start: float, end: float,
                   trim: str = "overlap") -> "SetQuery":
        return self._with(SliceTimeStep(start, end, trim))

    def restrict_processes(self, procs: Sequence[int]) -> "SetQuery":
        return self._with(ProcessStep(procs))

    filter_processes = restrict_processes

    def explain(self) -> str:
        """The shared plan, as TraceQuery.explain, once per member source."""
        lines = [f"set of {len(self._traces)} trace(s); shared plan:"]
        first = self._traces[0]
        if isinstance(first, StreamingTrace):
            proto = TraceQuery(first.query()._source, self._steps)
        else:
            proto = TraceQuery(_TraceSource(first), self._steps)
        lines.extend("  " + ln for ln in proto.explain().splitlines())
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"SetQuery({len(self._traces)} trace(s), "
                f"{len(self._steps)} step(s))")

    # -- execution ---------------------------------------------------------
    @staticmethod
    def _pool_prepare(traces: Sequence, steps, needs_structure: bool,
                      needs_messages: bool, processes: int) -> List:
        """Run collect + prerequisite materialization in a spawn pool and
        reassemble the prepared Traces in the parent (serial fallback for
        stdin / -c / REPL ``__main__`` lives in repro.parallel_util)."""
        from .trace import Trace
        from ..parallel_util import map_maybe_parallel
        args = [(t.events, t._structured, t._msg_match, t.definitions,
                 t.label, tuple(steps), needs_structure, needs_messages)
                for t in traces]
        parts, _pooled = map_maybe_parallel(_prepare_member, args, processes)
        out = []
        for ev, structured, mm, label, defs in parts:
            t = Trace(ev, definitions=defs, label=label)
            t._structured = structured
            t._msg_match = mm
            out.append(t)
        return out

    def _prepare(self, needs_structure: bool, needs_messages: bool,
                 processes: Optional[int] = None) -> List:
        """Collect every member's plan and ensure prerequisites, caching the
        materialized traces on this query (shared-plan execution).

        Streaming members are never materialized: the shared plan's steps
        are bound onto the handle (``with_steps``) and each terminal op
        executes them out of core, chunk by chunk."""
        use_pool = bool(processes and processes > 1)
        if self._collected is None and any(
                isinstance(t, StreamingTrace) for t in self._traces):
            self._collected = [
                t.with_steps(tuple(t._steps) + self._steps)
                if isinstance(t, StreamingTrace)
                else TraceQuery(_TraceSource(t), self._steps).collect()
                for t in self._traces]
        if self._collected is None:
            if use_pool and len(self._traces) > 1:
                self._collected = self._pool_prepare(
                    self._traces, self._steps, needs_structure,
                    needs_messages, processes)
            else:
                self._collected = [
                    TraceQuery(_TraceSource(t), self._steps).collect()
                    for t in self._traces]
        elif use_pool:
            # members were cached by an earlier terminal, but this op's
            # prerequisites may still be unmaterialized — honor the pool
            # request for that (possibly heavy) work too
            idx = [i for i, t in enumerate(self._collected)
                   if not isinstance(t, StreamingTrace)
                   and ((needs_structure and not t._structured)
                        or (needs_messages and t._msg_match is None))]
            if len(idx) > 1:
                prepared = self._pool_prepare(
                    [self._collected[i] for i in idx], (), needs_structure,
                    needs_messages, processes)
                for i, t in zip(idx, prepared):
                    self._collected[i] = t
        for t in self._collected:
            if isinstance(t, StreamingTrace):
                continue  # structure stitches per chunk inside each op
            if needs_structure:
                t._ensure_structure()
            if needs_messages:
                t._ensure_messages()
        return self._collected

    def collect(self, processes: Optional[int] = None) -> List:
        """Execute the shared plan; returns the list of selected Traces."""
        return list(self._prepare(False, False, processes))

    def run(self, op_name: str, *args: Any, processes: Optional[int] = None,
            **kwargs: Any) -> Any:
        """Run a registered op across the set.

        A ``scope="set"`` op receives the whole list of prepared traces and
        returns its comparison result; a ``scope="trace"`` op is mapped over
        the members and returns a list of per-trace results (in set order).
        ``processes`` > 1 prepares members in a process pool.
        """
        spec = registry.get_op(op_name)
        if spec is None:
            raise ValueError(f"unknown analysis op {op_name!r}; "
                             f"registered: {registry.list_ops()}")
        traces = self._prepare(spec.needs_structure, spec.needs_messages,
                               processes)
        if spec.scope == "set":
            return spec.fn(traces, *args, **kwargs)
        return [_member_op(t, op_name, *args, **kwargs) for t in traces]

    def __getattr__(self, name: str):
        return registry.terminal_op(name, self.run, "SetQuery")


def _relabel(t, label: str):
    """Shallow clone of a Trace under a new label, sharing the events frame
    and every derivation cache with the original."""
    if isinstance(t, StreamingTrace):
        clone = t.with_steps(t._steps)
        clone.label = label
        return clone
    clone = type(t)(t.events, definitions=t.definitions, label=label)
    clone._structured = t._structured
    clone._msg_match = t._msg_match
    clone._cct = t._cct
    return clone


class TraceSet:
    """N traces analyzed as one unit — the entry point for cross-run diffs.

    Construct from in-memory traces (``TraceSet([a, b, c])``) or straight
    from disk with :meth:`open`, which resolves each path through the same
    reader registry ``Trace.open`` uses (format sniffing included) and can
    ingest members in parallel.  Every registered analysis op is a method:
    set-scoped comparison ops (``diff_flat_profile``, ``regression_report``,
    ...) compare the members; single-trace ops map over them.  Start a
    shared lazy plan with :meth:`query` to select data once for several
    comparison ops.
    """

    def __init__(self, traces: Sequence, labels: Optional[Sequence[str]] = None):
        self._traces = list(traces)
        if not self._traces:
            raise ValueError("TraceSet needs at least one trace")
        if labels is not None:
            if len(labels) != len(self._traces):
                raise ValueError(f"{len(labels)} labels for "
                                 f"{len(self._traces)} traces")
            # relabel via shallow clones — never mutate the caller's traces
            # (two sets over the same trace must not clobber each other's
            # labels).  Clones share the events frame and derivation caches,
            # so nothing is recomputed.
            self._traces = [_relabel(t, lbl)
                            for t, lbl in zip(self._traces, labels)]

    @classmethod
    def open(cls, paths: Sequence, format: str = "auto",
             processes: Optional[int] = None,
             labels: Optional[Sequence[str]] = None, streaming: bool = False,
             chunk_rows: Optional[int] = None, **kw) -> "TraceSet":
        """Open N traces (any registered format; content is sniffed per
        member exactly like ``Trace.open``).  Each item may itself be a list
        of per-rank shard paths — those go through the parallel shard
        driver.  ``processes`` > 1 opens members concurrently.

        ``streaming=True`` opens every member as an out-of-core
        :class:`~repro.core.streaming.StreamingTrace`: comparison ops then
        stream each member chunk by chunk (diff profiles across traces that
        do not fit in RAM together).  ``processes=N`` then turns on the
        multi-core plan executor for every member, with all members' work
        units fanning into **one** shared spawn pool (worker startup is
        paid once per set, not once per member)."""
        if streaming:
            from .streaming import DEFAULT_CHUNK_ROWS
            members = [StreamingTrace(p, format=format,
                                      chunk_rows=chunk_rows
                                      or DEFAULT_CHUNK_ROWS,
                                      processes=processes, **kw)
                       for p in paths]
            # one pool for the whole set whenever members will run parallel
            # (processes=N, or executor="parallel" passed through **kw) —
            # obtained from the shared scheduler, so the set's pool is also
            # the pool every other same-sized consumer in the process uses
            if members and members[0].wants_parallel():
                from .scheduler import get_scheduler
                shared = get_scheduler().spawn_pool(processes)
                for m in members:
                    m._pool = shared
            return cls(members, labels=labels)
        if chunk_rows is not None:
            raise ValueError("chunk_rows only applies with streaming=True")
        from ..readers.parallel import open_many
        return cls(open_many(paths, kind=format, processes=processes, **kw),
                   labels=labels)

    # -- container protocol ------------------------------------------------
    @property
    def traces(self) -> List:
        return list(self._traces)

    @property
    def labels(self) -> List[str]:
        return run_labels(self._traces)

    def __len__(self) -> int:
        return len(self._traces)

    def __iter__(self):
        return iter(self._traces)

    def __getitem__(self, i):
        return self._traces[i]

    def __repr__(self) -> str:  # pragma: no cover
        return f"TraceSet({self.labels})"

    # -- analysis ----------------------------------------------------------
    def query(self) -> SetQuery:
        """Start one lazy plan executed across every member (see SetQuery)."""
        return SetQuery(self._traces)

    def run(self, op_name: str, *args: Any, **kwargs: Any) -> Any:
        return self.query().run(op_name, *args, **kwargs)

    def __getattr__(self, name: str):
        return registry.terminal_op(name, self.run, "TraceSet")
